"""Figure 5: query execution time vs cache budget for file_lru / chunk_lru /
cost-based caching, across PTF-1 (hdf5), PTF-2 (fits), GEO (csv)."""
from __future__ import annotations

from benchmarks.common import (build_geo, build_ptf, cell_anchors,
                               dataset_bytes, make_cluster, timed)
from repro.core.cluster import workload_summary
from repro.core.workload import geo_workload, ptf1_workload, ptf2_workload

POLICIES = ("file_lru", "chunk_lru", "cost")
# Budget fractions spanning the paper's regime: the smallest is near the
# workload's chunk working set (eviction pressure on chunk caches, thrash
# for whole-file caching); the largest lets chunk caches converge while
# file-granularity caching still cannot hold the touched files (§4.2.1).
BUDGET_FRACTIONS = (0.05, 0.10, 0.20)
# Join radii matched to the synthetic data's cell spacing so cross-chunk
# pairs exist (the paper joins arcsecond-scale matches on dense real data).
PTF_EPS, GEO_EPS = 300, 500


def _workloads():
    ptf1_cat, ptf1_rd = build_ptf("hdf5", seed=21)
    ptf2_cat, ptf2_rd = build_ptf("fits", seed=22)
    geo_cat, geo_rd = build_geo("csv", seed=11)
    a1 = cell_anchors(ptf1_cat, ptf1_rd, seed=1)
    a2 = cell_anchors(ptf2_cat, ptf2_rd, seed=2)
    return {
        "ptf1_hdf5": (ptf1_cat, ptf1_rd,
                      ptf1_workload(ptf1_cat.domain, n_queries=10,
                                    eps=PTF_EPS, anchors=a1)),
        "ptf2_fits": (ptf2_cat, ptf2_rd,
                      ptf2_workload(ptf2_cat.domain, n_queries=10,
                                    eps=PTF_EPS, anchors=a2)),
        "geo_csv": (geo_cat, geo_rd,
                    geo_workload(geo_cat.domain, eps=GEO_EPS)),
    }


def run(print_rows: bool = True):
    results = {}
    for wl_name, (catalog, reader, queries) in _workloads().items():
        total = dataset_bytes(catalog)
        for frac in BUDGET_FRACTIONS:
            for policy in POLICIES:
                cluster = make_cluster(catalog, reader, policy,
                                       int(total * frac))
                executed, us = timed(cluster.run_workload, queries)
                summ = workload_summary(executed)
                per_query = [e.time_total_s for e in executed]
                key = (wl_name, frac, policy)
                results[key] = {"summary": summ, "per_query": per_query}
                if print_rows:
                    print(f"fig5/{wl_name}/b{frac}/{policy},{us:.0f},"
                          f"{summ['total_time_s']:.3f}")
    # Headline derived metric: cost vs baselines at the smallest budget.
    for wl_name in ("ptf1_hdf5", "ptf2_fits", "geo_csv"):
        f = BUDGET_FRACTIONS[0]
        cost = results[(wl_name, f, "cost")]["summary"]["total_time_s"]
        for base in ("file_lru", "chunk_lru"):
            b = results[(wl_name, f, base)]["summary"]["total_time_s"]
            if print_rows:
                print(f"fig5/{wl_name}/speedup_vs_{base},0,"
                      f"{b / max(cost, 1e-9):.2f}")
    return results


if __name__ == "__main__":
    run()
