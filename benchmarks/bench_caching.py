"""Figure 5: query execution time vs cache budget for the registered
caching policies, across PTF-1 (hdf5), PTF-2 (fits), GEO (csv).

CLI knobs (the perf-trajectory harness):

    python -m benchmarks.bench_caching --policy cost,chunk_lru \
        --batch-size 4 --reuse on --out BENCH_caching.json
    python -m benchmarks.bench_caching --sweep --out BENCH_caching.json

``--policy`` selects any registered policy combos (default: the paper's
three), ``--sweep`` replaces the policy list with the FULL valid
(granularity x eviction x placement) cross product from the registries
and records the per-workload winner under the JSON's ``sweep`` key,
``--batch-size`` routes admission through the coordinator's batched
planning path, ``--reuse on`` enables the semantic cache-reuse rewrite,
and ``--out`` writes a JSON summary — including the resolved policy
spec and the reuse stats of every run — so successive PRs can diff the
trajectory.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, Optional, Sequence

from benchmarks.common import (build_geo, build_ptf, cell_anchors,
                               dataset_bytes, make_cluster, timed)
from repro.core.cluster import workload_summary
from repro.core.workload import geo_workload, ptf1_workload, ptf2_workload

POLICIES = ("file_lru", "chunk_lru", "cost")
# Budget fractions spanning the paper's regime: the smallest is near the
# workload's chunk working set (eviction pressure on chunk caches, thrash
# for whole-file caching); the largest lets chunk caches converge while
# file-granularity caching still cannot hold the touched files (§4.2.1).
BUDGET_FRACTIONS = (0.05, 0.10, 0.20)
# Join radii matched to the synthetic data's cell spacing so cross-chunk
# pairs exist (the paper joins arcsecond-scale matches on dense real data).
PTF_EPS, GEO_EPS = 300, 500


def sweep_policy_names() -> Sequence[str]:
    """The full valid (granularity x eviction x placement) cross product,
    as registered combo names. Triples already registered keep their
    canonical name (``cost``, ``chunk_lru``, ...); the rest are
    registered on the fly as ``{granularity}_{eviction}_{placement}``."""
    from repro.core.policies import (EVICTION_REGISTRY, GRANULARITIES,
                                     PLACEMENT_REGISTRY, POLICY_REGISTRY,
                                     PolicySpec, register_policy)
    names = []
    for gran in GRANULARITIES:
        for ev in EVICTION_REGISTRY:
            for pl in PLACEMENT_REGISTRY:
                spec = PolicySpec(f"{gran}_{ev}_{pl}", gran, ev, pl)
                try:
                    spec.validate()
                except ValueError:
                    continue            # e.g. file granularity needs an
                    # online-capable eviction policy
                existing = next(
                    (s.name for s in POLICY_REGISTRY.values()
                     if (s.granularity, s.eviction, s.placement)
                     == (gran, ev, pl)), None)
                names.append(existing or register_policy(spec).name)
    return tuple(names)


def sweep_winners(results: Dict) -> Dict:
    """Per-workload winners over a sweep: the combo minimizing total
    modeled time summed across budget fractions, plus the per-budget
    winner (ties break lexicographically for determinism)."""
    totals: Dict[str, Dict[str, float]] = {}
    by_budget: Dict[str, Dict[str, Dict[str, float]]] = {}
    specs: Dict[str, Dict] = {}
    for (wl, frac, policy), payload in sorted(results.items()):
        t = payload["summary"]["total_time_s"]
        totals.setdefault(wl, {})
        totals[wl][policy] = totals[wl].get(policy, 0.0) + t
        by_budget.setdefault(wl, {}).setdefault(str(frac), {})[policy] = t
        specs[policy] = payload["policy_spec"]
    out: Dict = {}
    for wl in sorted(totals):
        best = min(sorted(totals[wl]), key=lambda p: totals[wl][p])
        out[wl] = {
            "policy": best,
            "policy_spec": specs[best],
            "total_time_s": totals[wl][best],
            "by_budget": {
                frac: min(sorted(t), key=lambda p: t[p])
                for frac, t in sorted(by_budget[wl].items())},
        }
    return out


def _workloads():
    ptf1_cat, ptf1_rd = build_ptf("hdf5", seed=21)
    ptf2_cat, ptf2_rd = build_ptf("fits", seed=22)
    geo_cat, geo_rd = build_geo("csv", seed=11)
    a1 = cell_anchors(ptf1_cat, ptf1_rd, seed=1)
    a2 = cell_anchors(ptf2_cat, ptf2_rd, seed=2)
    return {
        "ptf1_hdf5": (ptf1_cat, ptf1_rd,
                      ptf1_workload(ptf1_cat.domain, n_queries=10,
                                    eps=PTF_EPS, anchors=a1)),
        "ptf2_fits": (ptf2_cat, ptf2_rd,
                      ptf2_workload(ptf2_cat.domain, n_queries=10,
                                    eps=PTF_EPS, anchors=a2)),
        "geo_csv": (geo_cat, geo_rd,
                    geo_workload(geo_cat.domain, eps=GEO_EPS)),
    }


def run(print_rows: bool = True, policies: Sequence[str] = POLICIES,
        budget_fractions: Sequence[float] = BUDGET_FRACTIONS,
        batch_size: Optional[int] = None, reuse: str = "off"):
    results = {}
    for wl_name, (catalog, reader, queries) in _workloads().items():
        total = dataset_bytes(catalog)
        for frac in budget_fractions:
            for policy in policies:
                cluster = make_cluster(catalog, reader, policy,
                                       int(total * frac), reuse=reuse)
                executed, us = timed(cluster.run_workload, queries,
                                     batch_size=batch_size)
                summ = workload_summary(executed)
                per_query = [e.time_total_s for e in executed]
                spec = cluster.coordinator.spec
                key = (wl_name, frac, policy)
                results[key] = {
                    "summary": summ, "per_query": per_query,
                    "policy_spec": {"granularity": spec.granularity,
                                    "eviction": spec.eviction,
                                    "placement": spec.placement}}
                if print_rows:
                    print(f"fig5/{wl_name}/b{frac}/{policy},{us:.0f},"
                          f"{summ['total_time_s']:.3f}")
    # Headline derived metric: cost vs baselines at the smallest budget.
    f = budget_fractions[0]
    for wl_name in ("ptf1_hdf5", "ptf2_fits", "geo_csv"):
        if (wl_name, f, "cost") not in results:
            continue
        cost = results[(wl_name, f, "cost")]["summary"]["total_time_s"]
        for base in ("file_lru", "chunk_lru"):
            if (wl_name, f, base) not in results:
                continue
            b = results[(wl_name, f, base)]["summary"]["total_time_s"]
            if print_rows:
                print(f"fig5/{wl_name}/speedup_vs_{base},0,"
                      f"{b / max(cost, 1e-9):.2f}")
    return results


def to_json_summary(results: Dict, policies: Sequence[str],
                    batch_size: Optional[int],
                    reuse: str = "off", sweep: bool = False) -> Dict:
    """Serialize run() results: per (workload, policy, budget fraction)
    the modeled times, scan volume, the resolved policy spec, and the
    semantic-reuse counters of that run (the ``reuse`` knob is recorded
    once, at the top level). With ``sweep=True`` the per-workload winning
    combos are recorded under the ``sweep`` key."""
    out: Dict = {"benchmark": "bench_caching", "policies": list(policies),
                 "batch_size": batch_size, "reuse": reuse, "workloads": {}}
    if sweep:
        out["sweep"] = {"policies": list(policies),
                        "winners": sweep_winners(results)}
    for (wl, frac, policy), payload in results.items():
        wl_entry = out["workloads"].setdefault(wl, {})
        pol_entry = wl_entry.setdefault(policy, {})
        pol_entry[str(frac)] = {
            **{k: payload["summary"][k]
               for k in ("total_time_s", "scan_time_s", "net_time_s",
                         "compute_time_s", "opt_time_s", "bytes_scanned",
                         "files_scanned", "reuse_hits", "reuse_bytes_served",
                         "residual_bytes_scanned", "reuse_scan_skips")},
            # Join-kernel block-pair counters (0.0 when joins are not
            # executed, the bench_caching default; BENCH_kernels.json
            # carries the executed-join pruning trajectory).
            **{k: payload["summary"].get(k, 0.0)
               for k in ("block_pairs_total", "block_pairs_evaluated")},
            "policy_spec": payload["policy_spec"],
        }
    return out


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--policy", default=",".join(POLICIES),
                    help="comma-separated registered policy combos "
                         "(e.g. cost,chunk_lru,chunk_lfu)")
    ap.add_argument("--sweep", action="store_true",
                    help="run the full valid (granularity x eviction x "
                         "placement) registry cross product and record "
                         "per-workload winners (overrides --policy)")
    ap.add_argument("--batch-size", type=int, default=None,
                    help="admit queries through process_batch in groups "
                         "of N (default: per-query admission)")
    ap.add_argument("--reuse", default="off", choices=("off", "on"),
                    help="semantic cache reuse: serve covered sub-regions "
                         "from resident chunks (default: off, seed parity)")
    ap.add_argument("--budget-frac", default=None,
                    help="comma-separated budget fractions "
                         f"(default: {BUDGET_FRACTIONS})")
    ap.add_argument("--out", default="BENCH_caching.json",
                    help="JSON summary path ('' disables)")
    args = ap.parse_args(argv)
    policies = (sweep_policy_names() if args.sweep
                else tuple(p for p in args.policy.split(",") if p))
    fracs = (tuple(float(f) for f in args.budget_frac.split(","))
             if args.budget_frac else BUDGET_FRACTIONS)
    results = run(policies=policies, budget_fractions=fracs,
                  batch_size=args.batch_size, reuse=args.reuse)
    if args.sweep:
        for wl, win in sweep_winners(results).items():
            print(f"sweep/{wl}/winner,0,{win['policy']}")
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(to_json_summary(results, policies, args.batch_size,
                                      args.reuse, sweep=args.sweep),
                      fh, indent=2, sort_keys=True)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
