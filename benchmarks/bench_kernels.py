"""Kernel micro-benchmarks (interpret mode on CPU — wall time is a
correctness-path cost, not TPU perf; the derived column reports the
work done: cell-pairs, attention FLOPs, pages touched)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.kernels.simjoin import ops as sj_ops


def _time(fn, *args, n=3, **kwargs):
    fn(*args, **kwargs)                        # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(print_rows: bool = True):
    rng = np.random.default_rng(0)
    rows = []
    a = jnp.asarray(rng.integers(0, 1000, (512, 3)), jnp.int32)
    us = _time(sj_ops.count_similar_pairs, a, a, 2, True)
    rows.append(("kernel/simjoin_512x512x3", us, 512 * 512))

    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    us = _time(flash_ops.flash_attention, q, k, k, causal=True)
    rows.append(("kernel/flash_256_gqa2", us,
                 2 * 256 * 256 * 4 * 64 * 2))

    kp = jnp.asarray(rng.normal(size=(64, 16, 4, 64)), jnp.bfloat16)
    qd = jnp.asarray(rng.normal(size=(4, 8, 64)), jnp.bfloat16)
    table = jnp.asarray(rng.permutation(64)[:4 * 4].reshape(4, 4), jnp.int32)
    lens = jnp.full((4,), 64, jnp.int32)
    us = _time(paged_decode_attention, qd, kp, kp, table, lens)
    rows.append(("kernel/paged_decode_4x4pages", us, 4 * 4 * 16))

    if print_rows:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    run()
