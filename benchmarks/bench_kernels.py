"""Kernel micro-benchmarks (interpret mode on CPU — wall time is a
correctness-path cost, not TPU perf; the derived column reports the
work done: cell-pairs, attention FLOPs, pages touched, block pairs).

The simjoin section records the kernel perf trajectory: dense vs
block-sparse (eps-pruned, ``PrefetchScalarGridSpec``) simjoin on
clustered inputs, plus the clustered GEO workload executed end-to-end
under prune=dense/block/bitmap/auto on both execution backends —
match-count parity, the ``block_pairs_evaluated / block_pairs_total``
pruning counters, the cell-exact bitmap stage's
``block_pairs_bitmap_killed``/``bitmap_build_s``, and
(``run_artifact_amortization``) cold-vs-warm rows for the
join-artifact cache: hit rates, the prep/dispatch wall-clock split, and
the warm prep speedup on a repeated workload.
``run(out_json=...)`` (the module main writes ``BENCH_kernels.json``)
serializes all of it so successive PRs can diff kernel performance.
"""
from __future__ import annotations

import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.kernels.simjoin import ops as sj_ops


def _time(fn, *args, n=3, **kwargs):
    fn(*args, **kwargs)                        # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def clustered_coords(rng, n: int, d: int = 3, n_clusters: int = 12,
                     domain: int = 100_000, spread: int = 40) -> np.ndarray:
    """Clustered integer coordinates (the geo/ptf regime: dense knots in
    a huge domain) — the distribution block pruning exploits."""
    centers = rng.integers(0, domain, (n_clusters, d))
    pick = rng.integers(0, n_clusters, n)
    return (centers[pick] + rng.integers(-spread, spread + 1,
                                         (n, d))).astype(np.int32)


def run_simjoin_pruning(print_rows: bool = True, n: int = 4096,
                        eps: int = 64):
    """Dense vs block-sparse simjoin self-join on clustered coords:
    timings, block-pair counters, match parity, and the jit trace tally
    (repeat dispatches must not retrace)."""
    rng = np.random.default_rng(7)
    a = clustered_coords(rng, n)
    aj = jnp.asarray(a)
    dense_us = _time(sj_ops.count_similar_pairs, aj, aj, eps, True)
    matches_dense = int(sj_ops.count_similar_pairs(aj, aj, eps, True))
    matches_pruned, total, evaluated = sj_ops.count_similar_pairs_pruned_np(
        a, a, eps, True)
    pruned_us = _time(sj_ops.count_similar_pairs_pruned_np, a, a, eps, True)
    trace_before = dict(sj_ops.TRACE_COUNTS)
    for _ in range(3):                         # repeat dispatch: no retrace
        sj_ops.count_similar_pairs_pruned_np(a, a, eps, True)
    retraced = dict(sj_ops.TRACE_COUNTS) != trace_before
    out = {
        "n": n, "eps": eps, "dense_us": dense_us, "pruned_us": pruned_us,
        "matches_dense": matches_dense, "matches_pruned": matches_pruned,
        "match_parity": matches_dense == matches_pruned,
        "block_pairs_total": total, "block_pairs_evaluated": evaluated,
        "evaluated_fraction": evaluated / max(total, 1),
        "retraced_on_repeat": retraced,
    }
    if print_rows:
        print(f"kernel/simjoin_dense_clustered_{n}x3,{dense_us:.0f},{total}")
        print(f"kernel/simjoin_pruned_clustered_{n}x3,{pruned_us:.0f},"
              f"{evaluated}")
        print(f"kernel/simjoin_pruned_fraction,0,"
              f"{out['evaluated_fraction']:.3f}")
    return out


def _geo_dataset():
    """The join-heavy clustered GEO dataset shared by the workload
    benches: fewer but denser files, chunks kept multi-block
    (``min_cells=8192``) — the regime where per-pair block pruning has
    room to act on top of the planner's chunk-level eps-box pruning (at
    bench_caching's CI scale most chunk pairs are a single 128-block,
    which nothing can prune further)."""
    import tempfile
    from benchmarks.common import N_NODES
    from repro.arrayio.catalog import FileReader, build_catalog
    from repro.arrayio.generator import make_geo_files
    from repro.core.workload import geo_workload
    files = make_geo_files(n_files=4, n_seeds=300, clones_per_seed=40,
                           seed=11)
    catalog, data = build_catalog(files, tempfile.mkdtemp(prefix="bk_geo_"),
                                  "csv", n_nodes=N_NODES)
    reader = FileReader(catalog, data)
    queries = geo_workload(catalog.domain, eps=500, range_frac=0.5)
    return catalog, reader, queries, N_NODES


def _geo_cluster(catalog, reader, n_nodes, backend, prune, budget_frac=8):
    from repro.core.cluster import RawArrayCluster
    budget = (sum(f.n_cells * f.cell_bytes for f in catalog.files)
              // budget_frac)
    return RawArrayCluster(
        catalog, reader, n_nodes, budget // n_nodes, policy="cost",
        min_cells=8192, execute_joins=True, backend=backend,
        join_backend="pallas", prune=prune)


def run_geo_workload_pruning(print_rows: bool = True):
    """The clustered GEO workload executed end-to-end (joins for real)
    under prune=dense/block/bitmap/auto on both the simulated backend
    and the jax device mesh: identical match counts, the per-run
    block-pair counters (including the cell-exact bitmap stage's
    killed-pair counter and build wall-clock), and the host-side
    prep/dispatch split from ``workload_summary`` — the numbers the
    ``prune="auto"`` default is judged by (auto must not do more grid
    work than the best of dense, block, and bitmap)."""
    from repro.core.cluster import workload_summary
    catalog, reader, queries, n_nodes = _geo_dataset()
    out = {}
    for backend, prune in (("simulated", "dense"), ("simulated", "block"),
                           ("simulated", "bitmap"), ("simulated", "auto"),
                           ("jax_mesh", "dense"), ("jax_mesh", "block"),
                           ("jax_mesh", "bitmap"), ("jax_mesh", "auto")):
        cluster = _geo_cluster(catalog, reader, n_nodes, backend, prune)
        t0 = time.perf_counter()
        executed = cluster.run_workload(queries)
        wall_us = (time.perf_counter() - t0) * 1e6
        summ = workload_summary(executed)
        label = f"{backend}_{prune}"
        out[label] = {
            "matches": int(sum(e.matches or 0 for e in executed)),
            "wall_us": wall_us,
            "block_pairs_total": summ.get("block_pairs_total", 0.0),
            "block_pairs_evaluated": summ.get("block_pairs_evaluated", 0.0),
            "prep_s": summ.get("prep_s", 0.0),
            "dispatch_s": summ.get("dispatch_s", 0.0),
        }
        if "block_pairs_bitmap_killed" in summ:
            out[label]["block_pairs_bitmap_killed"] = \
                summ["block_pairs_bitmap_killed"]
            out[label]["bitmap_build_s"] = summ.get("bitmap_build_s", 0.0)
        if print_rows:
            print(f"geo_join/{label},{wall_us:.0f},"
                  f"{out[label]['matches']}")
            print(f"geo_join/{label}/block_pairs,0,"
                  f"{out[label]['block_pairs_evaluated']:.0f}/"
                  f"{out[label]['block_pairs_total']:.0f}")
    base = out["simulated_dense"]["matches"]
    parity = all(v["matches"] == base for v in out.values()
                 if isinstance(v, dict))
    frac = (out["simulated_block"]["block_pairs_evaluated"]
            / max(out["simulated_block"]["block_pairs_total"], 1.0))
    bitmap_frac = (out["simulated_bitmap"]["block_pairs_evaluated"]
                   / max(out["simulated_bitmap"]["block_pairs_total"], 1.0))
    # The adaptive default's acceptance, compared in like units:
    # auto <= dense holds in the evaluated counter directly (a dense-
    # routed task counts its full grid, a block-routed one its live
    # pairs <= grid). Against prune=block the evaluated counters are
    # NOT commensurate — block under-reports its *padded* kernel cost
    # (the kernel sweeps padded_pair_len rows) while auto's dense-routed
    # tasks count their exact grid, which the routing rule only takes
    # when grid <= that pad — so auto <= block holds in padded units by
    # construction; the ratio below is informational, not a gate.
    auto_work = out["simulated_auto"]["block_pairs_evaluated"]
    dense_work = out["simulated_dense"]["block_pairs_evaluated"]
    block_work = out["simulated_block"]["block_pairs_evaluated"]
    bitmap_work = out["simulated_bitmap"]["block_pairs_evaluated"]
    if print_rows:
        print(f"geo_join/match_parity,0,{int(parity)}")
        print(f"geo_join/pruned_fraction,0,{frac:.3f}")
        print(f"geo_join/bitmap_pruned_fraction,0,{bitmap_frac:.3f}")
        print(f"geo_join/auto_work_vs_dense_vs_block_vs_bitmap,0,"
              f"{auto_work:.0f}/{dense_work:.0f}/{block_work:.0f}/"
              f"{bitmap_work:.0f}")
    out["match_parity"] = parity
    out["pruned_fraction"] = frac
    out["bitmap_pruned_fraction"] = bitmap_frac
    out["auto_work_le_dense"] = bool(auto_work <= dense_work)
    out["bitmap_work_le_block"] = bool(bitmap_work <= block_work)
    out["auto_vs_block_evaluated_ratio"] = auto_work / max(block_work, 1.0)
    return out


def run_artifact_amortization(print_rows: bool = True):
    """Cold-vs-warm artifact-cache rows (the ISSUE-5 amortization
    evidence): the clustered GEO workload repeated against a long-lived
    cluster whose cache holds the working set. The cold pass pays the
    full host prep (sort/boxes/pad/pair lists, all artifact misses); the
    warm pass replays the identical queries and must show hits, a
    collapsed per-query ``prep_s``, and bit-identical match counts — on
    the mesh backend additionally re-dispatching pinned device batches
    instead of re-staging them."""
    from repro.core.cluster import workload_summary
    catalog, reader, queries, n_nodes = _geo_dataset()
    out = {}
    for backend in ("simulated", "jax_mesh"):
        cluster = _geo_cluster(catalog, reader, n_nodes, backend, "auto",
                               budget_frac=1)     # working set resident
        passes = {}
        for tag in ("cold", "warm"):
            t0 = time.perf_counter()
            executed = cluster.run_workload(queries)
            wall_us = (time.perf_counter() - t0) * 1e6
            summ = workload_summary(executed)
            hits = summ.get("artifact_hits", 0.0)
            misses = summ.get("artifact_misses", 0.0)
            passes[tag] = {
                "matches": int(sum(e.matches or 0 for e in executed)),
                "wall_us": wall_us,
                "prep_s": summ.get("prep_s", 0.0),
                "dispatch_s": summ.get("dispatch_s", 0.0),
                "artifact_hits": hits,
                "artifact_misses": misses,
                "hit_rate": hits / max(hits + misses, 1.0),
            }
            if print_rows:
                print(f"geo_artifacts/{backend}_{tag},{wall_us:.0f},"
                      f"prep_us={passes[tag]['prep_s'] * 1e6:.0f}")
                print(f"geo_artifacts/{backend}_{tag}/hit_rate,0,"
                      f"{passes[tag]['hit_rate']:.3f}")
        passes["match_parity"] = (passes["warm"]["matches"]
                                  == passes["cold"]["matches"])
        passes["prep_speedup"] = (passes["cold"]["prep_s"]
                                  / max(passes["warm"]["prep_s"], 1e-9))
        if isinstance(getattr(cluster.backend, "device_stats", None), dict):
            passes["pinned_batch_hits"] = \
                cluster.backend.device_stats.get("pinned_batch_hits", 0.0)
        if print_rows:
            print(f"geo_artifacts/{backend}/prep_speedup,0,"
                  f"{passes['prep_speedup']:.1f}x")
        out[backend] = passes
    return out


def run(print_rows: bool = True, out_json: Optional[str] = None):
    """All kernel rows; ``out_json`` additionally writes the JSON perf
    trajectory (``BENCH_kernels.json`` from the module main)."""
    rng = np.random.default_rng(0)
    rows = []
    a = jnp.asarray(rng.integers(0, 1000, (512, 3)), jnp.int32)
    us = _time(sj_ops.count_similar_pairs, a, a, 2, True)
    rows.append(("kernel/simjoin_512x512x3", us, 512 * 512))

    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    us = _time(flash_ops.flash_attention, q, k, k, causal=True)
    rows.append(("kernel/flash_256_gqa2", us,
                 2 * 256 * 256 * 4 * 64 * 2))

    kp = jnp.asarray(rng.normal(size=(64, 16, 4, 64)), jnp.bfloat16)
    qd = jnp.asarray(rng.normal(size=(4, 8, 64)), jnp.bfloat16)
    table = jnp.asarray(rng.permutation(64)[:4 * 4].reshape(4, 4), jnp.int32)
    lens = jnp.full((4,), 64, jnp.int32)
    us = _time(paged_decode_attention, qd, kp, kp, table, lens)
    rows.append(("kernel/paged_decode_4x4pages", us, 4 * 4 * 16))

    if print_rows:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
    pruning = run_simjoin_pruning(print_rows=print_rows)
    geo = run_geo_workload_pruning(print_rows=print_rows)
    artifacts = run_artifact_amortization(print_rows=print_rows)
    if out_json:
        payload = {
            "benchmark": "bench_kernels",
            "platform": jax.default_backend(),
            "rows": [{"name": n_, "us_per_call": u, "derived": d}
                     for n_, u, d in rows],
            "simjoin_pruning": pruning,
            "geo_workload_pruning": geo,
            "artifact_amortization": artifacts,
        }
        with open(out_json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        if print_rows:
            print(f"wrote {out_json}")
    return rows


if __name__ == "__main__":
    run(out_json="BENCH_kernels.json")
