"""Figure 7: optimization time (query-driven chunking vs eviction+placement
plans) per query on the GEO workload — the coordinator's own cost, measured
for real (these algorithms execute, they are not simulated).

The ``best_split`` rows isolate the split-choice step inside chunking
(``RefineStats.split_eval_s`` wall-clock over ``split_candidates``
candidate faces): the part the vectorized ``EvolvingRTree._best_split``
accelerates, so planner-side speedups are visible in the trajectory."""
from __future__ import annotations

from benchmarks.common import build_geo, dataset_bytes, make_cluster
from repro.core.workload import geo_workload


def run(print_rows: bool = True):
    catalog, reader = build_geo("csv", seed=13)
    cluster = make_cluster(catalog, reader, "cost",
                           dataset_bytes(catalog) // 8)
    rows = []
    split_s = 0.0
    split_cands = 0
    for i, q in enumerate(geo_workload(catalog.domain), 1):
        ex = cluster.run_query(q)
        rep = ex.report
        rows.append((rep.opt_time_chunking_s, rep.opt_time_evict_place_s))
        split_s += rep.refine_stats.split_eval_s
        split_cands += rep.refine_stats.split_candidates
        if print_rows:
            print(f"fig7/q{i}/chunking,{rep.opt_time_chunking_s*1e6:.0f},"
                  f"{rep.refine_stats.splits}")
            print(f"fig7/q{i}/best_split,"
                  f"{rep.refine_stats.split_eval_s*1e6:.0f},"
                  f"{rep.refine_stats.split_candidates}")
            print(f"fig7/q{i}/evict_place,"
                  f"{rep.opt_time_evict_place_s*1e6:.0f},"
                  f"{rep.cached_chunks_after}")
    total_opt = sum(a + b for a, b in rows)
    if print_rows:
        print(f"fig7/total_best_split_s,{split_s*1e6:.0f},{split_cands}")
        print(f"fig7/total_opt_s,0,{total_opt:.4f}")
    return rows


if __name__ == "__main__":
    run()
