"""Figure 8: reduction in (modeled) similarity-join communication time with
cost-based cache placement (dynamic, Alg. 3) vs origin-pinned caching
(static), per workload."""
from __future__ import annotations

from benchmarks.common import (build_geo, build_ptf, cell_anchors,
                               dataset_bytes, make_cluster)
from repro.core.cluster import workload_summary
from repro.core.workload import geo_workload, ptf1_workload, ptf2_workload


def run(print_rows: bool = True):
    setups = {}
    c1, r1 = build_ptf("hdf5", seed=41)
    setups["ptf1"] = (c1, r1, ptf1_workload(c1.domain, n_queries=10,
                                            eps=300,
                                            anchors=cell_anchors(c1, r1)))
    c2, r2 = build_ptf("fits", seed=42)
    setups["ptf2"] = (c2, r2, ptf2_workload(c2.domain, n_queries=10,
                                            eps=300,
                                            anchors=cell_anchors(c2, r2)))
    c3, r3 = build_geo("csv", seed=43)
    setups["geo"] = (c3, r3, geo_workload(c3.domain, eps=500))
    out = {}
    for name, (catalog, reader, queries) in setups.items():
        budget = dataset_bytes(catalog) // 16
        nets = {}
        for mode in ("static", "dynamic"):
            cluster = make_cluster(catalog, reader, "cost", budget,
                                   placement=mode)
            executed = cluster.run_workload(queries)
            nets[mode] = workload_summary(executed)["net_time_s"]
            if print_rows:
                print(f"fig8/{name}/{mode},0,{nets[mode]:.4f}")
        ratio = nets["static"] / max(nets["dynamic"], 1e-9)
        out[name] = ratio
        if print_rows:
            print(f"fig8/{name}/static_over_dynamic,0,{ratio:.2f}")
    return out


if __name__ == "__main__":
    run()
