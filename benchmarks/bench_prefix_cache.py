"""Beyond-paper integration benchmark: KV prefix-cache hit rate and
prefill-tokens-saved, cost-based (paper-adapted) vs LRU, on a multi-turn
serving trace with hot system prompts and cold scans."""
from __future__ import annotations

import numpy as np

from repro.serve.kvcache import PagedKVCacheManager


def trace(rng, n=200, vocab=1000, sys_len=48, user_len=16):
    systems = [rng.integers(1, vocab, sys_len).tolist() for _ in range(3)]
    reqs = []
    for i in range(n):
        r = rng.random()
        if r < 0.7:                      # hot multi-turn traffic
            s = systems[int(rng.integers(0, len(systems)))]
            reqs.append(s + rng.integers(1, vocab, user_len).tolist())
        else:                            # cold long one-offs
            reqs.append(rng.integers(1, vocab, sys_len + user_len).tolist())
    return reqs


def run(print_rows: bool = True):
    rng = np.random.default_rng(7)
    reqs = trace(rng)
    out = {}
    for policy in ("lru", "cost"):
        m = PagedKVCacheManager(page_size=8, budget_bytes=40 * 128,
                                page_bytes=128, policy=policy)
        hits = pages = saved = total = 0
        for i, toks in enumerate(reqs):
            r = m.allocate(i, toks)
            hits += r.hit_pages
            pages += len(r.page_ids)
            saved += len(toks) - r.recompute_tokens
            total += len(toks)
        out[policy] = {"page_hit_rate": hits / pages,
                       "prefill_saved_frac": saved / total}
        if print_rows:
            print(f"prefix_cache/{policy}/page_hit_rate,0,"
                  f"{out[policy]['page_hit_rate']:.3f}")
            print(f"prefix_cache/{policy}/prefill_saved,0,"
                  f"{out[policy]['prefill_saved_frac']:.3f}")
    if print_rows:
        adv = out["cost"]["prefill_saved_frac"] - \
            out["lru"]["prefill_saved_frac"]
        print(f"prefix_cache/cost_advantage,0,{adv:.3f}")
    return out


if __name__ == "__main__":
    run()
