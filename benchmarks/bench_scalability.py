"""Figure 6: improvement over file_lru across a 100-query PTF stress
workload with a generous cache budget (favoring LRU, as in the paper) —
plus the execution-backend comparison: the same workload run under the
simulated cost model and under the jax device-mesh backend, reporting
REAL (measured, not modeled) transfer and join wall-clock per backend.

Run the backend section with virtual devices to exercise real
cross-device transfers on a CPU-only host:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.bench_scalability
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (N_NODES, build_ptf, cell_anchors,
                               dataset_bytes, make_cluster, timed)
from repro.core.cluster import RawArrayCluster, workload_summary
from repro.core.workload import ptf_stress_workload


def run(n_queries: int = 100, print_rows: bool = True):
    """Fig. 6: per-policy modeled improvement over the file_lru baseline."""
    catalog, reader = build_ptf("hdf5", n_files=16, cells=2500, seed=31)
    queries = ptf_stress_workload(catalog.domain, n_queries=n_queries,
                                  eps=300,
                                  anchors=cell_anchors(catalog, reader))
    budget = dataset_bytes(catalog) // 8          # generous: favors LRU
    times = {}
    for policy in ("file_lru", "chunk_lru", "cost"):
        cluster = make_cluster(catalog, reader, policy, budget)
        executed, us = timed(cluster.run_workload, queries)
        times[policy] = [e.time_total_s for e in executed]
        if print_rows:
            print(f"fig6/{policy},{us:.0f},"
                  f"{workload_summary(executed)['total_time_s']:.3f}")
    base = np.asarray(times["file_lru"])
    for policy in ("chunk_lru", "cost"):
        imp = base / np.maximum(np.asarray(times[policy]), 1e-9)
        if print_rows:
            print(f"fig6/median_improvement_{policy},0,"
                  f"{float(np.median(imp)):.2f}")
    return times


def run_backends(n_queries: int = 30, print_rows: bool = True):
    """Backend comparison: identical plans executed by the simulated and
    jax_mesh backends, each under the dense and block-sparse join grids.
    Rows report the modeled net/compute times, the block-pair pruning
    counters (``block_pairs_evaluated/total``), and for the mesh backend
    the MEASURED transfer + join kernel wall-clock and measured shipped
    device bytes."""
    from repro.backend import JaxMeshBackend
    catalog, reader = build_ptf("hdf5", n_files=12, cells=1500, seed=33)
    queries = ptf_stress_workload(catalog.domain, n_queries=n_queries,
                                  eps=300,
                                  anchors=cell_anchors(catalog, reader))
    budget = dataset_bytes(catalog) // 8
    out = {}
    matches = {}
    for backend, prune in (("simulated", "dense"), ("simulated", "block"),
                           ("jax_mesh", "dense"), ("jax_mesh", "block")):
        label = f"{backend}_{prune}"
        cluster = RawArrayCluster(
            catalog, reader, N_NODES, budget // N_NODES, policy="cost",
            min_cells=48, execute_joins=True, backend=backend,
            join_backend="pallas", prune=prune)
        executed, us = timed(cluster.run_workload, queries)
        summ = workload_summary(executed)
        out[label] = summ
        matches[label] = sum(e.matches or 0 for e in executed)
        if print_rows:
            print(f"backend/{label}/modeled_net_s,{us:.0f},"
                  f"{summ['net_time_s']:.4f}")
            print(f"backend/{label}/modeled_compute_s,0,"
                  f"{summ['compute_time_s']:.4f}")
            print(f"backend/{label}/block_pairs,0,"
                  f"{summ.get('block_pairs_evaluated', 0):.0f}/"
                  f"{summ.get('block_pairs_total', 0):.0f}")
        # make_backend degrades jax_mesh -> simulated when jax is absent;
        # only emit measured rows when the mesh backend actually ran.
        if isinstance(cluster.backend, JaxMeshBackend) and print_rows:
            print(f"backend/{label}/measured_net_s,0,"
                  f"{summ['measured_net_s']:.4f}")
            print(f"backend/{label}/measured_compute_s,0,"
                  f"{summ['measured_compute_s']:.4f}")
            print(f"backend/{label}/measured_ship_bytes,0,"
                  f"{summ['measured_ship_bytes']:.0f}")
            stats = cluster.backend.device_stats
            print(f"backend/{label}/committed_bytes_moved,0,"
                  f"{stats['committed_bytes_moved']:.0f}")
    if print_rows:
        parity = len(set(matches.values())) == 1
        print(f"backend/match_parity,0,{int(parity)}")
    return out


if __name__ == "__main__":
    run()
    run_backends()
