"""Figure 6: improvement over file_lru across a 100-query PTF stress
workload with a generous cache budget (favoring LRU, as in the paper)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (build_ptf, cell_anchors, dataset_bytes,
                               make_cluster, timed)
from repro.core.cluster import workload_summary
from repro.core.workload import ptf_stress_workload


def run(n_queries: int = 100, print_rows: bool = True):
    catalog, reader = build_ptf("hdf5", n_files=16, cells=2500, seed=31)
    queries = ptf_stress_workload(catalog.domain, n_queries=n_queries,
                                  eps=300,
                                  anchors=cell_anchors(catalog, reader))
    budget = dataset_bytes(catalog) // 8          # generous: favors LRU
    times = {}
    for policy in ("file_lru", "chunk_lru", "cost"):
        cluster = make_cluster(catalog, reader, policy, budget)
        executed, us = timed(cluster.run_workload, queries)
        times[policy] = [e.time_total_s for e in executed]
        if print_rows:
            print(f"fig6/{policy},{us:.0f},"
                  f"{workload_summary(executed)['total_time_s']:.3f}")
    base = np.asarray(times["file_lru"])
    for policy in ("chunk_lru", "cost"):
        imp = base / np.maximum(np.asarray(times[policy]), 1e-9)
        if print_rows:
            print(f"fig6/median_improvement_{policy},0,"
                  f"{float(np.median(imp)):.2f}")
    return times


if __name__ == "__main__":
    run()
