"""Figure 6: improvement over file_lru across a 100-query PTF stress
workload with a generous cache budget (favoring LRU, as in the paper) —
plus two executed-join sections:

  * the execution-backend comparison (``run_backends``): the same
    workload run under the simulated cost model and under the jax
    device-mesh backend, across the dense / block-sparse / auto join
    grids, reporting REAL (measured, not modeled) transfer and join
    wall-clock per backend;
  * the cross-query sharing scenario (``run_mqo``): a Zipf-skewed
    repeat workload run MQO-on/off x result-cache-on/off on both
    backends, recording the task-dedup and result-serving counters;
  * the failover scenario (``run_failover``): a skewed workload with
    the hottest node killed mid-run, replication off/on on both
    backends, recording post-kill tail latency and the
    replica-vs-raw recovery split;
  * the chaos scenario (``run_chaos``): the same replicated workload
    under seeded fault storms at increasing rates on both backends,
    recording completed/degraded fractions, latency inflation vs the
    fault-free baseline, and the reroute-vs-raw-fallback recovery
    split.

The sections emit structured row dicts and merge them into
``BENCH_caching.json`` (under the ``backends`` / ``mqo`` /
``failover`` / ``chaos`` keys, preserving whatever ``bench_caching``
wrote) so successive PRs can diff the perf trajectory.

Run the backend sections with virtual devices to exercise real
cross-device transfers on a CPU-only host:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.bench_scalability --n-queries 30 --seed 33
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from benchmarks.common import (N_NODES, build_ptf, cell_anchors,
                               dataset_bytes, make_cluster, timed)
from repro.core.cluster import RawArrayCluster, workload_summary
from repro.core.workload import ptf_stress_workload, zipf_workload


def run(n_queries: int = 100, print_rows: bool = True):
    """Fig. 6: per-policy modeled improvement over the file_lru baseline."""
    catalog, reader = build_ptf("hdf5", n_files=16, cells=2500, seed=31)
    queries = ptf_stress_workload(catalog.domain, n_queries=n_queries,
                                  eps=300,
                                  anchors=cell_anchors(catalog, reader))
    budget = dataset_bytes(catalog) // 8          # generous: favors LRU
    times = {}
    for policy in ("file_lru", "chunk_lru", "cost"):
        cluster = make_cluster(catalog, reader, policy, budget)
        executed, us = timed(cluster.run_workload, queries)
        times[policy] = [e.time_total_s for e in executed]
        if print_rows:
            print(f"fig6/{policy},{us:.0f},"
                  f"{workload_summary(executed)['total_time_s']:.3f}")
    base = np.asarray(times["file_lru"])
    for policy in ("chunk_lru", "cost"):
        imp = base / np.maximum(np.asarray(times[policy]), 1e-9)
        if print_rows:
            print(f"fig6/median_improvement_{policy},0,"
                  f"{float(np.median(imp)):.2f}")
    return times


def run_backends(n_queries: int = 30, print_rows: bool = True,
                 seed: int = 33) -> List[Dict]:
    """Backend comparison: identical plans executed by the simulated and
    jax_mesh backends, each under the dense, block-sparse, and
    adaptive-auto join grids. Returns one structured row dict per
    configuration carrying the modeled net/compute times, the block-pair
    pruning counters (``block_pairs_evaluated/total``), and for the mesh
    backend the MEASURED transfer + join kernel wall-clock and measured
    shipped device bytes; rows also print in the scaffold CSV shape."""
    from repro.backend import JaxMeshBackend
    catalog, reader = build_ptf("hdf5", n_files=12, cells=1500, seed=seed)
    queries = ptf_stress_workload(catalog.domain, n_queries=n_queries,
                                  eps=300,
                                  anchors=cell_anchors(catalog, reader))
    budget = dataset_bytes(catalog) // 8
    rows: List[Dict] = []
    matches = {}
    for backend in ("simulated", "jax_mesh"):
        for prune in ("dense", "block", "auto"):
            label = f"{backend}_{prune}"
            cluster = RawArrayCluster(
                catalog, reader, N_NODES, budget // N_NODES, policy="cost",
                min_cells=48, execute_joins=True, backend=backend,
                join_backend="pallas", prune=prune)
            executed, us = timed(cluster.run_workload, queries)
            summ = workload_summary(executed)
            mesh_ran = isinstance(cluster.backend, JaxMeshBackend)
            matches[label] = sum(e.matches or 0 for e in executed)
            row = {
                "backend": backend, "prune": prune, "seed": seed,
                "n_queries": n_queries, "bench_us": us,
                "modeled_net_s": summ["net_time_s"],
                "modeled_compute_s": summ["compute_time_s"],
                "block_pairs_total": summ.get("block_pairs_total", 0.0),
                "block_pairs_evaluated": summ.get("block_pairs_evaluated",
                                                  0.0),
                "matches": matches[label],
            }
            # make_backend degrades jax_mesh -> simulated when jax is
            # absent; only emit measured rows when the mesh actually ran.
            if mesh_ran:
                row.update({
                    "measured_net_s": summ["measured_net_s"],
                    "measured_compute_s": summ["measured_compute_s"],
                    "measured_ship_bytes": summ["measured_ship_bytes"],
                    "committed_bytes_moved":
                        cluster.backend.device_stats["committed_bytes_moved"],
                })
            rows.append(row)
            if print_rows:
                print(f"backend/{label}/modeled_net_s,{us:.0f},"
                      f"{summ['net_time_s']:.4f}")
                print(f"backend/{label}/modeled_compute_s,0,"
                      f"{summ['compute_time_s']:.4f}")
                print(f"backend/{label}/block_pairs,0,"
                      f"{summ.get('block_pairs_evaluated', 0):.0f}/"
                      f"{summ.get('block_pairs_total', 0):.0f}")
                if mesh_ran:
                    print(f"backend/{label}/measured_net_s,0,"
                          f"{summ['measured_net_s']:.4f}")
                    print(f"backend/{label}/measured_compute_s,0,"
                          f"{summ['measured_compute_s']:.4f}")
                    print(f"backend/{label}/measured_ship_bytes,0,"
                          f"{summ['measured_ship_bytes']:.0f}")
                    stats = cluster.backend.device_stats
                    print(f"backend/{label}/committed_bytes_moved,0,"
                          f"{stats['committed_bytes_moved']:.0f}")
    if print_rows:
        parity = len(set(matches.values())) == 1
        print(f"backend/match_parity,0,{int(parity)}")
    return rows


def run_mqo(n_queries: int = 60, n_templates: int = 12,
            batch_size: int = 8, print_rows: bool = True,
            seed: int = 41) -> List[Dict]:
    """Cross-query sharing scenario: a seeded Zipf(s=1.1) repeat workload
    executed MQO-on/off x result-cache-on/off on both backends. Each row
    records the dedup counters (``mqo_tasks_total/executed/shared_hits``),
    the result-tier counters (hits/misses + ``planner_invocations``), and
    the match total — identical across every configuration by
    construction (the parity row asserts it)."""
    from repro.backend import JaxMeshBackend  # noqa: F401 (mesh probe)
    catalog, reader = build_ptf("hdf5", n_files=12, cells=1500, seed=35)
    queries = zipf_workload(catalog.domain, n_queries=n_queries,
                            n_templates=n_templates, s=1.1, eps=300,
                            seed=seed,
                            anchors=cell_anchors(catalog, reader))
    budget = dataset_bytes(catalog) // 8
    rows: List[Dict] = []
    matches = {}
    for backend in ("simulated", "jax_mesh"):
        for mqo in ("off", "on"):
            for rc in ("off", "on"):
                label = f"{backend}_mqo_{mqo}_rc_{rc}"
                cluster = RawArrayCluster(
                    catalog, reader, N_NODES, budget // N_NODES,
                    policy="cost", min_cells=48, execute_joins=True,
                    backend=backend, join_backend="pallas", prune="auto",
                    mqo=mqo, result_cache=rc)
                executed, us = timed(cluster.run_workload, queries,
                                     batch_size=batch_size)
                summ = workload_summary(executed)
                coord = cluster.coordinator
                matches[label] = sum(e.matches or 0 for e in executed)
                rows.append({
                    "backend": backend, "mqo": mqo, "result_cache": rc,
                    "seed": seed, "n_queries": n_queries,
                    "n_templates": n_templates, "batch_size": batch_size,
                    "bench_us": us, "matches": matches[label],
                    "mqo_tasks_total": summ.get("mqo_tasks_total", 0.0),
                    "mqo_tasks_executed": summ.get("mqo_tasks_executed",
                                                   0.0),
                    "mqo_shared_hits": summ.get("mqo_shared_hits", 0.0),
                    "result_cache_hits":
                        coord.stats["result_cache_hits"],
                    "result_cache_misses":
                        coord.stats["result_cache_misses"],
                    "planner_invocations": coord.planner_invocations,
                    "compute_time_s": summ["compute_time_s"],
                    "measured_compute_s": summ.get("measured_compute_s",
                                                   0.0),
                })
                if print_rows:
                    print(f"mqo/{label}/tasks,{us:.0f},"
                          f"{summ.get('mqo_tasks_executed', 0):.0f}/"
                          f"{summ.get('mqo_tasks_total', 0):.0f}")
                    print(f"mqo/{label}/result_cache_hits,0,"
                          f"{coord.stats['result_cache_hits']}")
                    print(f"mqo/{label}/planner_invocations,0,"
                          f"{coord.planner_invocations}")
    if print_rows:
        parity = len(set(matches.values())) == 1
        print(f"mqo/match_parity,0,{int(parity)}")
    return rows


def run_failover(n_queries: int = 48, n_templates: int = 6,
                 batch_size: int = 6, print_rows: bool = True,
                 seed: int = 57) -> List[Dict]:
    """Failover scenario: a skewed Zipf(s=1.5) workload run
    replication-off/on on both backends; halfway through, the hottest
    node (most cached bytes) is killed. Each row records the post-kill
    tail latency (p95 of the modeled per-query time after the failure —
    the hot-node recovery penalty the paper's single-copy cache pays),
    the recovery source split (``recovery_bytes_from_replica`` vs
    ``recovery_bytes_from_raw``), the recovery wall-clock, and the match
    total — identical across every configuration and to an unfailed
    reference by construction (the parity row asserts it)."""
    catalog, reader = build_ptf("hdf5", n_files=12, cells=1500, seed=35)
    queries = zipf_workload(catalog.domain, n_queries=n_queries,
                            n_templates=n_templates, s=1.5, eps=300,
                            seed=seed,
                            anchors=cell_anchors(catalog, reader))
    # 1/4 (vs the other sections' 1/8): enough leftover headroom that
    # the hot tier can actually afford secondaries, while staying far
    # from fitting two full copies of the dataset.
    budget = dataset_bytes(catalog) // 4
    half = (len(queries) // (2 * batch_size)) * batch_size

    def build(backend: str, replication: str) -> RawArrayCluster:
        return RawArrayCluster(
            catalog, reader, N_NODES, budget // N_NODES, policy="cost",
            min_cells=48, execute_joins=True, backend=backend,
            join_backend="pallas", prune="auto", replication=replication,
            replica_k=2, replication_threshold=2.0)

    rows: List[Dict] = []
    matches = {}
    for backend in ("simulated", "jax_mesh"):
        ref = build(backend, "off").run_workload(queries,
                                                 batch_size=batch_size)
        matches[f"{backend}_ref"] = sum(e.matches or 0 for e in ref)
        for replication in ("off", "hot"):
            label = f"{backend}_repl_{replication}"
            cluster = build(backend, replication)
            executed, us = timed(cluster.run_workload, queries[:half],
                                 batch_size=batch_size)
            cache = cluster.coordinator.cache
            chunk_bytes, _ = cluster.coordinator.chunks.size_tables()
            by_node = cache.bytes_by_node(chunk_bytes)
            victim = max(by_node, key=lambda n: (by_node[n], -n))
            event = cluster.fail_node(victim)
            tail = cluster.run_workload(queries[half:],
                                        batch_size=batch_size)
            executed += tail
            summ = workload_summary(executed)
            matches[label] = sum(e.matches or 0 for e in executed)
            post_kill = sorted(e.time_total_s for e in tail)
            p95 = post_kill[min(len(post_kill) - 1,
                                int(0.95 * len(post_kill)))]
            rows.append({
                "backend": backend, "replication": replication,
                "seed": seed, "n_queries": n_queries,
                "n_templates": n_templates, "batch_size": batch_size,
                "bench_us": us, "matches": matches[label],
                "killed_node": victim,
                "failover_readmits": summ.get("failover_readmits", 0.0),
                "recovery_bytes_from_replica":
                    summ.get("recovery_bytes_from_replica", 0.0),
                "recovery_bytes_from_raw":
                    summ.get("recovery_bytes_from_raw", 0.0),
                "recovery_s": float(event["recovery_s"]),
                "replica_hits": summ.get("replica_hits", 0.0),
                "replicas_dropped": summ.get("replicas_dropped", 0.0),
                "post_kill_p95_s": p95,
                "post_kill_total_s": sum(post_kill),
            })
            if print_rows:
                print(f"failover/{label}/readmits,{us:.0f},"
                      f"{summ.get('failover_readmits', 0):.0f}")
                print(f"failover/{label}/recovery_bytes,0,"
                      f"{summ.get('recovery_bytes_from_replica', 0):.0f}/"
                      f"{summ.get('recovery_bytes_from_raw', 0):.0f}")
                print(f"failover/{label}/recovery_s,0,"
                      f"{event['recovery_s']:.5f}")
                print(f"failover/{label}/post_kill_p95_s,0,{p95:.4f}")
    if print_rows:
        parity = len(set(matches.values())) == 1
        print(f"failover/match_parity,0,{int(parity)}")
    return rows


def run_chaos(n_queries: int = 36, n_templates: int = 4,
              batch_size: int = 4, print_rows: bool = True,
              seed: int = 73,
              rates: Sequence[float] = (0.0, 0.05, 0.15)) -> List[Dict]:
    """Chaos scenario (ISSUE 10): a broad-field Zipf workload on a
    replicated cluster, swept across seeded fault-storm rates on both
    backends. ``rate == 0`` is the fault-free baseline row; each faulted
    row records the completed/degraded query fractions, the wall-clock
    and p95 modeled-latency inflation over the baseline, the
    recovery-source split (``transfer_reroutes`` — retries re-sourced
    from a surviving replica — vs ``raw_fallbacks`` — transfers that
    exhausted every replica and re-scanned raw files), the checksum
    catches, and the audit-violation count (zero by construction). The
    parity flag asserts every *completed* query's match count is
    bit-identical to the baseline — degraded-mode serving never leaks
    into completed answers."""
    from repro.faults import FaultInjector
    catalog, reader = build_ptf("hdf5", n_files=12, cells=1500, seed=35)
    # field_frac=0.5: query boxes span files on several nodes, so join
    # plans carry live transfer routes — the storm's ship.transfer
    # faults then exercise the re-route → raw-fallback ladder.
    queries = zipf_workload(catalog.domain, n_queries=n_queries,
                            n_templates=n_templates, s=1.5, eps=300,
                            field_frac=0.5, seed=seed)
    budget = dataset_bytes(catalog) // 4

    def build(backend: str, faults) -> RawArrayCluster:
        return RawArrayCluster(
            catalog, reader, N_NODES, budget // N_NODES, policy="cost",
            min_cells=48, execute_joins=True, backend=backend,
            join_backend="pallas", prune="auto", replication="hot",
            replica_k=2, replication_threshold=2.0, faults=faults)

    def p95(values: List[float]) -> float:
        xs = sorted(values)
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]

    rows: List[Dict] = []
    for backend in ("simulated", "jax_mesh"):
        # Warmup: the first run per backend pays one-time JIT/page-cache
        # costs that would otherwise inflate the fault-free baseline and
        # make the faulted rows look *faster* than rate 0.
        build(backend, "off").run_workload(queries, batch_size=batch_size)
        base_us = base_p95 = None
        ref_matches: List = []
        for rate in rates:
            faults = (FaultInjector.storm(rate, seed=seed)
                      if rate > 0 else "off")
            cluster = build(backend, faults)
            executed, us = timed(cluster.run_workload, queries,
                                 batch_size=batch_size)
            summ = workload_summary(executed)
            lat_p95 = p95([e.time_total_s for e in executed])
            if rate == 0:
                base_us, base_p95 = us, lat_p95
                ref_matches = [e.matches for e in executed]
            degraded = int(summ.get("degraded_queries", 0))
            completed_parity = all(
                e.matches == m
                for e, m in zip(executed, ref_matches)
                if e.degraded is None)
            label = f"{backend}_rate_{rate:g}"
            rows.append({
                "backend": backend, "fault_rate": rate, "seed": seed,
                "n_queries": n_queries, "n_templates": n_templates,
                "batch_size": batch_size, "bench_us": us,
                "completed_frac": (len(executed) - degraded)
                                  / len(executed),
                "degraded_frac": degraded / len(executed),
                "wall_inflation": us / base_us if base_us else 1.0,
                "p95_total_s": lat_p95,
                "p95_inflation": lat_p95 / base_p95 if base_p95 else 1.0,
                "faults_injected": summ.get("faults_injected", 0.0),
                "retries": summ.get("retries", 0.0),
                "transfer_reroutes": summ.get("transfer_reroutes", 0.0),
                "raw_fallbacks": summ.get("raw_fallbacks", 0.0),
                "checksum_mismatch": summ.get("checksum_mismatch", 0.0),
                "audit_violations": summ.get("audit_violations", 0.0),
                "completed_match_parity": completed_parity,
            })
            if print_rows:
                print(f"chaos/{label}/completed_frac,{us:.0f},"
                      f"{rows[-1]['completed_frac']:.3f}")
                print(f"chaos/{label}/injected_retries,0,"
                      f"{rows[-1]['faults_injected']:.0f}/"
                      f"{rows[-1]['retries']:.0f}")
                print(f"chaos/{label}/recovery_split,0,"
                      f"{rows[-1]['transfer_reroutes']:.0f}/"
                      f"{rows[-1]['raw_fallbacks']:.0f}")
                print(f"chaos/{label}/wall_inflation,0,"
                      f"{rows[-1]['wall_inflation']:.3f}")
                print(f"chaos/{label}/violations_parity,0,"
                      f"{rows[-1]['audit_violations']:.0f}/"
                      f"{int(completed_parity)}")
    return rows


#: Telemetry-only recording hooks outside ``src/repro/obs/`` whose
#: self-time counts as instrumentation cost in ``run_observability``.
_TELEMETRY_FUNCS = frozenset({
    "record_executed", "register_summary_counters", "_record",
    "_record_cache_health", "_mirror_device_stats"})


def run_observability(n_queries: int = 60, n_templates: int = 12,
                      batch_size: int = 8, repeats: int = 5,
                      print_rows: bool = True, seed: int = 41) -> List[Dict]:
    """Telemetry overhead scenario (ISSUE 8): the ``run_mqo`` mixed
    workload (reuse + MQO + result cache + hot replication, so every
    instrumented path fires) run ``telemetry="off"`` vs ``"on"`` on the
    simulated backend. The acceptance number, ``overhead_frac`` (<3%),
    is the *attributed* instrumentation share of a profiled
    telemetry-on run: the summed self-time of every function in
    ``src/repro/obs/`` plus the recording hooks (``record_executed``,
    cache-health/device-stat mirrors), over total run time — a
    deterministic measurement that cProfile's per-call cost biases
    *upward*, i.e. conservative. Differencing two wall-clocks cannot
    resolve a sub-1% effect on a shared machine (run-to-run jitter is
    an order of magnitude larger than the instrumentation), so the raw
    on-vs-off min-of-``repeats`` delta is recorded as the informational
    ``wall_delta_frac`` only. The row also carries the span volume and
    the off/on counter parity flag (every non-timing summary value must
    be bit-identical across modes)."""
    import cProfile
    import gc
    import pstats
    catalog, reader = build_ptf("hdf5", n_files=12, cells=1500, seed=35)
    queries = zipf_workload(catalog.domain, n_queries=n_queries,
                            n_templates=n_templates, s=1.1, eps=300,
                            seed=seed,
                            anchors=cell_anchors(catalog, reader))
    budget = dataset_bytes(catalog) // 8

    def once(telemetry: str, profile: bool = False):
        cluster = RawArrayCluster(
            catalog, reader, N_NODES, budget // N_NODES, policy="cost",
            min_cells=48, execute_joins=True, backend="simulated",
            join_backend="pallas", prune="auto", reuse="on", mqo="on",
            result_cache="on", replication="hot", telemetry=telemetry)
        # GC pauses (not the instrumentation) dominate run-to-run jitter
        # on this Python-geometry-heavy workload: collect up front and
        # keep the collector out of the timed region in both modes.
        gc.collect()
        gc.disable()
        try:
            prof = None
            if profile:
                prof = cProfile.Profile()
                prof.enable()
            executed, us = timed(cluster.run_workload, queries,
                                 batch_size=batch_size)
            if prof is not None:
                prof.disable()
        finally:
            gc.enable()
        return cluster, workload_summary(executed), us, prof

    best: Dict[str, float] = {}
    summaries: Dict[str, Dict] = {}
    spans = 0
    once("off"), once("on")           # warmup: JIT/page-cache/allocator
    # Interleave the repeats, alternating which mode goes first each
    # round (whichever runs second inherits a warmer allocator); keep
    # the minimum, the least-noise wall-clock estimate.
    for r in range(repeats):
        order = ("off", "on") if r % 2 == 0 else ("on", "off")
        for mode in order:
            cluster, summ, us, _ = once(mode)
            best[mode] = min(best.get(mode, float("inf")), us)
            summaries[mode] = summ
            if mode == "on":
                spans = len(cluster.telemetry.tracer.spans)
    wall_delta = (best["on"] - best["off"]) / best["off"]

    _, _, _, prof = once("on", profile=True)
    st = pstats.Stats(prof)
    telemetry_s = sum(
        tt for (fname, _lineno, func), (_cc, _nc, tt, _ct, _callers)
        in st.stats.items()
        if "/repro/obs/" in fname.replace("\\", "/")
        or func in _TELEMETRY_FUNCS)
    overhead = telemetry_s / st.total_tt if st.total_tt else 0.0

    parity = all(summaries["off"][k] == summaries["on"][k]
                 for k in summaries["off"] if not k.endswith("_s"))
    row = {
        "backend": "simulated", "seed": seed, "n_queries": n_queries,
        "n_templates": n_templates, "batch_size": batch_size,
        "repeats": repeats, "off_us": best["off"], "on_us": best["on"],
        "wall_delta_frac": wall_delta,
        "telemetry_self_us": telemetry_s * 1e6,
        "overhead_frac": overhead, "spans": spans,
        "counter_parity": parity, "pass_under_3pct": overhead < 0.03,
    }
    if print_rows:
        print(f"observability/simulated/off_us,{best['off']:.0f},0")
        print(f"observability/simulated/on_us,{best['on']:.0f},0")
        print(f"observability/simulated/wall_delta_pct,0,"
              f"{100.0 * wall_delta:.3f}")
        print(f"observability/simulated/overhead_pct,0,"
              f"{100.0 * overhead:.4f}")
        print(f"observability/simulated/spans,0,{spans}")
        print(f"observability/counter_parity,0,{int(parity)}")
    return [row]


def merge_json(path: str, backends_rows: Optional[List[Dict]] = None,
               mqo_rows: Optional[List[Dict]] = None,
               failover_rows: Optional[List[Dict]] = None,
               observability_rows: Optional[List[Dict]] = None,
               chaos_rows: Optional[List[Dict]] = None) -> None:
    """Read-modify-write ``BENCH_caching.json``: replace only the
    ``backends`` / ``mqo`` / ``failover`` / ``observability`` /
    ``chaos`` keys, preserving everything ``bench_caching`` (or a
    previous run) recorded."""
    data: Dict = {}
    if os.path.exists(path):
        with open(path) as fh:
            data = json.load(fh)
    if backends_rows is not None:
        data["backends"] = backends_rows
    if mqo_rows is not None:
        data["mqo"] = mqo_rows
    if failover_rows is not None:
        data["failover"] = failover_rows
    if observability_rows is not None:
        data["observability"] = observability_rows
    if chaos_rows is not None:
        data["chaos"] = chaos_rows
    with open(path, "w") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)
    print(f"wrote {path}")


def main(argv: Optional[Sequence[str]] = None) -> None:
    """CLI: Fig. 6 + both executed-join sections, JSON-merged."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n-queries", type=int, default=30,
                    help="workload length of the backend/MQO sections "
                         "(Fig. 6 keeps its 100-query stress workload)")
    ap.add_argument("--seed", type=int, default=33,
                    help="dataset/workload seed of the backend and MQO "
                         "sections")
    ap.add_argument("--skip-fig6", action="store_true",
                    help="run only the executed-join sections")
    ap.add_argument("--trace", action="store_true",
                    help="also measure telemetry on-vs-off overhead "
                         "(merged under the 'observability' key)")
    ap.add_argument("--out", default="BENCH_caching.json",
                    help="JSON path to merge backend/mqo rows into "
                         "('' disables)")
    args = ap.parse_args(argv)
    if not args.skip_fig6:
        run()
    backends_rows = run_backends(n_queries=args.n_queries, seed=args.seed)
    mqo_rows = run_mqo(n_queries=max(args.n_queries * 2, 20),
                       seed=args.seed + 8)
    failover_rows = run_failover(n_queries=max(args.n_queries, 24),
                                 seed=args.seed + 24)
    chaos_rows = run_chaos(n_queries=max(args.n_queries, 24),
                           seed=args.seed + 40)
    observability_rows = (run_observability(n_queries=max(args.n_queries, 24),
                                            seed=args.seed + 8)
                          if args.trace else None)
    if args.out:
        merge_json(args.out, backends_rows, mqo_rows, failover_rows,
                   observability_rows, chaos_rows)


if __name__ == "__main__":
    main()
