"""Shared helpers for the benchmark harness.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (the scaffold
contract): ``us_per_call`` is measured wall time of the named operation,
``derived`` carries the figure-specific quantity (speedup, bytes, hit-rate).
Datasets are scaled-down replicas of §4.1 (same skew/overlap structure);
``--scale full`` in the module mains regenerates the paper-sized inputs.
"""
from __future__ import annotations

import tempfile
import time
from typing import Dict, List, Tuple

from repro.arrayio.catalog import Catalog, FileReader, build_catalog
from repro.arrayio.generator import make_geo_files, make_ptf_files
from repro.core.cluster import CostModel, RawArrayCluster

N_NODES = 8          # the paper's 8 workers


def timed(fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, (time.perf_counter() - t0) * 1e6


def build_ptf(fmt: str, n_files: int = 20, cells: int = 4000,
              seed: int = 21, root: str | None = None):
    # skew 1.1: heavy pareto tail over file populations — the high-variance
    # regime (§4.1) where scanning a huge file for a few cells is the
    # pathology cost-based caching removes.
    files = make_ptf_files(n_files=n_files, cells_per_file_mean=cells,
                           skew=1.1, seed=seed)
    root = root or tempfile.mkdtemp(prefix=f"bench_ptf_{fmt}_")
    catalog, data = build_catalog(files, root, fmt, n_nodes=N_NODES)
    return catalog, FileReader(catalog, data)


def build_geo(fmt: str = "csv", n_files: int = 12, seed: int = 11,
              root: str | None = None):
    files = make_geo_files(n_files=n_files, n_seeds=400, clones_per_seed=20,
                           seed=seed)
    root = root or tempfile.mkdtemp(prefix="bench_geo_")
    catalog, data = build_catalog(files, root, fmt, n_nodes=N_NODES)
    return catalog, FileReader(catalog, data)


PAPER_DATASET_BYTES = 262e9      # PTF in HDF5 (§4.1)


def make_cluster(catalog, reader, policy: str, budget_total: int,
                 placement: str = "dynamic",
                 paper_scale: bool = True,
                 reuse: str = "off",
                 prune: str = "dense") -> RawArrayCluster:
    # min_cells keeps refined chunks well below one node's cache budget
    # (the paper's regime: GB-scale node budgets vs MB-scale chunks).
    #
    # paper_scale: the benchmark datasets are ~1000x smaller than §4.1's so
    # CI stays fast; scaling the modeled bandwidths by the same factor
    # reports times *as if* at paper scale (byte counts stay exact), so the
    # measured optimizer wall-clock compares meaningfully against scan time,
    # as in Fig. 7 vs Fig. 5.
    cm = CostModel()
    if paper_scale:
        scale = dataset_bytes(catalog) / PAPER_DATASET_BYTES
        cm = CostModel(
            disk_bw=cm.disk_bw * scale, net_bw=cm.net_bw * scale,
            cell_pairs_per_sec=cm.cell_pairs_per_sec,
            decode_rates={k: v * scale for k, v in cm.decode_rates.items()})
    # Planner-only benches keep the numpy executor (never called under
    # execute_joins=False); a non-default prune mode needs pallas.
    return RawArrayCluster(
        catalog, reader, N_NODES, budget_total // N_NODES, policy=policy,
        placement_mode=placement, min_cells=48, cost_model=cm,
        execute_joins=False, reuse=reuse,
        join_backend="numpy" if prune == "dense" else "pallas",
        prune=prune)


def dataset_bytes(catalog: Catalog) -> int:
    return sum(f.n_cells * f.cell_bytes for f in catalog.files)


def cell_anchors(catalog: Catalog, reader: FileReader, k: int = 16,
                 seed: int = 0):
    """Sample (dim0, dim1) anchor points from actual cells — exploration
    queries target where detections are, as the real PTF workload does."""
    import numpy as np
    rng = np.random.default_rng(seed)
    anchors = []
    for _ in range(k):
        f = catalog.files[int(rng.integers(0, len(catalog.files)))]
        coords, _ = reader.read(f.file_id)
        row = coords[int(rng.integers(0, coords.shape[0]))]
        anchors.append((int(row[0]), int(row[1])))
    return anchors
