"""Fill EXPERIMENTS.md placeholders from the final dry-run JSONLs."""
from __future__ import annotations

from benchmarks.roofline_report import load, perf_summary, table


def main() -> None:
    base = load("results_final_baseline.jsonl")
    opt = load("results_final_opt.jsonl")
    text = open("EXPERIMENTS.md").read()
    text = text.replace("<!-- ROOFLINE_TABLE -->", table(opt, "pod"))
    text = text.replace("<!-- PERF_SUMMARY_TABLE -->",
                        perf_summary(base, opt, "pod"))
    open("EXPERIMENTS.md", "w").write(text)
    print("EXPERIMENTS.md tables filled.")


if __name__ == "__main__":
    main()
