"""Render the dry-run JSONL (launch/dryrun.py --out) as the EXPERIMENTS.md
roofline table."""
from __future__ import annotations

import argparse
import json
from typing import List


def load(path: str) -> List[dict]:
    return [json.loads(l) for l in open(path)]


def table(rows: List[dict], mesh: str = "pod") -> str:
    out = ["| arch | shape | chips | compute s | memory s | collective s |"
           " serial s | dominant | useful | roofline | HBM/chip |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                       f"SKIP: {r['reason']} | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | FAILED | | | | "
                       f"| | | |")
            continue
        hbm = (r.get("hbm_per_chip") or 0) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r.get('serial_s', 0):.2e} "
            f"| {r['bottleneck']} "
            f"| {r['useful_ratio']:.1%} | {r['roofline_fraction']:.2%} "
            f"| {hbm:.1f} GB |")
    return "\n".join(out)


def perf_summary(baseline: List[dict], optimized: List[dict],
                 mesh: str = "pod") -> str:
    def key(r):
        return (r["arch"], r["shape"])

    def ceiling(r):
        return max(r["compute_s"], r["memory_s"], r["collective_s"],
                   r.get("serial_s", 0.0))

    base = {key(r): r for r in baseline
            if r.get("mesh") == mesh and r["status"] == "ok"}
    opt = {key(r): r for r in optimized
           if r.get("mesh") == mesh and r["status"] == "ok"}
    out = ["| arch | shape | baseline ceiling s | optimized ceiling s |"
           " speedup | roofline before → after |",
           "|---|---|---|---|---|---|"]
    for k in sorted(base):
        if k not in opt:
            continue
        b, o = base[k], opt[k]
        cb, co = ceiling(b), ceiling(o)
        out.append(
            f"| {k[0]} | {k[1]} | {cb:.2e} | {co:.2e} "
            f"| {cb / max(co, 1e-12):.2f}× "
            f"| {b['roofline_fraction']:.2%} → {o['roofline_fraction']:.2%} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="results_baseline.jsonl")
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()
    print(table(load(args.jsonl), args.mesh))


if __name__ == "__main__":
    main()
