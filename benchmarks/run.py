"""Benchmark harness entry point — one section per paper table/figure plus
the framework-integration benches. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig5 fig8  # subset
"""
from __future__ import annotations

import sys

from benchmarks import (bench_caching, bench_kernels, bench_opt_time,
                        bench_placement, bench_prefix_cache,
                        bench_scalability)

SECTIONS = {
    "fig5": ("Fig 5: caching strategies x budgets x formats",
             bench_caching.run),
    "fig6": ("Fig 6: 100-query improvement over file_lru",
             bench_scalability.run),
    "fig7": ("Fig 7: optimization time (chunking / evict+place)",
             bench_opt_time.run),
    "fig8": ("Fig 8: placement static vs dynamic", bench_placement.run),
    "kernels": ("Pallas kernels (interpret mode)", bench_kernels.run),
    "prefix": ("KV prefix cache: cost vs LRU", bench_prefix_cache.run),
}


def main() -> None:
    wanted = [a for a in sys.argv[1:] if a in SECTIONS] or list(SECTIONS)
    print("name,us_per_call,derived")
    for key in wanted:
        title, fn = SECTIONS[key]
        print(f"# {title}")
        fn()


if __name__ == "__main__":
    main()
