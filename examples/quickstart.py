"""Quickstart: the paper's distributed caching stack in ~60 lines.

Builds a small PTF-like raw-array dataset in three formats, runs an array
similarity-join workload through the three caching policies, and prints the
scan/transfer/latency comparison — the Figure-5 experiment at toy scale.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.arrayio import FileReader, build_catalog, make_ptf_files
from repro.core import RawArrayCluster, workload_summary
from repro.core.workload import ptf2_workload

N_NODES = 4


def main():
    print("generating a skewed PTF-like sparse array (12 files)...")
    files = make_ptf_files(n_files=12, cells_per_file_mean=2000, seed=5)
    catalog, data = build_catalog(files, tempfile.mkdtemp(), "fits",
                                  n_nodes=N_NODES)
    reader = FileReader(catalog, data)
    total = sum(f.n_cells * f.cell_bytes for f in catalog.files)
    budget = total // 4
    print(f"dataset: {sum(f.n_cells for f in catalog.files)} cells, "
          f"{total/1e6:.1f} MB in memory; cache budget {budget/1e6:.1f} MB\n")

    queries = ptf2_workload(catalog.domain, n_queries=10)
    print(f"{'policy':<12}{'total(s)':>10}{'scan(s)':>10}{'net(s)':>10}"
          f"{'files scanned':>15}{'matches q1':>12}")
    for policy in ("file_lru", "chunk_lru", "cost"):
        cluster = RawArrayCluster(catalog, reader, N_NODES,
                                  budget // N_NODES, policy=policy,
                                  min_cells=128)
        executed = cluster.run_workload(queries)
        s = workload_summary(executed)
        print(f"{policy:<12}{s['total_time_s']:>10.2f}"
              f"{s['scan_time_s']:>10.2f}{s['net_time_s']:>10.2f}"
              f"{s['files_scanned']:>15.0f}"
              f"{executed[0].matches:>12}")
    print("\ncost-based caching scans the fewest raw files and is fastest —"
          "\nthe paper's headline result (Fig. 5), reproduced at toy scale.")

    # The layered engine's new knobs: batched admission shares raw-file
    # scans across a query batch, and the Pallas-backed executor runs the
    # join kernel instead of the numpy loop (identical match counts).
    cluster = RawArrayCluster(catalog, reader, N_NODES, budget // N_NODES,
                              policy="cost", min_cells=128,
                              join_backend="pallas")
    executed = cluster.run_workload(queries, batch_size=5)
    s = workload_summary(executed)
    print(f"\ncost + batch_size=5 + pallas executor: "
          f"total {s['total_time_s']:.2f}s, "
          f"{s['files_scanned']:.0f} files scanned, "
          f"matches q1 = {executed[0].matches}")

    # Semantic cache reuse (reuse="on"): before a query's scan plan is
    # built, the coordinator rewrites it against the CoverageIndex of
    # resident chunk extents. Sub-regions covered by cached chunks are
    # served by slicing those chunks in place (shipping only the sliced
    # extent); only the residual region takes the catalog/scan path. Run
    # the same query twice: the first admission scans raw files cold, the
    # repeat is answered from covering cached chunks.
    cluster = RawArrayCluster(catalog, reader, N_NODES, budget // N_NODES,
                              policy="cost", min_cells=128, reuse="on")
    # Demo on the densest query of the workload (one that touches cells).
    q = queries[max(range(len(queries)),
                    key=lambda i: executed[i].report.queried_cells)]
    first = cluster.run_query(q)
    second = cluster.run_query(q)
    b1 = sum(first.report.scan_bytes_by_node.values())
    b2 = sum(second.report.scan_bytes_by_node.values())
    print(f"\nsemantic reuse, same query twice:"
          f"\n  run 1: scanned {b1} B, reuse_hits={first.report.reuse_hits}"
          f"\n  run 2: scanned {b2} B, reuse_hits={second.report.reuse_hits},"
          f" served {second.report.reuse_bytes_served} B from cache slices,"
          f" matches identical = {second.matches == first.matches}")
    # The example doubles as a smoke test: the repeat must hit the cache,
    # scan strictly fewer bytes, and return the same answer.
    assert second.report.reuse_hits > 0
    assert b2 < b1
    assert second.matches == first.matches
    assert cluster.coordinator.stats["reuse_hits"] > 0


if __name__ == "__main__":
    main()
