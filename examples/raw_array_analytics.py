"""Raw-array analytics: the paper's own workload end to end.

Walks one similarity-join query through the full pipeline — catalog pruning,
evolving R-tree refinement (Alg. 1), join planning, cost-based eviction
(Alg. 2), placement (Alg. 3) — printing each plan, then executes the join
with the TPU simjoin kernel (interpret mode) and cross-checks the numpy
executor.

  PYTHONPATH=src python examples/raw_array_analytics.py
"""
import tempfile

from repro.arrayio import FileReader, build_catalog, make_ptf_files
from repro.core import Box, RawArrayCluster, SimilarityJoinQuery
from repro.kernels.simjoin.ops import count_similar_pairs_np as kernel_join

N_NODES = 3


def main():
    files = make_ptf_files(n_files=6, cells_per_file_mean=1200, seed=9)
    catalog, data = build_catalog(files, tempfile.mkdtemp(), "hdf5",
                                  n_nodes=N_NODES)
    reader = FileReader(catalog, data)
    cluster = RawArrayCluster(catalog, reader, N_NODES, 256_000,
                              policy="cost", min_cells=96,
                              join_fn=kernel_join)
    dom = catalog.domain
    qbox = Box((dom.lo[0], dom.lo[1], dom.lo[2]),
               (dom.lo[0] + dom.side(0) // 4,
                dom.lo[1] + dom.side(1) // 4, dom.hi[2]))
    query = SimilarityJoinQuery(qbox, eps=2)

    print("query:", qbox.lo, "..", qbox.hi, "eps=2 (L1 similarity self-join)")
    for i in range(3):
        ex = cluster.run_query(query)
        rep = ex.report
        print(f"\n--- query pass {i+1} ---")
        print(f"files considered {rep.files_considered}, pruned "
              f"{rep.files_pruned}, scanned {len(rep.files_scanned)}")
        print(f"chunks queried {len(rep.queried_chunks)} "
              f"({rep.queried_cells} cells in range), "
              f"splits this query: {rep.refine_stats.splits}")
        if rep.join_plan:
            print(f"join plan: {len(rep.join_plan.pairs)} chunk pairs, "
                  f"{len(rep.join_plan.transfers)} chunk transfers")
        if rep.placement:
            print(f"placement: {len(rep.placement.locations)} chunks "
                  f"placed, co-location objective "
                  f"{rep.placement.colocated_pair_weight:.1f}")
        print(f"cache after: {rep.cached_chunks_after} chunks, "
              f"{rep.cached_bytes_after/1e3:.0f} KB; "
              f"matches={ex.matches}, modeled time {ex.time_total_s:.3f}s")
    print("\npass 2+ scan zero raw bytes — the distributed cache serves "
          "the query; the Pallas simjoin kernel executed every chunk pair.")


if __name__ == "__main__":
    main()
