"""Serving example: batched requests with the cost-based KV prefix cache.

Runs two traffic mixes through the engine — with and without a shared
system prompt — and shows the prefill tokens the paper-adapted page cache
saves.

  PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import get, reduced
from repro.models.model import init_params
from repro.serve.engine import Request, ServingEngine


def main():
    cfg = reduced(get("qwen1.5-0.5b"), d_model=64, n_periods=2, vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)

    system = rng.integers(1, cfg.vocab_size, 32).tolist()
    shared = [Request(i, system + rng.integers(1, cfg.vocab_size, 8).tolist(),
                      max_new_tokens=4) for i in range(6)]
    cold = [Request(100 + i,
                    rng.integers(1, cfg.vocab_size, 40).tolist(),
                    max_new_tokens=4) for i in range(6)]

    for name, reqs in (("shared system prompt", shared),
                       ("cold unrelated prompts", cold)):
        engine = ServingEngine(cfg, params, slots=3, max_len=96,
                               page_size=8, cache_budget_pages=32,
                               policy="cost")
        done = engine.run(list(reqs))
        st = engine.stats
        print(f"{name}: served {len(done)}; prompt tokens "
              f"{st.prompt_tokens}, prefill saved by cache "
              f"{st.prefill_saved} ({st.prefill_saved/st.prompt_tokens:.0%})")
        print("  sample generation:", done[0].generated)


if __name__ == "__main__":
    main()
