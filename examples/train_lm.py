"""End-to-end training driver: a ~100M-parameter llama3.2-style model
trained for a few hundred steps on CPU, with the raw-array cached data
pipeline, sharded params over the host mesh, AdamW, and async checkpoints.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import sys
import tempfile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-3b")
    args = ap.parse_args()

    from repro.launch.train import main as train_main
    # ~100M params: d_model=512, 14 periods of the llama pattern, vocab 32k
    # (vocab dominates: 2 x 32000 x 512 = 33M; blocks ~ 55M).
    out = train_main([
        "--arch", args.arch,
        "--scale", "reduced",
        "--d-model", "512",
        "--periods", "14",
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "128",
        "--vocab", "32000",
        "--lr", "3e-4",
        "--ckpt-dir", tempfile.mkdtemp(prefix="ckpt_"),
        "--ckpt-every", "50",
        "--log-every", "10",
    ])
    losses = out["losses"]
    print(f"\nfirst-10 mean loss {sum(losses[:10])/10:.3f} -> "
          f"last-10 mean loss {sum(losses[-10:])/10:.3f}")
    assert losses[-1] < losses[0], "training should reduce the loss"


if __name__ == "__main__":
    main()
