"""Raw array I/O: file formats, catalog, and synthetic dataset generators."""
from repro.arrayio.formats import (FORMATS, read_array_file,
                                   write_array_file)
from repro.arrayio.catalog import Catalog, FileReader, build_catalog
from repro.arrayio.generator import (GeneratedFile, make_geo_files,
                                     make_ptf_files)

__all__ = ["FORMATS", "read_array_file", "write_array_file", "Catalog",
           "FileReader", "build_catalog", "GeneratedFile", "make_geo_files",
           "make_ptf_files"]
