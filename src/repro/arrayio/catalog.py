"""System catalog: raw files, their home nodes, and bounding boxes (§2.1).

The catalog is the coordinator-resident metadata store: active servers, array
schema, file -> node assignment, and the per-file bounding box B(f_{i,j})
recorded at acquisition time (§3 Problem setting). It never holds cell data.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.arrayio import formats
from repro.arrayio.generator import GeneratedFile
from repro.core.chunk import FileMeta
from repro.core.geometry import Box, enclosing
from repro.faults.errors import ScanError


@dataclasses.dataclass
class Catalog:
    files: List[FileMeta]
    ndim: int
    nattr: int

    @property
    def domain(self) -> Box:
        box = enclosing(f.box for f in self.files)
        assert box is not None
        return box

    def files_overlapping(self, query: Box) -> List[FileMeta]:
        return [f for f in self.files if f.box.overlaps(query)]

    def by_id(self, file_id: int) -> FileMeta:
        return self.files[file_id]


def build_catalog(generated: Sequence[GeneratedFile],
                  root: str,
                  fmt: str,
                  n_nodes: int,
                  in_memory: bool = True) -> Tuple[Catalog, Dict[int, Tuple[np.ndarray, np.ndarray]]]:
    """Materialize generated files in ``fmt`` under ``root`` (round-robin over
    nodes, as in Figure 1) and build the catalog.

    Returns the catalog plus an id -> (coords, attrs) map. With
    ``in_memory=True`` the bytes are still written (sizes are real) but reads
    during query processing are served from memory while the *cost model*
    charges the disk scan — the algorithmic quantities stay exact without
    re-decoding gigabytes in CI. ``in_memory=False`` re-reads through the
    format decoder every time (used by the arrayio tests and the full-scale
    benchmark mode).
    """
    os.makedirs(root, exist_ok=True)
    metas: List[FileMeta] = []
    data: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    ndim = generated[0].coords.shape[1]
    nattr = generated[0].attrs.shape[1]
    for i, g in enumerate(generated):
        path = os.path.join(root, f"file_{i:05d}.{fmt}")
        nbytes = formats.write_array_file(path, fmt, g.coords, g.attrs)
        cell_bytes = ndim * 8 + nattr * 4
        metas.append(FileMeta(file_id=i, node=i % n_nodes, path=path, fmt=fmt,
                              box=g.box, n_cells=g.coords.shape[0],
                              file_bytes=nbytes, cell_bytes=cell_bytes))
        if in_memory:
            data[i] = (g.coords, g.attrs)
    catalog = Catalog(files=metas, ndim=ndim, nattr=nattr)
    return catalog, data


class FileReader:
    """Read cells of a raw file — from memory (cost-modeled) or from disk
    through the real format decoder."""

    def __init__(self, catalog: Catalog,
                 data: Optional[Dict[int, Tuple[np.ndarray, np.ndarray]]] = None):
        self.catalog = catalog
        self._data = data or {}

    def read(self, file_id: int) -> Tuple[np.ndarray, np.ndarray]:
        """Cells of ``file_id`` as ``(coords, attrs)``, memoized.

        A missing or truncated file (or a decoder failure) raises a
        typed :class:`~repro.faults.errors.ScanError` naming the file —
        the planner annotates it with the queried box and routes it
        through the retry/degrade path instead of letting a bare
        ``OSError``/numpy exception escape mid-scan."""
        if file_id in self._data:
            return self._data[file_id]
        meta = self.catalog.by_id(file_id)
        try:
            coords, attrs = formats.read_array_file(meta.path, meta.fmt)
        except ScanError:
            raise
        except (OSError, ValueError, EOFError, IndexError, KeyError) as e:
            raise ScanError(file_id, meta.path, cause=e) from e
        self._data[file_id] = (coords, attrs)
        return coords, attrs
