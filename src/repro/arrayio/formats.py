"""Raw array file formats: CSV, FITS-like, and HDF5-like binary tables.

The paper stores sparse arrays as *tables* of (dimension..., attribute...)
tuples in all three formats (§4.1 Data: "Each tuple in HDF5 and FITS contains
the dimensions and attributes for each cell"). CFITSIO/libhdf5 are not
available offline, so we implement byte-level table formats that preserve the
semantics that matter to the caching framework:

  * files are unorganized along array dimensions -> any cell access requires
    a full scan + decode;
  * the three formats differ only in their decode constant and on-disk size
    (§4.3 "The file format has only a constant factor impact").

``fits`` mimics FITS binary tables: 2880-byte header blocks of 80-char ASCII
cards, big-endian records. ``hdf5`` mimics an HDF5 packet table: magic +
little-endian records with a small binary superblock. ``csv`` is real CSV.
"""
from __future__ import annotations

import io
import os
import struct
from typing import Tuple

import numpy as np

FORMATS = ("csv", "fits", "hdf5")

_FITS_BLOCK = 2880
_HDF5_MAGIC = b"\x89HDF\r\n\x1a\n"
_HDF5_VERSION = 1


def _check(ndim: int, nattr: int) -> None:
    if ndim < 1 or nattr < 0:
        raise ValueError(f"bad table schema ndim={ndim} nattr={nattr}")


# ------------------------------------------------------------------- CSV ---

def write_csv(path: str, coords: np.ndarray, attrs: np.ndarray) -> int:
    n, d = coords.shape
    m = attrs.shape[1]
    with open(path, "w") as f:
        f.write(",".join([f"dim{k}" for k in range(d)] +
                         [f"attr{k}" for k in range(m)]) + "\n")
        lines = []
        for i in range(n):
            row = [str(int(x)) for x in coords[i]] + \
                  [f"{float(x):.6g}" for x in attrs[i]]
            lines.append(",".join(row))
        f.write("\n".join(lines) + ("\n" if lines else ""))
    return os.path.getsize(path)


def read_csv(path: str) -> Tuple[np.ndarray, np.ndarray]:
    with open(path) as f:
        header = f.readline().strip().split(",")
        d = sum(1 for h in header if h.startswith("dim"))
        m = len(header) - d
        _check(d, m)
        raw = np.loadtxt(f, delimiter=",", dtype=np.float64, ndmin=2)
    if raw.size == 0:
        return (np.zeros((0, d), np.int64), np.zeros((0, m), np.float32))
    return raw[:, :d].astype(np.int64), raw[:, d:].astype(np.float32)


# ------------------------------------------------------------------ FITS ---

def _fits_card(key: str, value) -> bytes:
    if isinstance(value, str):
        v = f"'{value}'"
    else:
        v = str(value)
    return f"{key:<8}= {v:>20} /".ljust(80).encode("ascii")


def write_fits(path: str, coords: np.ndarray, attrs: np.ndarray) -> int:
    n, d = coords.shape
    m = attrs.shape[1]
    _check(d, m)
    cards = [
        _fits_card("SIMPLE", "T"), _fits_card("BITPIX", 8),
        _fits_card("NAXIS", 2), _fits_card("NAXIS1", d * 8 + m * 4),
        _fits_card("NAXIS2", n), _fits_card("XTENSION", "BINTABLE"),
        _fits_card("TFIELDS", d + m), _fits_card("NDIM", d),
        _fits_card("NATTR", m),
        "END".ljust(80).encode("ascii"),
    ]
    header = b"".join(cards)
    header += b" " * (-len(header) % _FITS_BLOCK)
    body = io.BytesIO()
    # FITS binary tables are big-endian.
    body.write(coords.astype(">i8").tobytes())
    body.write(attrs.astype(">f4").tobytes())
    data = body.getvalue()
    data += b"\x00" * (-len(data) % _FITS_BLOCK)
    with open(path, "wb") as f:
        f.write(header)
        f.write(data)
    return os.path.getsize(path)


def read_fits(path: str) -> Tuple[np.ndarray, np.ndarray]:
    with open(path, "rb") as f:
        blob = f.read()
    header = {}
    off = 0
    while off < len(blob):
        card = blob[off:off + 80].decode("ascii", errors="replace")
        off += 80
        if card.startswith("END"):
            break
        if "=" in card:
            key, rest = card.split("=", 1)
            header[key.strip()] = rest.split("/")[0].strip().strip("'").strip()
    data_off = ((off + _FITS_BLOCK - 1) // _FITS_BLOCK) * _FITS_BLOCK
    n = int(header["NAXIS2"]);  d = int(header["NDIM"]);  m = int(header["NATTR"])
    _check(d, m)
    coords = np.frombuffer(blob, dtype=">i8", count=n * d,
                           offset=data_off).reshape(n, d)
    attrs = np.frombuffer(blob, dtype=">f4", count=n * m,
                          offset=data_off + n * d * 8).reshape(n, m)
    return coords.astype(np.int64), attrs.astype(np.float32)


# ------------------------------------------------------------------ HDF5 ---

def write_hdf5(path: str, coords: np.ndarray, attrs: np.ndarray) -> int:
    n, d = coords.shape
    m = attrs.shape[1]
    _check(d, m)
    with open(path, "wb") as f:
        f.write(_HDF5_MAGIC)
        f.write(struct.pack("<IIII", _HDF5_VERSION, n, d, m))
        # Interleaved rows, little-endian — a packet-table-style layout.
        row = np.zeros((n, d * 2 + m), dtype=np.float64)
        # Store int64 dims bit-exactly inside float64 slots via view.
        dims64 = coords.astype("<i8").view("<f8")
        row[:, :d] = dims64
        row[:, d:2 * d] = 0.0  # reserved (chunk index words in real HDF5)
        row[:, 2 * d:] = attrs.astype(np.float64)
        f.write(row.astype("<f8").tobytes())
    return os.path.getsize(path)


def read_hdf5(path: str) -> Tuple[np.ndarray, np.ndarray]:
    with open(path, "rb") as f:
        magic = f.read(8)
        if magic != _HDF5_MAGIC:
            raise ValueError(f"{path}: not an hdf5-like file")
        version, n, d, m = struct.unpack("<IIII", f.read(16))
        if version != _HDF5_VERSION:
            raise ValueError(f"unsupported version {version}")
        _check(d, m)
        row = np.frombuffer(f.read(n * (d * 2 + m) * 8),
                            dtype="<f8").reshape(n, d * 2 + m)
    coords = row[:, :d].copy().view("<i8").astype(np.int64)
    attrs = row[:, 2 * d:].astype(np.float32)
    return coords, attrs


# --------------------------------------------------------------- dispatch --

_WRITERS = {"csv": write_csv, "fits": write_fits, "hdf5": write_hdf5}
_READERS = {"csv": read_csv, "fits": read_fits, "hdf5": read_hdf5}

# Relative decode throughput (cells/sec scale) — the "constant factor impact"
# of the I/O library (§4.3). CSV tokenization is the slowest; binary formats
# decode faster, FITS pays byte-swapping on little-endian hosts.
DECODE_CELLS_PER_SEC = {"csv": 2.0e6, "fits": 12.0e6, "hdf5": 20.0e6}


def write_array_file(path: str, fmt: str, coords: np.ndarray,
                     attrs: np.ndarray) -> int:
    if fmt not in FORMATS:
        raise ValueError(f"unknown format {fmt!r}")
    return _WRITERS[fmt](path, coords, attrs)


def read_array_file(path: str, fmt: str) -> Tuple[np.ndarray, np.ndarray]:
    if fmt not in FORMATS:
        raise ValueError(f"unknown format {fmt!r}")
    return _READERS[fmt](path)
