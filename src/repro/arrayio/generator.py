"""Synthetic skewed sparse-array dataset generators (PTF-like and GEO-like).

PTF (§4.1): candidates<bright,mag>[ra, dec, time] — one file per night, each
night points the telescope at a handful of sky fields, so files cover large,
*overlapping* ranges while cells cluster heavily inside them (high variance:
sparse files with tens of cells, skewed files with millions).

GEO (§4.1): 2-D (long, lat) points of interest, each original point fanned
out with Gaussian offsets, split into equal files.

Sizes are fully parameterized so CI runs a small replica of the paper setup
and ``--scale full`` reproduces the published dimensions.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.geometry import Box


@dataclasses.dataclass
class GeneratedFile:
    coords: np.ndarray          # (n, d) int64
    attrs: np.ndarray           # (n, m) float32
    box: Box                    # acquisition-time bounding box (catalog input)


def _clip(coords: np.ndarray, domain: Box) -> np.ndarray:
    lo, hi = domain.as_arrays()
    return np.clip(coords, lo, hi)


def _dedup(coords: np.ndarray, attrs: np.ndarray):
    """Sparse arrays hold at most one cell per coordinate."""
    _, keep = np.unique(coords, axis=0, return_index=True)
    keep.sort()
    return coords[keep], attrs[keep]


def make_ptf_files(n_files: int = 16,
                   cells_per_file_mean: int = 4000,
                   skew: float = 1.4,
                   fields_per_night: int = 3,
                   n_canonical_fields: int = 8,
                   domain: Optional[Box] = None,
                   seed: int = 7) -> List[GeneratedFile]:
    """PTF-like [ra, dec, time] catalog, one file per 'night'.

    The survey re-images a fixed set of *canonical fields* night after night
    (transient detection compares detections at the same coordinates across
    time), so files overlap heavily in (ra, dec) while covering disjoint
    time ranges — the structure that makes cross-file similarity joins and
    shared-range caching matter."""
    rng = np.random.default_rng(seed)
    if domain is None:
        domain = Box((1, 1, 1), (100_000, 50_000, 153_064))
    ra_hi, dec_hi, t_hi = domain.hi
    # Telescope latitude bias: dec is skewed around one band of the sky.
    dec_center = int(0.55 * dec_hi)
    fields = [(int(rng.integers(1, ra_hi + 1)),
               int(np.clip(rng.normal(dec_center, dec_hi * 0.12), 1,
                           dec_hi)))
              for _ in range(n_canonical_fields)]
    night_len = max(2, t_hi // max(n_files, 1))
    # Zipf-ish heavy tail over file populations (paper: high variance).
    pops = (cells_per_file_mean *
            (rng.pareto(skew, size=n_files) + 0.05)).astype(np.int64)
    pops = np.maximum(pops, 16)
    files: List[GeneratedFile] = []
    for i in range(n_files):
        t0 = 1 + i * night_len
        t1 = min(t_hi, t0 + night_len - 1)
        parts = []
        for _ in range(fields_per_night):
            # A pointing: one canonical field (with jitter) this night.
            f_ra, f_dec = fields[int(rng.integers(0, n_canonical_fields))]
            c_ra = int(np.clip(f_ra + rng.normal(0, ra_hi * 0.002), 1,
                               ra_hi))
            c_dec = int(np.clip(f_dec + rng.normal(0, dec_hi * 0.002), 1,
                                dec_hi))
            n = max(4, int(pops[i] / fields_per_night))
            ra = rng.normal(c_ra, ra_hi * 0.01, n)
            dec = rng.normal(c_dec, dec_hi * 0.01, n)
            t = rng.integers(t0, t1 + 1, n)
            parts.append(np.stack([ra, dec, t], axis=1))
        coords = _clip(np.concatenate(parts).round().astype(np.int64), domain)
        attrs = rng.normal(18.0, 2.0, (coords.shape[0], 2)).astype(np.float32)
        coords, attrs = _dedup(coords, attrs)
        lo = coords.min(axis=0);  hi = coords.max(axis=0)
        files.append(GeneratedFile(coords, attrs,
                                   Box(tuple(map(int, lo)), tuple(map(int, hi)))))
    return files


def make_geo_files(n_files: int = 16,
                   n_seeds: int = 400,
                   clones_per_seed: int = 40,
                   sigma: float = 500.0,
                   domain: Optional[Box] = None,
                   seed: int = 11) -> List[GeneratedFile]:
    """GEO-like 2-D POI dataset: seed points + Gaussian clones (§4.1),
    split round-robin into equal files (paper: 8,000 equal files)."""
    rng = np.random.default_rng(seed)
    if domain is None:
        domain = Box((1, 1), (100_000, 50_000))
    lon_hi, lat_hi = domain.hi
    seeds = np.stack([rng.integers(1, lon_hi + 1, n_seeds),
                      rng.integers(1, lat_hi + 1, n_seeds)], axis=1)
    pts = seeds[:, None, :] + rng.normal(0, sigma,
                                         (n_seeds, clones_per_seed, 2))
    pts = pts.reshape(-1, 2)
    pts = np.concatenate([seeds, pts], axis=0)
    coords = _clip(pts.round().astype(np.int64), domain)
    rng.shuffle(coords, axis=0)
    per = len(coords) // n_files
    files: List[GeneratedFile] = []
    for i in range(n_files):
        c = coords[i * per:(i + 1) * per if i < n_files - 1 else None]
        a = rng.normal(0.0, 1.0, (c.shape[0], 1)).astype(np.float32)
        c, a = _dedup(c, a)
        lo = c.min(axis=0);  hi = c.max(axis=0)
        files.append(GeneratedFile(c, a,
                                   Box(tuple(map(int, lo)), tuple(map(int, hi)))))
    return files
