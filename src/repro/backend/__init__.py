"""Pluggable execution backends for the caching cluster.

The planning layers (``repro.core``) decide *what* to scan, ship, and
join; an :class:`~repro.backend.base.ExecutionBackend` decides *how*
those decisions are carried out:

  * ``"simulated"`` — the paper's §4.1 analytical cost model
    (:class:`~repro.backend.simulated.SimulatedBackend`): bytes and
    match counts are exact, wall-clock is modeled from calibrated
    bandwidths. This is the seed behavior, extracted out of
    ``repro.core.cluster``.
  * ``"jax_mesh"`` — real execution over a ``jax.sharding.Mesh``
    (:class:`~repro.backend.jax_mesh.JaxMeshBackend`): one mesh axis
    maps paper *nodes* onto jax devices, cached chunks are committed as
    device-resident buffers via ``jax.device_put``, the join plan's
    ship decisions become actual cross-device transfers with measured
    bytes and wall-clock, and each node's shape-bucketed simjoin batch
    dispatches to the Pallas kernel (compiled when the platform
    supports it, interpret-mode otherwise).

Both backends execute the *same* plans from the same coordinator, so
planned byte accounting is identical by construction — the mesh backend
adds measured quantities on top instead of replacing them.
"""
from repro.backend.artifacts import (ChunkView, JoinArtifactCache,
                                     subset_token, task_coords)
from repro.backend.base import (BACKENDS, DeviceBindingListener,
                                ExecutedQuery, ExecutionBackend,
                                workload_summary)
from repro.backend.cost_model import CostModel
from repro.backend.executors import (JOIN_BACKENDS, PRUNE_MODES, JoinTask,
                                     NumpyJoinExecutor, PallasJoinExecutor,
                                     PreparedBatch, count_similar_pairs_np,
                                     make_join_executor)
from repro.backend.simulated import MQO_MODES, SimulatedBackend
from repro.backend.jax_mesh import JaxMeshBackend, make_backend

__all__ = [
    "BACKENDS", "ChunkView", "CostModel", "DeviceBindingListener",
    "ExecutedQuery", "ExecutionBackend", "JOIN_BACKENDS",
    "JaxMeshBackend", "JoinArtifactCache", "JoinTask", "MQO_MODES",
    "NumpyJoinExecutor", "PRUNE_MODES", "PallasJoinExecutor",
    "PreparedBatch", "SimulatedBackend", "count_similar_pairs_np",
    "make_backend", "make_join_executor", "subset_token", "task_coords",
    "workload_summary",
]
