"""Join-artifact cache: memoized host-side join prep riding residency.

The block-sparse simjoin path (PR 4) pays a host-side preparation cost
per chunk pair on EVERY query: ``spatial_sort`` over each coordinate
set, per-block bounding boxes, sentinel padding to the kernel's
coordinate-major layout, and the eps-pruned block-pair list. The paper's
whole premise is that a workload of overlapping queries repeatedly
touches the *same* resident chunks — so those derived artifacts are
recomputed over identical inputs again and again.

:class:`JoinArtifactCache` memoizes them *alongside the resident data*:

  * per ``(chunk, queried-subset)`` — the spatially sorted coordinate
    array and its sentinel-padded coordinate-major forms (one per
    sentinel sign, i.e. per join side);
  * per ``(chunk_a, chunk_b, block, eps, same)`` — the pruned
    block-pair list from ``prune.build_block_pairs`` together with its
    dense-grid denominator;
  * per ``(chunk, queried-subset, block, scale)`` — the hierarchical
    occupancy bitmap sidecars from ``prune.build_bitmaps`` (the
    cell-exact prune stage's per-block quantized-cell sets);
  * per ``(chunk_a, chunk_b, block, eps, same)`` again under a distinct
    ``"bpair"`` tag — the bitmap-refined pair list from
    ``prune.refine_block_pairs`` with its killed-pair count, so warm
    queries skip the refinement pass along with the rest of prep.

Keying is *content-addressed through residency*: a chunk id's cell set
never changes while the id is live (splits retire the parent id and mint
new child ids), and the queried subset token — the query box intersected
with the chunk box, canonicalized to "full" when the chunk is entirely
covered — pins down exactly which coordinate slice the artifacts were
derived from. Invalidation therefore only has to follow the cache
life-cycle, and it does so through the same
:class:`repro.core.cache_state.CacheState` listener hooks the device
backends use: ``on_drop`` and ``on_split`` fire point-wise from
eviction and split-remap, and ``reconcile`` prunes artifacts of chunks
that left residency in a wholesale policy round — artifacts can never
outlive their chunk. Simulated node failures (PR 7,
``CacheCoordinator.fail_node``) need no extra wiring: a lost sole copy
leaves residency through the same hooks, and a chunk that survives via
a replica (or is re-admitted) is still resident, so its artifacts stay
valid — artifact keys name chunk content, never holder nodes.

The executors consult the cache through :class:`ChunkView` handles the
backends attach to join tasks (``repro.backend.simulated.
SimulatedBackend.gather_join_tasks``); plain ndarray tasks pass through
uncached, so executor-level tests and custom callers are unaffected.
``hits``/``misses`` counters are surfaced per query as
``ExecutedQuery.artifact_hits``/``artifact_misses``.
"""
from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Set,
                    Tuple)

import numpy as np

if TYPE_CHECKING:  # planning types only; no runtime import cycle
    from repro.core.cache_state import CacheState
    from repro.core.chunk import ChunkMeta
    from repro.core.geometry import Box

# (chunk id, queried-subset token): () = the full chunk, otherwise the
# (lo, hi) corners of the query box intersected with the chunk box.
ChunkKey = Tuple[int, tuple]


def subset_token(chunk_box: Optional["Box"],
                 query_box: Optional["Box"]) -> Optional[tuple]:
    """Canonical queried-subset token of a chunk under a query box:
    ``()`` when the query covers the whole chunk (every covering query
    shares one token), the intersected ``(lo, hi)`` corners under
    partial coverage (the intersection pins down the coordinate slice
    exactly — cells live inside the chunk box), and ``None`` for
    disjoint or unknown geometry (uncacheable/unshareable). This is the
    sharing signature both the :class:`JoinArtifactCache` keys and the
    backends' cross-query MQO dedup
    (``repro.backend.simulated.SimulatedBackend.execute_batch``) are
    built from."""
    if chunk_box is None or query_box is None:
        return None
    if query_box.contains_box(chunk_box):
        return ()
    inter = query_box.intersection(chunk_box)
    if inter is None:
        return None
    return (tuple(inter.lo), tuple(inter.hi))


@dataclasses.dataclass
class ChunkView:
    """One join-task side: a queried chunk's coordinate slice tagged
    with its artifact-cache key (``None`` disables caching — the slice
    came from a source the cache cannot address, e.g. a raw test array).
    Executors unwrap the coordinates with :func:`task_coords`."""

    key: Optional[ChunkKey]
    coords: np.ndarray


def task_coords(x) -> np.ndarray:
    """The raw (n, d) coordinate array of one join-task side, whether it
    is a bare ndarray (seed-shaped tasks) or a :class:`ChunkView`."""
    return x.coords if isinstance(x, ChunkView) else x


class _Artifacts:
    """Lazily-filled derived arrays of one (chunk, subset) slice."""

    __slots__ = ("sorted_coords", "padded", "bitmaps")

    def __init__(self):
        self.sorted_coords: Optional[np.ndarray] = None
        # sentinel value -> (d, N_padded) coordinate-major padded array
        # (one entry per join side: +sentinel for a, -sentinel for b).
        self.padded: Dict[int, np.ndarray] = {}
        # (block, scale) -> per-block hierarchical occupancy bitmaps
        # (list of (fine, coarse) quantized-cell arrays).
        self.bitmaps: Dict[Tuple[int, int], list] = {}


class JoinArtifactCache:
    """Memoized join-prep artifacts, invalidated in lockstep with cache
    residency (a ``CacheState`` listener alongside the device backends).

    ``max_subsets_per_chunk`` bounds memory for workloads whose query
    boxes slice one chunk many different ways: the least-recently-used
    subset's artifacts (and any pair lists referencing them) are
    evicted first.
    """

    def __init__(self, max_subsets_per_chunk: int = 8):
        self.max_subsets_per_chunk = max_subsets_per_chunk
        self._entries: Dict[ChunkKey, _Artifacts] = {}
        # ("pair", key_a, key_b, block, eps, same) -> (pairs, dense_total)
        # ("bpair", key_a, key_b, block, eps, same) -> (refined, killed)
        self._pairs: Dict[tuple, tuple] = {}
        # chunk id -> every key (entry or pair) derived from it, so one
        # residency event invalidates all dependent artifacts.
        self._by_chunk: Dict[int, Set[tuple]] = {}
        self._subset_order: Dict[int, List[tuple]] = {}
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    # ---------------------------------------------------------- keying

    def view(self, chunk_id: int, chunk_box: Optional["Box"],
             query_box: Optional["Box"], coords: np.ndarray) -> ChunkView:
        """Wrap a queried coordinate slice in its cache-addressable view.

        The subset token canonicalizes coverage: a chunk box entirely
        inside the query box yields the ``()`` (full-chunk) token — so
        every query that covers the whole chunk shares one artifact set
        — while partial coverage keys by the intersected box, which
        determines the slice content exactly (cells live inside the
        chunk box, so intersecting with the query box is equivalent to
        filtering by it). Unknown geometry degrades to an uncacheable
        passthrough view."""
        subset = subset_token(chunk_box, query_box)
        if subset is None:             # disjoint/unknown: nothing to cache
            return ChunkView(None, coords)
        return ChunkView((int(chunk_id), subset), coords)

    # --------------------------------------------------------- getters

    def _entry(self, view) -> Optional[_Artifacts]:
        """The artifact record behind a view (created on first touch,
        respecting the per-chunk subset cap), or ``None`` for
        uncacheable sides."""
        if not isinstance(view, ChunkView) or view.key is None:
            return None
        cid, subset = view.key
        order = self._subset_order.setdefault(cid, [])
        e = self._entries.get(view.key)
        if e is None:
            if subset not in order:
                order.append(subset)
                while len(order) > self.max_subsets_per_chunk:
                    self._evict_subset(cid, order.pop(0))
            e = self._entries[view.key] = _Artifacts()
            self._by_chunk.setdefault(cid, set()).add(view.key)
        elif order and order[-1] != subset:
            # LRU refresh: a hot subset touched on every query must not
            # be capacity-evicted ahead of cold one-off subsets.
            order.remove(subset)
            order.append(subset)
        return e

    def sorted_coords(self, view: ChunkView,
                      compute: Callable[[], np.ndarray]) -> np.ndarray:
        """The spatially sorted coordinate array of a view (memoized)."""
        e = self._entry(view)
        if e is None:
            return compute()
        if e.sorted_coords is None:
            self.misses += 1
            e.sorted_coords = compute()
        else:
            self.hits += 1
        return e.sorted_coords

    def padded(self, view: ChunkView, sentinel: int,
               compute: Callable[[], np.ndarray]) -> np.ndarray:
        """The sentinel-padded coordinate-major form of a view's sorted
        coordinates (memoized per sentinel sign, i.e. per join side)."""
        e = self._entry(view)
        if e is None:
            return compute()
        got = e.padded.get(sentinel)
        if got is None:
            self.misses += 1
            got = e.padded[sentinel] = compute()
        else:
            self.hits += 1
        return got

    def block_pairs(self, view_a, view_b, block: int, eps: int, same: bool,
                    compute: Callable[[], Tuple[np.ndarray, int]]
                    ) -> Tuple[np.ndarray, int]:
        """The ``(pairs, dense_total)`` pruned block-pair list for one
        task (memoized per chunk pair, block size, eps, and join mode;
        computed directly when either side is uncacheable)."""
        return self._pair_artifact("pair", view_a, view_b, block, eps,
                                   same, compute)

    def bitmaps(self, view: ChunkView, block: int, scale: int,
                compute: Callable[[], list]) -> list:
        """The hierarchical occupancy bitmaps of a view's sorted
        coordinates (memoized per block size and quantization scale) —
        the per-block ``(fine, coarse)`` quantized-cell sets the
        cell-exact prune stage intersects."""
        e = self._entry(view)
        if e is None:
            return compute()
        got = e.bitmaps.get((int(block), int(scale)))
        if got is None:
            self.misses += 1
            got = e.bitmaps[(int(block), int(scale))] = compute()
        else:
            self.hits += 1
        return got

    def refined_pairs(self, view_a, view_b, block: int, eps: int,
                      same: bool,
                      compute: Callable[[], Tuple[np.ndarray, int]]
                      ) -> Tuple[np.ndarray, int]:
        """The ``(refined_pairs, killed)`` bitmap-refined pair list for
        one task (memoized like :meth:`block_pairs` under a distinct
        ``"bpair"`` tag, so warm queries skip the bitmap intersection
        pass; invalidated through exactly the same residency hooks)."""
        return self._pair_artifact("bpair", view_a, view_b, block, eps,
                                   same, compute)

    def _pair_artifact(self, tag: str, view_a, view_b, block: int,
                       eps: int, same: bool,
                       compute: Callable[[], tuple]) -> tuple:
        """Shared memoization of per-chunk-pair artifacts (bbox pair
        lists and bitmap-refined pair lists), registered on both sides'
        chunks so either chunk's residency event invalidates them."""
        ka = view_a.key if isinstance(view_a, ChunkView) else None
        kb = view_b.key if isinstance(view_b, ChunkView) else None
        if ka is None or kb is None:
            return compute()
        key = (tag, ka, kb, int(block), int(eps), bool(same))
        got = self._pairs.get(key)
        if got is None:
            self.misses += 1
            got = self._pairs[key] = compute()
            self._by_chunk.setdefault(ka[0], set()).add(key)
            self._by_chunk.setdefault(kb[0], set()).add(key)
        else:
            self.hits += 1
        return got

    # --------------------------------------------------- introspection

    def chunk_ids(self) -> Set[int]:
        """Chunk ids that currently have at least one live artifact."""
        return {cid for cid in self._by_chunk if self.has_chunk(cid)}

    def has_chunk(self, chunk_id: int) -> bool:
        """Whether any artifact derived from this chunk is still live."""
        return any(
            (k in self._pairs) if k[0] in ("pair", "bpair")
            else (k in self._entries)
            for k in self._by_chunk.get(chunk_id, ()))

    def __len__(self) -> int:
        """Total live artifact records (entries + pair lists)."""
        return len(self._entries) + len(self._pairs)

    def audit(self) -> List[str]:
        """Internal-index consistency check (used by the cross-layer
        ``InvariantAuditor``): every live entry and pair list must be
        reachable from ``_by_chunk``, else a residency event could never
        invalidate it. Returns one description per violation."""
        out: List[str] = []
        indexed: Set[tuple] = set()
        for keys in self._by_chunk.values():
            indexed.update(keys)
        for key in self._entries:
            if key not in indexed:
                out.append(f"artifact entry {key!r} unreachable from "
                           f"the chunk index")
        for key in self._pairs:
            if key not in indexed:
                out.append(f"pair artifact {key!r} unreachable from "
                           f"the chunk index")
        return out

    # ---------------------------------------------------- invalidation

    def _evict_subset(self, cid: int, subset: tuple) -> None:
        """Capacity eviction of one (chunk, subset) slice: drop its
        entry and every pair list derived from it (pair keys registered
        on the partner chunk are popped here too; later discards are
        idempotent)."""
        old: tuple = (cid, subset)
        dropped = self._entries.pop(old, None) is not None
        keys = self._by_chunk.get(cid, set())
        stale = {k for k in keys
                 if k == old or (k[0] in ("pair", "bpair")
                                 and old in (k[1], k[2]))}
        for k in stale:
            keys.discard(k)
            if k[0] in ("pair", "bpair"):
                dropped += self._pairs.pop(k, None) is not None
        self.invalidations += int(dropped)

    def invalidate_chunk(self, chunk_id: int) -> int:
        """Drop every artifact derived from a chunk (entries and pair
        lists, both sides); returns the number of records dropped."""
        keys = self._by_chunk.pop(chunk_id, None)
        self._subset_order.pop(chunk_id, None)
        if not keys:
            return 0
        n = 0
        for k in keys:
            if k[0] in ("pair", "bpair"):
                n += self._pairs.pop(k, None) is not None
            else:
                n += self._entries.pop(k, None) is not None
        self.invalidations += n
        return n

    # ------------------------- residency listener (CacheState hooks) --

    def on_drop(self, chunk_id: int) -> None:
        """Eviction/placement dropped a chunk: its artifacts go with it."""
        self.invalidate_chunk(chunk_id)

    def on_split(self, parent_id: int, leaves: List["ChunkMeta"]) -> None:
        """A cached chunk split: the parent id is retired, so every
        artifact derived from it is stale by construction (children mint
        fresh ids and warm their own artifacts on next touch)."""
        self.invalidate_chunk(parent_id)

    def reconcile(self, state: "CacheState") -> None:
        """Post-round sync (the artifact twin of the device backends'
        reconcile): policy rounds reassign residency wholesale, so drop
        artifacts of every chunk no longer resident — the guarantee that
        artifacts never outlive their chunk."""
        for cid in list(self._by_chunk):
            if cid not in state.cached:
                self.invalidate_chunk(cid)
