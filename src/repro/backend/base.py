"""Backend protocol and shared execution dataclasses.

An :class:`ExecutionBackend` turns a query's planning report (produced
by :class:`repro.core.coordinator.CacheCoordinator`) into an
:class:`ExecutedQuery`. The planning layers never see the backend — the
same plans flow into either implementation, which is what makes the
byte-parity guarantees of ``tests/test_backend_parity.py`` hold by
construction.

:class:`DeviceBindingListener` is the hook surface a backend registers
on :class:`repro.core.cache_state.CacheState` so committed device
buffers move or free in lockstep with cache residency (the same
life-cycle events the CoverageIndex syncs on: point-wise drop and
split-remap, plus a post-round reconcile after eviction/placement
reassign the resident set wholesale).
"""
from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Dict, List, Optional, Protocol, Sequence,
                    runtime_checkable)

from repro.obs.metrics import MetricsRegistry

if TYPE_CHECKING:  # planning types only; no runtime import cycle
    from repro.core.cache_state import CacheState
    from repro.core.chunk import ChunkMeta
    from repro.core.coordinator import (CacheCoordinator, QueryReport,
                                        SimilarityJoinQuery)
    from repro.faults.retry import DegradedResult

BACKENDS = ("simulated", "jax_mesh")


@dataclasses.dataclass
class ExecutedQuery:
    """A query's planning report plus its modeled phase times, the
    (really computed) join match count, and — when the backend performs
    real work — measured wall-clock/byte counters.

    The ``time_*_s`` fields are always the §4.1 *modeled* phase times so
    cross-backend comparisons stay apples-to-apples; ``measured_*``
    fields are ``None`` under the simulated backend and real measured
    quantities under the mesh backend.
    """

    report: "QueryReport"
    time_scan_s: float
    time_net_s: float
    time_compute_s: float
    time_opt_s: float
    matches: Optional[int]
    backend: str = "simulated"
    measured_net_s: Optional[float] = None      # wall-clock of transfers
    measured_compute_s: Optional[float] = None  # wall-clock of join kernels
    measured_ship_bytes: Optional[int] = None   # device bytes moved
    # Block-sparsity counters of the Pallas join path (None when the
    # numpy executor ran or no join executed): *_total is the dense
    # kernel's grid size over this query's chunk pairs, *_evaluated the
    # block pairs actually dispatched (equal under prune="dense").
    block_pairs_total: Optional[int] = None
    block_pairs_evaluated: Optional[int] = None
    # Pallas host-prep amortization observables (None off the pallas
    # path): prep_s is the host-side sort/prune/pad/stack wall-clock,
    # dispatch_s the kernel-dispatch wall-clock, and the artifact
    # counters are this query's hit/miss deltas against the
    # JoinArtifactCache (repro.backend.artifacts) — a warm repeat query
    # over resident chunks shows hits > 0 and a collapsed prep_s.
    prep_s: Optional[float] = None
    dispatch_s: Optional[float] = None
    artifact_hits: Optional[int] = None
    artifact_misses: Optional[int] = None
    # Cell-exact bitmap-prune counters (None unless the bitmap stage ran
    # on at least one multi-block candidate this query — prune="bitmap",
    # or "auto" past its single-block fast path — so summaries of
    # workloads that never engage the feature are bit-identical to the
    # pre-bitmap ones): block pairs the hierarchical-bitmap intersection
    # proved dead after surviving the bbox prune, and the refinement
    # stage's wall-clock (also traced as a ``prep.bitmap`` span).
    block_pairs_bitmap_killed: Optional[int] = None
    bitmap_build_s: Optional[float] = None
    # Cross-batch multi-query-optimization counters (None when the
    # backend's ``mqo`` knob is off or the query was served from the
    # result cache): of this query's join tasks, how many there were
    # (*_total), how many it executed as the first subscriber of their
    # sharing signature (*_executed), and how many were served by a task
    # another query in the same admission batch already owned
    # (*_shared_hits). Per batch, sum(executed) == distinct tasks and
    # sum(shared_hits) == sum(total) - distinct tasks.
    mqo_tasks_total: Optional[int] = None
    mqo_tasks_executed: Optional[int] = None
    mqo_shared_hits: Optional[int] = None
    # Hot-chunk replication counters (None whenever the coordinator's
    # ``replication`` knob is off, so single-copy workload summaries are
    # bit-identical to the pre-replication ones): pair-sides this
    # query's join plan served in place from a secondary replica, and
    # secondaries the batch's replication round shed for budget
    # (attributed to the first query executed after the round).
    replica_hits: Optional[int] = None
    replicas_dropped: Optional[int] = None
    # Failure-recovery counters (None unless a ``fail_node`` event
    # occurred since the previous ExecutedQuery was built; attached to
    # the first query executed after the failure, whatever the
    # replication knob): chunks re-admitted, the bytes restored from
    # surviving replicas vs re-scanned from raw files, and the recovery
    # round's wall-clock.
    failover_readmits: Optional[int] = None
    recovery_bytes_from_replica: Optional[int] = None
    recovery_bytes_from_raw: Optional[int] = None
    recovery_s: Optional[float] = None
    # Transient-fault pipeline counters (None whenever the coordinator's
    # ``faults`` knob is off, so fault-free workload summaries are
    # bit-identical to the pre-fault ones): seeded injections attributed
    # to this query, retry activity (re-attempts, backoff seconds spent,
    # exhausted budgets), transfer re-routes to surviving replicas and
    # raw-file fallbacks, checksum mismatches caught on shipped
    # payloads, and whether this query degraded (0/1).
    faults_injected: Optional[int] = None
    retries: Optional[int] = None
    retry_backoff_s: Optional[float] = None
    retry_giveups: Optional[int] = None
    transfer_reroutes: Optional[int] = None
    raw_fallbacks: Optional[int] = None
    checksum_mismatch: Optional[int] = None
    degraded_queries: Optional[int] = None
    # Invariant-audit violations attributed to this query (None when no
    # auditor is armed; rides its own emission group so audit-only runs
    # don't drag the fault counters into summaries).
    audit_violations: Optional[int] = None
    # The typed degraded-mode payload (None = the query completed):
    # which sub-boxes were served / failed and which operations gave up.
    degraded: Optional["DegradedResult"] = None

    @property
    def time_total_s(self) -> float:
        """Modeled end-to-end latency: scan + net + compute + opt (§4.1)."""
        return (self.time_scan_s + self.time_net_s + self.time_compute_s
                + self.time_opt_s)


@runtime_checkable
class ExecutionBackend(Protocol):
    """How a planned query is carried out (simulated or for real)."""

    name: str

    def bind(self, coordinator: "CacheCoordinator") -> None:
        """Attach to a coordinator: the backend reads chunk coordinates
        and cache state through it (and, for device backends, registers
        its binding listener on ``coordinator.cache``)."""
        ...

    def execute(self, query: "SimilarityJoinQuery",
                report: "QueryReport") -> ExecutedQuery:
        """Execute one planned query; returns its ExecutedQuery."""
        ...

    def execute_batch(self, queries: Sequence["SimilarityJoinQuery"],
                      reports: Sequence["QueryReport"]
                      ) -> List[ExecutedQuery]:
        """Execute one admission batch's planned queries together. With
        the backend's ``mqo`` knob on, join tasks are deduplicated by
        sharing signature across the batch — each distinct task runs
        once and its match count fans out to every subscribing query;
        with ``mqo="off"`` this is exactly a per-query ``execute`` loop
        (``execute`` itself is a batch of one)."""
        ...


class DeviceBindingListener(Protocol):
    """Cache life-cycle hooks a residency-coupled component registers on
    ``CacheState.listeners`` — device buffers (``JaxMeshBackend``) and
    memoized join-prep artifacts (``JoinArtifactCache``) both move/free
    in lockstep with residency through this surface (mirror of the
    CoverageIndex sync points)."""

    def on_drop(self, chunk_id: int) -> None:
        """A chunk left the cache: free its committed buffer."""
        ...

    def on_split(self, parent_id: int, leaves: List["ChunkMeta"]) -> None:
        """A cached chunk split: retire the parent's buffer (children
        materialize at the next reconcile, at their inherited node)."""
        ...

    def reconcile(self, state: "CacheState") -> None:
        """Post-round sync: after eviction/placement reassign residency
        and locations wholesale, (re)materialize, move, or free buffers
        so every cached chunk's committed buffers match its replica set
        (``CacheState.replicas_of``)."""
        ...


# Summary counter names that only appear when their subsystem engaged
# (the registry's *emission groups*, reproducing the conditional keys of
# the legacy hand-rolled summary): counter name -> group. The leftover
# pending-event merge below uses the same map to surface post-workload
# events under the right group.
SUMMARY_GROUPS: Dict[str, str] = {
    "measured_net_s": "measured", "measured_compute_s": "measured",
    "measured_ship_bytes": "measured",
    "block_pairs_total": "block", "block_pairs_evaluated": "block",
    "prep_s": "prep", "dispatch_s": "prep",
    "artifact_hits": "prep", "artifact_misses": "prep",
    "block_pairs_bitmap_killed": "bitmap", "bitmap_build_s": "bitmap",
    "mqo_tasks_total": "mqo", "mqo_tasks_executed": "mqo",
    "mqo_shared_hits": "mqo",
    "replica_hits": "replica", "replicas_dropped": "replica",
    "failover_readmits": "failover",
    "recovery_bytes_from_replica": "failover",
    "recovery_bytes_from_raw": "failover", "recovery_s": "failover",
    "result_cache_hits": "result_cache",
    "faults_injected": "faults", "retries": "faults",
    "retry_backoff_s": "faults", "retry_giveups": "faults",
    "transfer_reroutes": "faults", "raw_fallbacks": "faults",
    "checksum_mismatch": "faults", "degraded_queries": "faults",
    "audit_violations": "audit",
}

# Ungrouped summary counters, in emission order (before any group).
_SUMMARY_BASE = (
    "total_time_s", "scan_time_s", "net_time_s", "compute_time_s",
    "opt_time_s", "bytes_scanned", "files_scanned", "queries",
    "reuse_hits", "reuse_bytes_served", "residual_bytes_scanned",
    "reuse_scan_skips",
)


def register_summary_counters(registry: MetricsRegistry) -> None:
    """Pre-register every workload-summary counter in emission order
    (idempotent — get-or-create), so ``as_summary`` key order matches
    the legacy summary regardless of which query records first."""
    for name in _SUMMARY_BASE:
        registry.counter(name)
    for name, group in SUMMARY_GROUPS.items():
        registry.counter(name, group=group)


def record_executed(registry: MetricsRegistry, e: ExecutedQuery) -> None:
    """Accumulate one ExecutedQuery into a registry's summary counters.

    Counters are named exactly as the ``workload_summary`` keys and
    accumulate in the same left-to-right order the legacy summary's
    ``sum()`` calls did, so registry totals equal summary values bit for
    bit. Optional subsystems accumulate unconditionally (``None`` -> 0)
    but their emission group is only marked present when the field is
    actually set — the registry equivalent of the legacy ``any(field is
    not None)`` guards."""
    register_summary_counters(registry)
    c = registry.counter
    c("total_time_s").inc(e.time_total_s)
    c("scan_time_s").inc(e.time_scan_s)
    c("net_time_s").inc(e.time_net_s)
    c("compute_time_s").inc(e.time_compute_s)
    c("opt_time_s").inc(e.time_opt_s)
    c("bytes_scanned").inc(sum(e.report.scan_bytes_by_node.values()))
    c("files_scanned").inc(len(e.report.files_scanned))
    c("queries").inc(1)
    c("reuse_hits").inc(e.report.reuse_hits)
    c("reuse_bytes_served").inc(e.report.reuse_bytes_served)
    c("residual_bytes_scanned").inc(e.report.residual_bytes_scanned)
    c("reuse_scan_skips").inc(e.report.reuse_scan_skips)
    c("measured_net_s").inc(e.measured_net_s or 0.0)
    c("measured_compute_s").inc(e.measured_compute_s or 0.0)
    c("measured_ship_bytes").inc(e.measured_ship_bytes or 0)
    c("block_pairs_total").inc(e.block_pairs_total or 0)
    c("block_pairs_evaluated").inc(e.block_pairs_evaluated or 0)
    c("prep_s").inc(e.prep_s or 0.0)
    c("dispatch_s").inc(e.dispatch_s or 0.0)
    c("artifact_hits").inc(e.artifact_hits or 0)
    c("artifact_misses").inc(e.artifact_misses or 0)
    c("block_pairs_bitmap_killed").inc(e.block_pairs_bitmap_killed or 0)
    c("bitmap_build_s").inc(e.bitmap_build_s or 0.0)
    c("mqo_tasks_total").inc(e.mqo_tasks_total or 0)
    c("mqo_tasks_executed").inc(e.mqo_tasks_executed or 0)
    c("mqo_shared_hits").inc(e.mqo_shared_hits or 0)
    c("replica_hits").inc(e.replica_hits or 0)
    c("replicas_dropped").inc(e.replicas_dropped or 0)
    c("failover_readmits").inc(e.failover_readmits or 0)
    c("recovery_bytes_from_replica").inc(e.recovery_bytes_from_replica or 0)
    c("recovery_bytes_from_raw").inc(e.recovery_bytes_from_raw or 0)
    c("recovery_s").inc(e.recovery_s or 0.0)
    c("faults_injected").inc(e.faults_injected or 0)
    c("retries").inc(e.retries or 0)
    c("retry_backoff_s").inc(e.retry_backoff_s or 0.0)
    c("retry_giveups").inc(e.retry_giveups or 0)
    c("transfer_reroutes").inc(e.transfer_reroutes or 0)
    c("raw_fallbacks").inc(e.raw_fallbacks or 0)
    c("checksum_mismatch").inc(e.checksum_mismatch or 0)
    c("degraded_queries").inc(e.degraded_queries or 0)
    c("audit_violations").inc(e.audit_violations or 0)
    hit = bool(getattr(e.report, "result_cache_hit", False))
    c("result_cache_hits").inc(1 if hit else 0)
    if e.measured_net_s is not None:
        registry.mark_group("measured")
    if e.block_pairs_total is not None:
        registry.mark_group("block")
    if e.prep_s is not None:
        registry.mark_group("prep")
    if e.block_pairs_bitmap_killed is not None:
        registry.mark_group("bitmap")
    if e.mqo_tasks_total is not None:
        registry.mark_group("mqo")
    if e.replica_hits is not None:
        registry.mark_group("replica")
    if e.failover_readmits is not None:
        registry.mark_group("failover")
    if e.faults_injected is not None:
        registry.mark_group("faults")
    if e.audit_violations is not None:
        registry.mark_group("audit")
    if hit:
        registry.mark_group("result_cache")


def workload_summary(executed: Sequence[ExecutedQuery],
                     coordinator: Optional["CacheCoordinator"] = None
                     ) -> Dict[str, float]:
    """Aggregate modeled times, scan volume, semantic-reuse counters, and
    (when present) measured backend quantities over an executed workload
    (the quantities the benchmarks report).

    Implemented on a fresh :class:`~repro.obs.metrics.MetricsRegistry`
    via :func:`record_executed` — every counter keeps its legacy name,
    value, and emission condition. Pass ``coordinator=`` to also surface
    any replication/failover events still pending in its event channel
    (events posted after the last executed query would otherwise never
    drain into an ``ExecutedQuery``); the channel is asserted empty
    afterwards."""
    reg = MetricsRegistry()
    register_summary_counters(reg)
    for e in executed:
        record_executed(reg, e)
    if coordinator is not None:
        for key, v in coordinator.events.drain().items():
            group = SUMMARY_GROUPS.get(key)
            reg.counter(key, group=group).inc(v)
            if group is not None:
                reg.mark_group(group)
        assert coordinator.events.empty(), \
            "pending-event channel not empty after workload_summary"
    return reg.as_summary()
