"""Backend protocol and shared execution dataclasses.

An :class:`ExecutionBackend` turns a query's planning report (produced
by :class:`repro.core.coordinator.CacheCoordinator`) into an
:class:`ExecutedQuery`. The planning layers never see the backend — the
same plans flow into either implementation, which is what makes the
byte-parity guarantees of ``tests/test_backend_parity.py`` hold by
construction.

:class:`DeviceBindingListener` is the hook surface a backend registers
on :class:`repro.core.cache_state.CacheState` so committed device
buffers move or free in lockstep with cache residency (the same
life-cycle events the CoverageIndex syncs on: point-wise drop and
split-remap, plus a post-round reconcile after eviction/placement
reassign the resident set wholesale).
"""
from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Dict, List, Optional, Protocol, Sequence,
                    runtime_checkable)

if TYPE_CHECKING:  # planning types only; no runtime import cycle
    from repro.core.cache_state import CacheState
    from repro.core.chunk import ChunkMeta
    from repro.core.coordinator import (CacheCoordinator, QueryReport,
                                        SimilarityJoinQuery)

BACKENDS = ("simulated", "jax_mesh")


@dataclasses.dataclass
class ExecutedQuery:
    """A query's planning report plus its modeled phase times, the
    (really computed) join match count, and — when the backend performs
    real work — measured wall-clock/byte counters.

    The ``time_*_s`` fields are always the §4.1 *modeled* phase times so
    cross-backend comparisons stay apples-to-apples; ``measured_*``
    fields are ``None`` under the simulated backend and real measured
    quantities under the mesh backend.
    """

    report: "QueryReport"
    time_scan_s: float
    time_net_s: float
    time_compute_s: float
    time_opt_s: float
    matches: Optional[int]
    backend: str = "simulated"
    measured_net_s: Optional[float] = None      # wall-clock of transfers
    measured_compute_s: Optional[float] = None  # wall-clock of join kernels
    measured_ship_bytes: Optional[int] = None   # device bytes moved
    # Block-sparsity counters of the Pallas join path (None when the
    # numpy executor ran or no join executed): *_total is the dense
    # kernel's grid size over this query's chunk pairs, *_evaluated the
    # block pairs actually dispatched (equal under prune="dense").
    block_pairs_total: Optional[int] = None
    block_pairs_evaluated: Optional[int] = None
    # Pallas host-prep amortization observables (None off the pallas
    # path): prep_s is the host-side sort/prune/pad/stack wall-clock,
    # dispatch_s the kernel-dispatch wall-clock, and the artifact
    # counters are this query's hit/miss deltas against the
    # JoinArtifactCache (repro.backend.artifacts) — a warm repeat query
    # over resident chunks shows hits > 0 and a collapsed prep_s.
    prep_s: Optional[float] = None
    dispatch_s: Optional[float] = None
    artifact_hits: Optional[int] = None
    artifact_misses: Optional[int] = None
    # Cross-batch multi-query-optimization counters (None when the
    # backend's ``mqo`` knob is off or the query was served from the
    # result cache): of this query's join tasks, how many there were
    # (*_total), how many it executed as the first subscriber of their
    # sharing signature (*_executed), and how many were served by a task
    # another query in the same admission batch already owned
    # (*_shared_hits). Per batch, sum(executed) == distinct tasks and
    # sum(shared_hits) == sum(total) - distinct tasks.
    mqo_tasks_total: Optional[int] = None
    mqo_tasks_executed: Optional[int] = None
    mqo_shared_hits: Optional[int] = None
    # Hot-chunk replication counters (None whenever the coordinator's
    # ``replication`` knob is off, so single-copy workload summaries are
    # bit-identical to the pre-replication ones): pair-sides this
    # query's join plan served in place from a secondary replica, and
    # secondaries the batch's replication round shed for budget
    # (attributed to the first query executed after the round).
    replica_hits: Optional[int] = None
    replicas_dropped: Optional[int] = None
    # Failure-recovery counters (None unless a ``fail_node`` event
    # occurred since the previous ExecutedQuery was built; attached to
    # the first query executed after the failure, whatever the
    # replication knob): chunks re-admitted, the bytes restored from
    # surviving replicas vs re-scanned from raw files, and the recovery
    # round's wall-clock.
    failover_readmits: Optional[int] = None
    recovery_bytes_from_replica: Optional[int] = None
    recovery_bytes_from_raw: Optional[int] = None
    recovery_s: Optional[float] = None

    @property
    def time_total_s(self) -> float:
        """Modeled end-to-end latency: scan + net + compute + opt (§4.1)."""
        return (self.time_scan_s + self.time_net_s + self.time_compute_s
                + self.time_opt_s)


@runtime_checkable
class ExecutionBackend(Protocol):
    """How a planned query is carried out (simulated or for real)."""

    name: str

    def bind(self, coordinator: "CacheCoordinator") -> None:
        """Attach to a coordinator: the backend reads chunk coordinates
        and cache state through it (and, for device backends, registers
        its binding listener on ``coordinator.cache``)."""
        ...

    def execute(self, query: "SimilarityJoinQuery",
                report: "QueryReport") -> ExecutedQuery:
        """Execute one planned query; returns its ExecutedQuery."""
        ...

    def execute_batch(self, queries: Sequence["SimilarityJoinQuery"],
                      reports: Sequence["QueryReport"]
                      ) -> List[ExecutedQuery]:
        """Execute one admission batch's planned queries together. With
        the backend's ``mqo`` knob on, join tasks are deduplicated by
        sharing signature across the batch — each distinct task runs
        once and its match count fans out to every subscribing query;
        with ``mqo="off"`` this is exactly a per-query ``execute`` loop
        (``execute`` itself is a batch of one)."""
        ...


class DeviceBindingListener(Protocol):
    """Cache life-cycle hooks a residency-coupled component registers on
    ``CacheState.listeners`` — device buffers (``JaxMeshBackend``) and
    memoized join-prep artifacts (``JoinArtifactCache``) both move/free
    in lockstep with residency through this surface (mirror of the
    CoverageIndex sync points)."""

    def on_drop(self, chunk_id: int) -> None:
        """A chunk left the cache: free its committed buffer."""
        ...

    def on_split(self, parent_id: int, leaves: List["ChunkMeta"]) -> None:
        """A cached chunk split: retire the parent's buffer (children
        materialize at the next reconcile, at their inherited node)."""
        ...

    def reconcile(self, state: "CacheState") -> None:
        """Post-round sync: after eviction/placement reassign residency
        and locations wholesale, (re)materialize, move, or free buffers
        so every cached chunk's committed buffers match its replica set
        (``CacheState.replicas_of``)."""
        ...


def workload_summary(executed: Sequence[ExecutedQuery]) -> Dict[str, float]:
    """Aggregate modeled times, scan volume, semantic-reuse counters, and
    (when present) measured backend quantities over an executed workload
    (the quantities the benchmarks report)."""
    out = {
        "total_time_s": sum(e.time_total_s for e in executed),
        "scan_time_s": sum(e.time_scan_s for e in executed),
        "net_time_s": sum(e.time_net_s for e in executed),
        "compute_time_s": sum(e.time_compute_s for e in executed),
        "opt_time_s": sum(e.time_opt_s for e in executed),
        "bytes_scanned": float(sum(sum(e.report.scan_bytes_by_node.values())
                                   for e in executed)),
        "files_scanned": float(sum(len(e.report.files_scanned)
                                   for e in executed)),
        "queries": float(len(executed)),
        "reuse_hits": float(sum(e.report.reuse_hits for e in executed)),
        "reuse_bytes_served": float(sum(e.report.reuse_bytes_served
                                        for e in executed)),
        "residual_bytes_scanned": float(sum(e.report.residual_bytes_scanned
                                            for e in executed)),
        "reuse_scan_skips": float(sum(e.report.reuse_scan_skips
                                      for e in executed)),
    }
    if any(e.measured_net_s is not None for e in executed):
        out["measured_net_s"] = sum(e.measured_net_s or 0.0
                                    for e in executed)
        out["measured_compute_s"] = sum(e.measured_compute_s or 0.0
                                        for e in executed)
        out["measured_ship_bytes"] = float(sum(e.measured_ship_bytes or 0
                                               for e in executed))
    if any(e.block_pairs_total is not None for e in executed):
        out["block_pairs_total"] = float(sum(e.block_pairs_total or 0
                                             for e in executed))
        out["block_pairs_evaluated"] = float(sum(e.block_pairs_evaluated or 0
                                                 for e in executed))
    if any(e.prep_s is not None for e in executed):
        out["prep_s"] = sum(e.prep_s or 0.0 for e in executed)
        out["dispatch_s"] = sum(e.dispatch_s or 0.0 for e in executed)
        out["artifact_hits"] = float(sum(e.artifact_hits or 0
                                         for e in executed))
        out["artifact_misses"] = float(sum(e.artifact_misses or 0
                                           for e in executed))
    if any(e.mqo_tasks_total is not None for e in executed):
        out["mqo_tasks_total"] = float(sum(e.mqo_tasks_total or 0
                                           for e in executed))
        out["mqo_tasks_executed"] = float(sum(e.mqo_tasks_executed or 0
                                              for e in executed))
        out["mqo_shared_hits"] = float(sum(e.mqo_shared_hits or 0
                                           for e in executed))
    if any(e.replica_hits is not None for e in executed):
        out["replica_hits"] = float(sum(e.replica_hits or 0
                                        for e in executed))
        out["replicas_dropped"] = float(sum(e.replicas_dropped or 0
                                            for e in executed))
    if any(e.failover_readmits is not None for e in executed):
        out["failover_readmits"] = float(sum(e.failover_readmits or 0
                                             for e in executed))
        out["recovery_bytes_from_replica"] = float(sum(
            e.recovery_bytes_from_replica or 0 for e in executed))
        out["recovery_bytes_from_raw"] = float(sum(
            e.recovery_bytes_from_raw or 0 for e in executed))
        out["recovery_s"] = sum(e.recovery_s or 0.0 for e in executed)
    if any(getattr(e.report, "result_cache_hit", False) for e in executed):
        out["result_cache_hits"] = float(sum(
            1 for e in executed
            if getattr(e.report, "result_cache_hit", False)))
    return out
