"""Calibrated §4.1 cost model (extracted from ``repro.core.cluster``).

Disk and network are replaced by calibrated bandwidths (the paper's
testbed was 8 workers + 1 coordinator on HDD + GbE); algorithmic
quantities — bytes scanned, bytes shipped, cache contents, chunk counts,
plan times — are exact, and wall-clock is modeled as

    t(query) = max_n scan_n + max_n net_n + max_n compute_n + t_opt

with scan_n = scanned_bytes/disk_bw + decoded_cells/decode_rate(fmt),
net_n = max(bytes_in, bytes_out)/net_bw (full-duplex switch), and
compute_n = assigned cell-pair work / pair_rate. Defaults follow §4.1:
125 MB/s disk and network. A TPU-pod profile (PCIe host link + ICI) is
provided for the framework-integration experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


def _default_decode_rates() -> Dict[str, float]:
    """The per-format decode throughputs from ``repro.arrayio.formats``
    (imported lazily — the backend package must not import the arrayio
    package at module level, which would close an import cycle through
    ``repro.core``)."""
    from repro.arrayio.formats import DECODE_CELLS_PER_SEC
    return dict(DECODE_CELLS_PER_SEC)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Calibrated per-node bandwidths/rates for the §4.1 time model."""

    disk_bw: float = 125e6               # B/s  (§4.1: HDD ~ GbE)
    net_bw: float = 125e6                # B/s per node link
    cell_pairs_per_sec: float = 5e8      # join predicate throughput per node
    decode_rates: Dict[str, float] = dataclasses.field(
        default_factory=_default_decode_rates)

    @staticmethod
    def tpu_pod_host() -> "CostModel":
        """v5e-host profile: raw shards on host NVMe/DRAM, PCIe to device,
        ICI between pods' hosts (DESIGN.md hardware-adaptation notes)."""
        return CostModel(disk_bw=3.2e9, net_bw=50e9, cell_pairs_per_sec=2e11,
                         decode_rates={k: v * 50 for k, v in
                                       _default_decode_rates().items()})
