"""Join executors: per-node grouped chunk-pair work -> match counts
(extracted from ``repro.core.cluster``).

  * ``"numpy"``  — the reference executor: one blocked numpy evaluation
    per chunk pair (``join_fn`` override preserved).
  * ``"pallas"`` — the batched executor: each node's chunk-pair work is
    grouped, coordinate sets are padded to the kernel's 128-wide BLOCK,
    and shape-bucketed pair batches are dispatched to the
    ``kernels/simjoin`` Pallas kernel (interpret-mode by default, so it
    runs on CPU CI and compiles on TPU).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

JOIN_BACKENDS = ("numpy", "pallas")

# One unit of join work: (node, a coords, b coords, self-join?).
JoinTask = Tuple[int, np.ndarray, np.ndarray, bool]


def count_similar_pairs_np(a: np.ndarray, b: np.ndarray, eps: int,
                           same: bool, block: int = 4096) -> int:
    """Unordered (x != y) L1-neighbor pairs between cell coordinate sets.
    Blocked to bound memory; numpy reference executor."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return 0
    total = 0
    for i0 in range(0, a.shape[0], block):
        ai = a[i0:i0 + block]
        for j0 in range(0, b.shape[0], block):
            bj = b[j0:j0 + block]
            dist = np.abs(ai[:, None, :].astype(np.int64)
                          - bj[None, :, :].astype(np.int64)).sum(axis=2)
            hit = dist <= eps
            if same:
                # Count each unordered pair once; drop identical cells.
                ii = i0 + np.arange(ai.shape[0])[:, None]
                jj = j0 + np.arange(bj.shape[0])[None, :]
                hit &= ii < jj
            total += int(hit.sum())
    return total


def bucket_by_shape(tasks: Sequence[JoinTask], block: int,
                    by_node: bool = False) -> Dict[tuple, List[int]]:
    """Group non-empty tasks into batched-dispatch buckets keyed by
    self-join mode and BLOCK-padded coordinate-set shapes (plus the
    executing node when ``by_node`` — the mesh backend pins each bucket
    to its node's device). Returns key -> task indices."""
    buckets: Dict[tuple, List[int]] = {}
    for i, (node, a, b, same) in enumerate(tasks):
        if a.shape[0] == 0 or b.shape[0] == 0:
            continue
        na = -(-a.shape[0] // block) * block
        nb = -(-b.shape[0] // block) * block
        key = (node, same, na, nb) if by_node else (same, na, nb)
        buckets.setdefault(key, []).append(i)
    return buckets


def stack_bucket(tasks: Sequence[JoinTask], idxs: Sequence[int], ops,
                 sentinel: int):
    """Pad one bucket's coordinate sets to BLOCK (±sentinel fill, via
    ``ops.pad_cm_np``) and stack them into the (k, d, N) batches the
    batched simjoin kernel consumes."""
    a_stack = np.stack([ops.pad_cm_np(tasks[i][1], sentinel)
                        for i in idxs])
    b_stack = np.stack([ops.pad_cm_np(tasks[i][2], -sentinel)
                        for i in idxs])
    return a_stack, b_stack


class NumpyJoinExecutor:
    """Reference executor: evaluate each pair independently."""

    def __init__(self, join_fn: Callable[..., int]):
        self.join_fn = join_fn

    def count_pairs(self, tasks: Sequence[JoinTask], eps: int) -> List[int]:
        """Per-task match counts via the (overridable) numpy predicate."""
        return [self.join_fn(a, b, eps, same) for _, a, b, same in tasks]


class PallasJoinExecutor:
    """Batched executor over the ``kernels/simjoin`` Pallas kernel.

    Each node's chunk-pair tasks are padded to BLOCK and bucketed by
    padded shape and self-join mode; each bucket is dispatched as ONE
    stacked kernel call — turning a pair-at-a-time python loop into a
    handful of jit'd launches per query. Buckets span nodes because the
    simulated backend executes every node's work on this one device; the
    mesh backend (``repro.backend.jax_mesh``) keys buckets by node and
    pins each bucket to that node's device."""

    def __init__(self, interpret: bool = True):
        # Imported lazily so the numpy backend never pulls in jax.
        from repro.kernels.simjoin import ops, simjoin
        self._ops = ops
        self._block = simjoin.BLOCK
        self._sentinel = simjoin.SENTINEL
        self.interpret = interpret

    def count_pairs(self, tasks: Sequence[JoinTask], eps: int) -> List[int]:
        """Per-task match counts via bucketed batched kernel dispatch."""
        import jax.numpy as jnp
        counts = [0] * len(tasks)
        for (same, _, _), idxs in bucket_by_shape(tasks,
                                                  self._block).items():
            a_stack, b_stack = stack_bucket(tasks, idxs, self._ops,
                                            self._sentinel)
            got = self._ops.count_similar_pairs_batch(
                jnp.asarray(a_stack), jnp.asarray(b_stack), int(eps),
                bool(same), interpret=self.interpret)
            for i, c in zip(idxs, np.asarray(got)):
                counts[i] = int(c)
        return counts


def make_join_executor(backend: str, join_fn: Callable[..., int],
                       interpret: bool = True):
    """Build a join executor for ``backend``, degrading pallas -> numpy
    with a warning when jax is unavailable."""
    if backend == "numpy":
        return NumpyJoinExecutor(join_fn)
    if backend == "pallas":
        try:
            return PallasJoinExecutor(interpret=interpret)
        except ImportError as e:                 # jax not available: degrade
            import warnings
            warnings.warn(f"join_backend='pallas' unavailable ({e}); "
                          f"falling back to the numpy executor",
                          RuntimeWarning, stacklevel=3)
            return NumpyJoinExecutor(join_fn)
    raise ValueError(f"unknown join backend {backend!r}; "
                     f"known: {JOIN_BACKENDS}")
