"""Join executors: per-node grouped chunk-pair work -> match counts
(extracted from ``repro.core.cluster``).

  * ``"numpy"``  — the reference executor: one blocked numpy evaluation
    per chunk pair (``join_fn`` override preserved).
  * ``"pallas"`` — the batched executor: each node's chunk-pair work is
    grouped, coordinate sets are padded to the kernel's 128-wide BLOCK,
    and shape-bucketed pair batches are dispatched to the
    ``kernels/simjoin`` Pallas kernel (interpret-mode by default, so it
    runs on CPU CI and compiles on TPU). Its ``prune`` knob selects the
    grid per task:

      - ``"dense"`` — every block pair evaluated (the parity reference);
      - ``"block"`` — coordinates spatially sorted, per-block bounding
        boxes pruned against ``eps`` on host, only live block pairs
        scalar-prefetched into the kernel (``kernels.simjoin.prune``);
      - ``"bitmap"`` — the bbox-pruned pair list is refined by a second,
        cell-exact stage: hierarchical occupancy bitmaps per block
        (``prune.build_bitmaps``) are intersected per surviving pair and
        pairs whose occupied cells are provably > eps apart are killed
        (``prune.refine_block_pairs``) before the list is padded and
        scalar-prefetched — strictly fewer live pairs, identical counts;
      - ``"auto"`` (default) — per task, the block-sparse grid only when
        it can win: a task goes dense when its *post-bitmap refined*
        pair list, padded, would be at least as long as the dense grid
        (``padded_pair_len(refined) >= dense blocks``), which covers
        single-block chunk pairs (a dense grid of 1 is below the minimum
        pad of 8) and near-dense pair lists in one rule — the block
        kernel's cost is proportional to the *padded* pair count, so
        this choice is never the slower one.

Host-side prep (sort, boxes, padding, pair lists) is memoized in a
:class:`repro.backend.artifacts.JoinArtifactCache` when tasks carry
:class:`~repro.backend.artifacts.ChunkView` handles (attached by the
backends, invalidated with cache residency); bare ndarray tasks prep
uncached, preserving the seed behavior for direct callers.

Every pallas dispatch records ``last_stats``: ``block_pairs_total`` (the
dense grid size) and ``block_pairs_evaluated`` (block pairs actually
dispatched), plus ``prep_s``/``dispatch_s`` wall-clock and the query's
``artifact_hits``/``artifact_misses`` — the backends surface all of them
per query on ``ExecutedQuery``. When the bitmap stage engages (bitmap or
auto mode with at least one multi-block candidate), stats additionally
carry ``block_pairs_bitmap_killed`` (pairs the cell-exact stage proved
dead) and ``bitmap_build_s`` (its wall-clock, also traced as a
``prep.bitmap`` span); the keys are absent otherwise, so summaries of
workloads that never engage the feature are unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.backend.artifacts import ChunkView, JoinArtifactCache, task_coords
from repro.obs.trace import NULL_TRACER

JOIN_BACKENDS = ("numpy", "pallas")
PRUNE_MODES = ("dense", "block", "bitmap", "auto")

# One unit of join work: (node, a side, b side, self-join?). Each side is
# a (n, d) coordinate array or a ChunkView wrapping one (see
# repro.backend.artifacts.task_coords).
JoinTask = Tuple[int, np.ndarray, np.ndarray, bool]


@dataclasses.dataclass
class PreparedBatch:
    """One shape bucket's stacked kernel inputs, ready for dispatch.

    ``arrays`` is ``(a_stack, b_stack)`` for the dense grid or
    ``(a_stack, b_stack, pairs_stack)`` for the block-sparse grid;
    ``fn_key`` identifies the jitted entry point + static shape bucket
    (the executor memoizes the bound callable per ``fn_key`` + eps).
    The mesh backend re-places ``arrays`` onto ``node``'s device before
    dispatch; ``node`` is ``None`` for node-agnostic bucketing."""

    node: Optional[int]
    same: bool
    idxs: List[int]
    arrays: Tuple[np.ndarray, ...]
    fn_key: tuple


def count_similar_pairs_np(a: np.ndarray, b: np.ndarray, eps: int,
                           same: bool, block: int = 4096) -> int:
    """Unordered (x != y) L1-neighbor pairs between cell coordinate sets.
    Blocked to bound memory; numpy reference executor."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return 0
    total = 0
    for i0 in range(0, a.shape[0], block):
        ai = a[i0:i0 + block]
        for j0 in range(0, b.shape[0], block):
            bj = b[j0:j0 + block]
            dist = np.abs(ai[:, None, :].astype(np.int64)
                          - bj[None, :, :].astype(np.int64)).sum(axis=2)
            hit = dist <= eps
            if same:
                # Count each unordered pair once; drop identical cells.
                ii = i0 + np.arange(ai.shape[0])[:, None]
                jj = j0 + np.arange(bj.shape[0])[None, :]
                hit &= ii < jj
            total += int(hit.sum())
    return total


def bucket_by_shape(tasks: Sequence[JoinTask], block: int,
                    by_node: bool = False) -> Dict[tuple, List[int]]:
    """Group non-empty tasks into batched-dispatch buckets keyed by
    self-join mode and BLOCK-padded coordinate-set shapes (plus the
    executing node when ``by_node`` — the mesh backend pins each bucket
    to its node's device). Returns key -> task indices."""
    buckets: Dict[tuple, List[int]] = {}
    for i, (node, a, b, same) in enumerate(tasks):
        ca, cb = task_coords(a), task_coords(b)
        if ca.shape[0] == 0 or cb.shape[0] == 0:
            continue
        na = -(-ca.shape[0] // block) * block
        nb = -(-cb.shape[0] // block) * block
        key = (node, same, na, nb) if by_node else (same, na, nb)
        buckets.setdefault(key, []).append(i)
    return buckets


class NumpyJoinExecutor:
    """Reference executor: evaluate each pair independently."""

    # The fault points a join round through this executor crosses, in
    # order (the backends arm them — with retry — before dispatching;
    # see ``SimulatedBackend._arm_join_points``). The numpy reference
    # has no host-prep stage to fail, only the dispatch itself.
    fault_points = ("dispatch.kernel",)

    def __init__(self, join_fn: Callable[..., int]):
        self.join_fn = join_fn
        # Block-pair counters are a kernel-path concept; the numpy
        # reference has none (ExecutedQuery fields stay None).
        self.last_stats: Optional[Dict[str, int]] = None
        # Backends swap in a live tracer at bind time (telemetry on).
        self.tracer = NULL_TRACER

    def count_pairs(self, tasks: Sequence[JoinTask], eps: int) -> List[int]:
        """Per-task match counts via the (overridable) numpy predicate
        (ChunkView task sides are unwrapped to the raw arrays the
        predicate expects)."""
        with self.tracer.span("dispatch", tasks=len(tasks)):
            return [self.join_fn(task_coords(a), task_coords(b), eps, same)
                    for _, a, b, same in tasks]


class PallasJoinExecutor:
    """Batched executor over the ``kernels/simjoin`` Pallas kernels.

    Each node's chunk-pair tasks are padded to BLOCK and bucketed by
    padded shape and self-join mode; each bucket is dispatched as ONE
    stacked kernel call — turning a pair-at-a-time python loop into a
    handful of jit'd launches per query. Buckets span nodes because the
    simulated backend executes every node's work on this one device; the
    mesh backend (``repro.backend.jax_mesh``) keys buckets by node and
    pins each bucket to that node's device.

    ``prune`` selects the grid: ``"dense"`` (full grid — parity
    reference and fallback), ``"block"`` (always block-sparse: per task
    the coordinates are spatially sorted, live block pairs computed on
    host, and the pair list — padded to a power-of-two bucket length so
    pair-count jitter does not retrace — scalar-prefetched into the
    kernel), ``"bitmap"`` (block-sparse with the cell-exact second
    stage: hierarchical occupancy bitmaps kill bbox-surviving pairs
    whose occupied cells are provably > eps apart before the list is
    padded), or ``"auto"`` (default: per task, block-sparse only when
    the padded *bitmap-refined* pair list is shorter than the dense
    grid — single-block chunk pairs and near-dense pair lists dispatch
    dense, so auto never pays prefetch overhead the prune cannot
    recoup).

    Host-side prep is memoized in :attr:`artifacts` (a
    :class:`~repro.backend.artifacts.JoinArtifactCache`) for tasks whose
    sides are :class:`~repro.backend.artifacts.ChunkView` handles — the
    backends attach them so repeated queries over resident chunks skip
    sort/box/pad/pair-list work entirely; ``last_stats`` records the
    per-query ``prep_s``/``dispatch_s`` split and artifact hit/miss
    deltas alongside the block-pair counters.

    The jitted batch callable for every ``(kernel, same, shapes, eps)``
    bucket key is memoized in ``_fn_cache``: repeated same-shape queries
    dispatch through the SAME bound callable, so jax's jit cache is hit
    without re-binding statics (``ops.TRACE_COUNTS`` proves no retrace).
    """

    # A join round through this executor has two failure-prone stages:
    # the host-side batch build and the kernel dispatch. The backends
    # arm these fault points (with retry) before the round — re-arming
    # without re-running is a faithful redo since both are pure.
    fault_points = ("prep.build", "dispatch.kernel")

    def __init__(self, interpret: bool = True, prune: str = "auto",
                 artifacts: Optional[JoinArtifactCache] = None):
        # Imported lazily so the numpy backend never pulls in jax.
        from repro.kernels.simjoin import ops, prune as prune_mod, simjoin
        if prune not in PRUNE_MODES:
            raise ValueError(f"unknown prune mode {prune!r}; "
                             f"known: {PRUNE_MODES}")
        self._ops = ops
        self._prune = prune_mod
        self._block = simjoin.BLOCK
        self._sentinel = simjoin.SENTINEL
        self.interpret = interpret
        self.prune = prune
        self.artifacts = (artifacts if artifacts is not None
                          else JoinArtifactCache())
        self._fn_cache: Dict[tuple, Callable] = {}
        self.last_stats: Optional[Dict[str, int]] = None
        # Backends swap in a live tracer at bind time (telemetry on);
        # prep/dispatch spans bracket the host-side batch build and the
        # kernel-dispatch loop respectively.
        self.tracer = NULL_TRACER

    # ------------------------------------------------ artifact-aware prep

    def _sorted_side(self, x) -> np.ndarray:
        """Spatially sorted coordinates of one task side (artifact-cached
        for ChunkViews, computed in place for raw arrays)."""
        if isinstance(x, ChunkView) and x.key is not None:
            return self.artifacts.sorted_coords(
                x, lambda: self._prune.spatial_sort(x.coords))
        return self._prune.spatial_sort(task_coords(x))

    def _padded_side(self, x, sentinel: int,
                     sorted_arr: Optional[np.ndarray] = None) -> np.ndarray:
        """Sentinel-padded coordinate-major form of one task side.
        ChunkViews cache the padded *sorted* artifact (shared across
        dense and block dispatch — the count is invariant under the
        reordering); raw arrays pad ``sorted_arr`` when the caller
        pre-sorted them (block path) and the original order otherwise
        (dense path, the seed behavior)."""
        if isinstance(x, ChunkView) and x.key is not None:
            return self.artifacts.padded(
                x, sentinel,
                lambda: self._ops.pad_cm_np(self._sorted_side(x), sentinel))
        base = sorted_arr if sorted_arr is not None else task_coords(x)
        return self._ops.pad_cm_np(base, sentinel)

    def _pair_list(self, xa, xb, a_s: np.ndarray, b_s: np.ndarray,
                   eps: int, same: bool) -> Tuple[np.ndarray, int]:
        """The task's ``(pairs, dense_total)`` block-pair list
        (artifact-cached per chunk pair + eps when both sides are
        cacheable views)."""
        return self.artifacts.block_pairs(
            xa, xb, self._block, int(eps), bool(same),
            lambda: self._prune.build_block_pairs(
                a_s, b_s, self._block, int(eps), bool(same)))

    def _bitmaps(self, x, sorted_arr: np.ndarray, scale: int) -> list:
        """One task side's hierarchical occupancy bitmaps
        (artifact-cached per block size + quantization scale for
        ChunkViews, computed in place for raw arrays)."""
        if isinstance(x, ChunkView) and x.key is not None:
            return self.artifacts.bitmaps(
                x, self._block, scale,
                lambda: self._prune.build_bitmaps(
                    sorted_arr, self._block, scale))
        return self._prune.build_bitmaps(sorted_arr, self._block, scale)

    def _refined_pairs(self, xa, xb, a_s: np.ndarray, b_s: np.ndarray,
                       pairs: np.ndarray, eps: int, same: bool
                       ) -> Tuple[np.ndarray, int]:
        """The task's ``(refined_pairs, killed)`` after the cell-exact
        bitmap stage (artifact-cached like the bbox pair list; warm
        queries skip both the bitmap build and the intersection pass)."""
        scale = self._prune.bitmap_scale(eps)

        def compute():
            bm_a = self._bitmaps(xa, a_s, scale)
            bm_b = bm_a if same else self._bitmaps(xb, b_s, scale)
            return self._prune.refine_block_pairs(
                pairs, bm_a, bm_b, int(eps), scale)

        return self.artifacts.refined_pairs(
            xa, xb, self._block, int(eps), bool(same), compute)

    # ------------------------------------------------- batch preparation

    def iter_batches(self, tasks: Sequence[JoinTask], eps: int,
                     by_node: bool = False
                     ) -> Tuple[List[PreparedBatch], Dict[str, int]]:
        """Bucket and stack the tasks' kernel inputs (dense, block, or
        per-task auto-selected per the ``prune`` knob); returns
        ``(batches, stats)`` where stats carries the query's
        ``block_pairs_total`` / ``_evaluated``, the host-side ``prep_s``
        wall-clock, and the artifact-cache hit/miss deltas."""
        t0 = time.perf_counter()
        h0, m0 = self.artifacts.hits, self.artifacts.misses
        with self.tracer.span("prep", tasks=len(tasks)):
            if self.prune == "dense":
                batches, stats = self._batches_dense(tasks, by_node)
            else:
                batches, stats = self._batches_block(
                    tasks, eps, by_node, auto=self.prune == "auto",
                    bitmap=self.prune in ("bitmap", "auto"))
        stats["prep_s"] = time.perf_counter() - t0
        stats["artifact_hits"] = self.artifacts.hits - h0
        stats["artifact_misses"] = self.artifacts.misses - m0
        return batches, stats

    def _stack_dense(self, tasks: Sequence[JoinTask], idxs: Sequence[int]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Pad one dense bucket's coordinate sets to BLOCK (±sentinel
        fill) and stack them into the (k, d, N) batches the batched
        kernel consumes."""
        a_stack = np.stack([self._padded_side(tasks[i][1], self._sentinel)
                            for i in idxs])
        b_stack = np.stack([self._padded_side(tasks[i][2], -self._sentinel)
                            for i in idxs])
        return a_stack, b_stack

    def _batches_dense(self, tasks: Sequence[JoinTask], by_node: bool
                       ) -> Tuple[List[PreparedBatch], Dict[str, int]]:
        """Dense grid: every block pair of every bucketed task runs."""
        batches: List[PreparedBatch] = []
        total = 0
        for key, idxs in bucket_by_shape(tasks, self._block,
                                         by_node=by_node).items():
            node = key[0] if by_node else None
            same, na, nb = key[-3:]
            a_stack, b_stack = self._stack_dense(tasks, idxs)
            total += (na // self._block) * (nb // self._block) * len(idxs)
            batches.append(PreparedBatch(
                node=node, same=same, idxs=list(idxs),
                arrays=(a_stack, b_stack),
                fn_key=("dense", same, na, nb)))
        return batches, {"block_pairs_total": total,
                         "block_pairs_evaluated": total}

    def _batches_block(self, tasks: Sequence[JoinTask], eps: int,
                       by_node: bool, auto: bool = False,
                       bitmap: bool = False
                       ) -> Tuple[List[PreparedBatch], Dict[str, int]]:
        """Block-sparse grid: sort, prune, and pad each task's pair
        list; tasks with no surviving block pair skip dispatch (their
        count is provably zero). With ``bitmap``, bbox-surviving pair
        lists pass a second, cell-exact refinement stage (hierarchical
        occupancy bitmaps, ``prune.refine_block_pairs``) before routing
        — run as a distinct ``prep.bitmap`` phase so its wall-clock and
        killed-pair counters are attributable. With ``auto``, a task
        whose padded (refined) pair list cannot beat its dense grid is
        routed to a dense bucket instead — single-block chunk pairs skip
        pair-list construction entirely (a dense grid of one block is
        already minimal)."""
        total = evaluated = killed = 0
        prepped: Dict[int, tuple] = {}
        block_buckets: Dict[tuple, List[int]] = {}
        dense_buckets: Dict[tuple, List[int]] = {}
        # Phase 1 — bbox prune: sorted sides + live block-pair lists.
        cand: List[tuple] = []
        for i, (node, a, b, same) in enumerate(tasks):
            ca, cb = task_coords(a), task_coords(b)
            if ca.shape[0] == 0 or cb.shape[0] == 0:
                continue
            na = -(-ca.shape[0] // self._block) * self._block
            nb = -(-cb.shape[0] // self._block) * self._block
            grid = (na // self._block) * (nb // self._block)
            dkey = ((node,) if by_node else ()) + (same, na, nb)
            if auto and grid == 1:
                total += 1
                evaluated += 1
                dense_buckets.setdefault(dkey, []).append(i)
                continue
            a_s = self._sorted_side(a)
            b_s = a_s if same else self._sorted_side(b)
            pairs, dense_total = self._pair_list(a, b, a_s, b_s, eps, same)
            total += dense_total
            if pairs.shape[0] == 0:
                continue
            cand.append((i, dkey, a, b, a_s, b_s, same, pairs, dense_total))
        # Phase 2 — cell-exact refinement of every bbox survivor (the
        # stats keys appear iff this stage actually ran on a candidate,
        # so workloads that never engage it keep seed-shaped stats).
        bitmap_s = None
        if bitmap and cand:
            tb = time.perf_counter()
            with self.tracer.span("prep.bitmap", candidates=len(cand)):
                refined_cand = []
                for (i, dkey, a, b, a_s, b_s, same, pairs,
                     dense_total) in cand:
                    pairs, k = self._refined_pairs(
                        a, b, a_s, b_s, pairs, eps, same)
                    killed += k
                    refined_cand.append(
                        (i, dkey, a_s, b_s, pairs, dense_total))
                cand = refined_cand
            bitmap_s = time.perf_counter() - tb
        else:
            cand = [(i, dkey, a_s, b_s, pairs, dense_total)
                    for (i, dkey, a, b, a_s, b_s, same, pairs,
                         dense_total) in cand]
        # Phase 3 — routing: fully-killed tasks skip dispatch (their
        # count is provably zero); auto compares the padded refined
        # length against the dense grid.
        for (i, dkey, a_s, b_s, pairs, dense_total) in cand:
            if pairs.shape[0] == 0:
                continue
            if (auto and self._prune.padded_pair_len(pairs.shape[0])
                    >= dense_total):
                evaluated += dense_total
                dense_buckets.setdefault(dkey, []).append(i)
                continue
            evaluated += pairs.shape[0]
            plen = self._prune.padded_pair_len(pairs.shape[0])
            prepped[i] = (a_s, b_s, pairs)
            block_buckets.setdefault(dkey + (plen,), []).append(i)
        batches: List[PreparedBatch] = []
        for key, idxs in block_buckets.items():
            node = key[0] if by_node else None
            same, na, nb, plen = key[-4:]
            a_stack = np.stack([self._padded_side(tasks[i][1], self._sentinel,
                                                  sorted_arr=prepped[i][0])
                                for i in idxs])
            b_stack = np.stack([self._padded_side(tasks[i][2],
                                                  -self._sentinel,
                                                  sorted_arr=prepped[i][1])
                                for i in idxs])
            p_stack = np.stack([self._prune.pad_pairs(prepped[i][2], plen)
                                for i in idxs])
            batches.append(PreparedBatch(
                node=node, same=same, idxs=list(idxs),
                arrays=(a_stack, b_stack, p_stack),
                fn_key=("block", same, na, nb, plen)))
        for key, idxs in dense_buckets.items():
            node = key[0] if by_node else None
            same, na, nb = key[-3:]
            a_stack, b_stack = self._stack_dense(tasks, idxs)
            batches.append(PreparedBatch(
                node=node, same=same, idxs=list(idxs),
                arrays=(a_stack, b_stack),
                fn_key=("dense", same, na, nb)))
        stats = {"block_pairs_total": total,
                 "block_pairs_evaluated": evaluated}
        if bitmap_s is not None:
            stats["block_pairs_bitmap_killed"] = killed
            stats["bitmap_build_s"] = bitmap_s
        return batches, stats

    # ---------------------------------------------------------- dispatch

    def dispatch(self, batch: PreparedBatch, eps: int,
                 arrays: Optional[tuple] = None):
        """Run one prepared batch through its memoized jitted entry;
        returns the (k,) per-task match-count device array. ``arrays``
        overrides ``batch.arrays`` with device-placed copies (the mesh
        backend pins them to the executing node's device first)."""
        key = batch.fn_key + (int(eps), self.interpret)
        fn = self._fn_cache.get(key)
        if fn is None:
            base = (self._ops.count_similar_pairs_batch
                    if batch.fn_key[0] == "dense"
                    else self._ops.count_similar_pairs_pruned_batch)
            fn = functools.partial(base, eps=int(eps), same=batch.same,
                                   interpret=self.interpret)
            self._fn_cache[key] = fn
        return fn(*(arrays if arrays is not None else batch.arrays))

    def count_pairs(self, tasks: Sequence[JoinTask], eps: int) -> List[int]:
        """Per-task match counts via bucketed batched kernel dispatch;
        records the query's block-pair counters, prep/dispatch split,
        and artifact hit/miss deltas in ``last_stats``."""
        counts = [0] * len(tasks)
        batches, stats = self.iter_batches(tasks, eps)
        t0 = time.perf_counter()
        with self.tracer.span("dispatch", batches=len(batches)):
            for batch in batches:
                got = np.asarray(self.dispatch(batch, eps))
                for i, c in zip(batch.idxs, got):
                    counts[i] = int(c)
        stats["dispatch_s"] = time.perf_counter() - t0
        self.last_stats = stats
        return counts


def make_join_executor(backend: str, join_fn: Callable[..., int],
                       interpret: bool = True, prune: str = "auto",
                       artifacts: Optional[JoinArtifactCache] = None):
    """Build a join executor for ``backend``, degrading pallas -> numpy
    with a warning when jax is unavailable. ``prune`` selects the pallas
    grid (``"dense"`` full grid / ``"block"`` block-sparse / ``"bitmap"``
    block-sparse + cell-exact refinement / ``"auto"`` per-task selection,
    the default); the numpy executor has no block structure, so it
    accepts the adaptive default as a no-op but rejects an explicit
    ``"block"`` or ``"bitmap"`` request it cannot honor."""
    if backend == "numpy":
        if prune in ("block", "bitmap"):
            raise ValueError(
                f"prune={prune!r} requires the pallas join backend; the "
                f"numpy executor has no block grid to prune")
        return NumpyJoinExecutor(join_fn)
    if backend == "pallas":
        try:
            return PallasJoinExecutor(interpret=interpret, prune=prune,
                                      artifacts=artifacts)
        except ImportError as e:                 # jax not available: degrade
            import warnings
            warnings.warn(f"join_backend='pallas' unavailable ({e}); "
                          f"falling back to the numpy executor",
                          RuntimeWarning, stacklevel=3)
            return NumpyJoinExecutor(join_fn)
    raise ValueError(f"unknown join backend {backend!r}; "
                     f"known: {JOIN_BACKENDS}")
