"""Join executors: per-node grouped chunk-pair work -> match counts
(extracted from ``repro.core.cluster``).

  * ``"numpy"``  — the reference executor: one blocked numpy evaluation
    per chunk pair (``join_fn`` override preserved).
  * ``"pallas"`` — the batched executor: each node's chunk-pair work is
    grouped, coordinate sets are padded to the kernel's 128-wide BLOCK,
    and shape-bucketed pair batches are dispatched to the
    ``kernels/simjoin`` Pallas kernel (interpret-mode by default, so it
    runs on CPU CI and compiles on TPU). Its ``prune`` knob selects the
    dense grid (``"dense"``, every block pair evaluated — the parity
    reference) or the block-sparse grid (``"block"``: coordinates are
    spatially sorted, per-block bounding boxes pruned against ``eps``
    on host, and only live block pairs are scalar-prefetched into the
    kernel — see ``repro.kernels.simjoin.prune``).

Every pallas dispatch records ``last_stats`` (``block_pairs_total`` =
the dense grid size, ``block_pairs_evaluated`` = block pairs actually
dispatched), which the backends surface per query on ``ExecutedQuery``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

JOIN_BACKENDS = ("numpy", "pallas")
PRUNE_MODES = ("dense", "block")

# One unit of join work: (node, a coords, b coords, self-join?).
JoinTask = Tuple[int, np.ndarray, np.ndarray, bool]


@dataclasses.dataclass
class PreparedBatch:
    """One shape bucket's stacked kernel inputs, ready for dispatch.

    ``arrays`` is ``(a_stack, b_stack)`` for the dense grid or
    ``(a_stack, b_stack, pairs_stack)`` for the block-sparse grid;
    ``fn_key`` identifies the jitted entry point + static shape bucket
    (the executor memoizes the bound callable per ``fn_key`` + eps).
    The mesh backend re-places ``arrays`` onto ``node``'s device before
    dispatch; ``node`` is ``None`` for node-agnostic bucketing."""

    node: Optional[int]
    same: bool
    idxs: List[int]
    arrays: Tuple[np.ndarray, ...]
    fn_key: tuple


def count_similar_pairs_np(a: np.ndarray, b: np.ndarray, eps: int,
                           same: bool, block: int = 4096) -> int:
    """Unordered (x != y) L1-neighbor pairs between cell coordinate sets.
    Blocked to bound memory; numpy reference executor."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return 0
    total = 0
    for i0 in range(0, a.shape[0], block):
        ai = a[i0:i0 + block]
        for j0 in range(0, b.shape[0], block):
            bj = b[j0:j0 + block]
            dist = np.abs(ai[:, None, :].astype(np.int64)
                          - bj[None, :, :].astype(np.int64)).sum(axis=2)
            hit = dist <= eps
            if same:
                # Count each unordered pair once; drop identical cells.
                ii = i0 + np.arange(ai.shape[0])[:, None]
                jj = j0 + np.arange(bj.shape[0])[None, :]
                hit &= ii < jj
            total += int(hit.sum())
    return total


def bucket_by_shape(tasks: Sequence[JoinTask], block: int,
                    by_node: bool = False) -> Dict[tuple, List[int]]:
    """Group non-empty tasks into batched-dispatch buckets keyed by
    self-join mode and BLOCK-padded coordinate-set shapes (plus the
    executing node when ``by_node`` — the mesh backend pins each bucket
    to its node's device). Returns key -> task indices."""
    buckets: Dict[tuple, List[int]] = {}
    for i, (node, a, b, same) in enumerate(tasks):
        if a.shape[0] == 0 or b.shape[0] == 0:
            continue
        na = -(-a.shape[0] // block) * block
        nb = -(-b.shape[0] // block) * block
        key = (node, same, na, nb) if by_node else (same, na, nb)
        buckets.setdefault(key, []).append(i)
    return buckets


def stack_bucket(tasks: Sequence[JoinTask], idxs: Sequence[int], ops,
                 sentinel: int):
    """Pad one bucket's coordinate sets to BLOCK (±sentinel fill, via
    ``ops.pad_cm_np``) and stack them into the (k, d, N) batches the
    batched simjoin kernel consumes."""
    a_stack = np.stack([ops.pad_cm_np(tasks[i][1], sentinel)
                        for i in idxs])
    b_stack = np.stack([ops.pad_cm_np(tasks[i][2], -sentinel)
                        for i in idxs])
    return a_stack, b_stack


class NumpyJoinExecutor:
    """Reference executor: evaluate each pair independently."""

    def __init__(self, join_fn: Callable[..., int]):
        self.join_fn = join_fn
        # Block-pair counters are a kernel-path concept; the numpy
        # reference has none (ExecutedQuery fields stay None).
        self.last_stats: Optional[Dict[str, int]] = None

    def count_pairs(self, tasks: Sequence[JoinTask], eps: int) -> List[int]:
        """Per-task match counts via the (overridable) numpy predicate."""
        return [self.join_fn(a, b, eps, same) for _, a, b, same in tasks]


class PallasJoinExecutor:
    """Batched executor over the ``kernels/simjoin`` Pallas kernels.

    Each node's chunk-pair tasks are padded to BLOCK and bucketed by
    padded shape and self-join mode; each bucket is dispatched as ONE
    stacked kernel call — turning a pair-at-a-time python loop into a
    handful of jit'd launches per query. Buckets span nodes because the
    simulated backend executes every node's work on this one device; the
    mesh backend (``repro.backend.jax_mesh``) keys buckets by node and
    pins each bucket to that node's device.

    ``prune="block"`` switches buckets to the block-sparse kernel: per
    task the coordinates are spatially sorted, live block pairs computed
    on host (min L1 box distance ``<= eps``), and the pair list —
    padded to a power-of-two bucket length so pair-count jitter does not
    retrace — scalar-prefetched into the kernel. ``prune="dense"`` (the
    default) keeps the full grid for parity testing and as fallback.

    The jitted batch callable for every ``(kernel, same, shapes, eps)``
    bucket key is memoized in ``_fn_cache``: repeated same-shape queries
    dispatch through the SAME bound callable, so jax's jit cache is hit
    without re-binding statics (``ops.TRACE_COUNTS`` proves no retrace).
    """

    def __init__(self, interpret: bool = True, prune: str = "dense"):
        # Imported lazily so the numpy backend never pulls in jax.
        from repro.kernels.simjoin import ops, prune as prune_mod, simjoin
        if prune not in PRUNE_MODES:
            raise ValueError(f"unknown prune mode {prune!r}; "
                             f"known: {PRUNE_MODES}")
        self._ops = ops
        self._prune = prune_mod
        self._block = simjoin.BLOCK
        self._sentinel = simjoin.SENTINEL
        self.interpret = interpret
        self.prune = prune
        self._fn_cache: Dict[tuple, Callable] = {}
        self.last_stats: Optional[Dict[str, int]] = None

    # ------------------------------------------------- batch preparation

    def iter_batches(self, tasks: Sequence[JoinTask], eps: int,
                     by_node: bool = False
                     ) -> Tuple[List[PreparedBatch], Dict[str, int]]:
        """Bucket and stack the tasks' kernel inputs (dense or pruned per
        the ``prune`` knob); returns ``(batches, stats)`` where stats
        carries the query's ``block_pairs_total`` / ``_evaluated``."""
        if self.prune == "block":
            return self._batches_block(tasks, eps, by_node)
        return self._batches_dense(tasks, by_node)

    def _batches_dense(self, tasks: Sequence[JoinTask], by_node: bool
                       ) -> Tuple[List[PreparedBatch], Dict[str, int]]:
        """Dense grid: every block pair of every bucketed task runs."""
        batches: List[PreparedBatch] = []
        total = 0
        for key, idxs in bucket_by_shape(tasks, self._block,
                                         by_node=by_node).items():
            node = key[0] if by_node else None
            same, na, nb = key[-3:]
            a_stack, b_stack = stack_bucket(tasks, idxs, self._ops,
                                            self._sentinel)
            total += (na // self._block) * (nb // self._block) * len(idxs)
            batches.append(PreparedBatch(
                node=node, same=same, idxs=list(idxs),
                arrays=(a_stack, b_stack),
                fn_key=("dense", same, na, nb)))
        return batches, {"block_pairs_total": total,
                         "block_pairs_evaluated": total}

    def _batches_block(self, tasks: Sequence[JoinTask], eps: int,
                       by_node: bool
                       ) -> Tuple[List[PreparedBatch], Dict[str, int]]:
        """Block-sparse grid: sort, prune, and pad each task's pair
        list; tasks with no surviving block pair skip dispatch (their
        count is provably zero)."""
        total = evaluated = 0
        prepped: Dict[int, tuple] = {}
        buckets: Dict[tuple, List[int]] = {}
        for i, (node, a, b, same) in enumerate(tasks):
            if a.shape[0] == 0 or b.shape[0] == 0:
                continue
            a_s = self._prune.spatial_sort(a)
            b_s = a_s if same else self._prune.spatial_sort(b)
            pairs, dense_total = self._prune.build_block_pairs(
                a_s, b_s, self._block, int(eps), bool(same))
            total += dense_total
            if pairs.shape[0] == 0:
                continue
            evaluated += pairs.shape[0]
            na = -(-a.shape[0] // self._block) * self._block
            nb = -(-b.shape[0] // self._block) * self._block
            plen = self._prune.padded_pair_len(pairs.shape[0])
            key = ((node,) if by_node else ()) + (same, na, nb, plen)
            prepped[i] = (a_s, b_s, pairs)
            buckets.setdefault(key, []).append(i)
        batches: List[PreparedBatch] = []
        for key, idxs in buckets.items():
            node = key[0] if by_node else None
            same, na, nb, plen = key[-4:]
            a_stack = np.stack([self._ops.pad_cm_np(prepped[i][0],
                                                    self._sentinel)
                                for i in idxs])
            b_stack = np.stack([self._ops.pad_cm_np(prepped[i][1],
                                                    -self._sentinel)
                                for i in idxs])
            p_stack = np.stack([self._prune.pad_pairs(prepped[i][2], plen)
                                for i in idxs])
            batches.append(PreparedBatch(
                node=node, same=same, idxs=list(idxs),
                arrays=(a_stack, b_stack, p_stack),
                fn_key=("block", same, na, nb, plen)))
        return batches, {"block_pairs_total": total,
                         "block_pairs_evaluated": evaluated}

    # ---------------------------------------------------------- dispatch

    def dispatch(self, batch: PreparedBatch, eps: int,
                 arrays: Optional[tuple] = None):
        """Run one prepared batch through its memoized jitted entry;
        returns the (k,) per-task match-count device array. ``arrays``
        overrides ``batch.arrays`` with device-placed copies (the mesh
        backend pins them to the executing node's device first)."""
        key = batch.fn_key + (int(eps), self.interpret)
        fn = self._fn_cache.get(key)
        if fn is None:
            base = (self._ops.count_similar_pairs_batch
                    if batch.fn_key[0] == "dense"
                    else self._ops.count_similar_pairs_pruned_batch)
            fn = functools.partial(base, eps=int(eps), same=batch.same,
                                   interpret=self.interpret)
            self._fn_cache[key] = fn
        return fn(*(arrays if arrays is not None else batch.arrays))

    def count_pairs(self, tasks: Sequence[JoinTask], eps: int) -> List[int]:
        """Per-task match counts via bucketed batched kernel dispatch;
        records the query's block-pair counters in ``last_stats``."""
        counts = [0] * len(tasks)
        batches, stats = self.iter_batches(tasks, eps)
        for batch in batches:
            got = np.asarray(self.dispatch(batch, eps))
            for i, c in zip(batch.idxs, got):
                counts[i] = int(c)
        self.last_stats = stats
        return counts


def make_join_executor(backend: str, join_fn: Callable[..., int],
                       interpret: bool = True, prune: str = "dense"):
    """Build a join executor for ``backend``, degrading pallas -> numpy
    with a warning when jax is unavailable. ``prune`` selects the pallas
    grid (``"dense"`` full grid / ``"block"`` block-sparse) and is
    rejected for the numpy executor, which has no block structure."""
    if backend == "numpy":
        if prune != "dense":
            raise ValueError(
                f"prune={prune!r} requires the pallas join backend; the "
                f"numpy executor has no block grid to prune")
        return NumpyJoinExecutor(join_fn)
    if backend == "pallas":
        try:
            return PallasJoinExecutor(interpret=interpret, prune=prune)
        except ImportError as e:                 # jax not available: degrade
            import warnings
            warnings.warn(f"join_backend='pallas' unavailable ({e}); "
                          f"falling back to the numpy executor",
                          RuntimeWarning, stacklevel=3)
            return NumpyJoinExecutor(join_fn)
    raise ValueError(f"unknown join backend {backend!r}; "
                     f"known: {JOIN_BACKENDS}")
