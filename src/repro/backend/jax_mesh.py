"""Device-mesh backend: real jax transfers and compiled Pallas joins.

Maps the paper's *nodes* onto jax devices over a one-axis
``jax.sharding.Mesh`` (axis name ``"node"``). Three things become real
that the simulated backend only models:

  * **Committed cache buffers** — every chunk in ``CacheState.cached``
    is materialized as a device-resident jax array pinned (via
    ``jax.device_put``) to the device of each holder node in its
    ``CacheState`` replica set (one buffer per replica copy; single-copy
    under ``replication="off"``). Buffers move/free in lockstep with admit, evict, and
    split-remap through the :class:`~repro.backend.base.
    DeviceBindingListener` hooks (the same life-cycle points the
    CoverageIndex syncs on).
  * **Ship decisions** — each ``plan_join`` transfer route (chunk, src,
    dest) is replayed as an actual cross-device ``jax.device_put`` with
    measured bytes and wall-clock (``measured_net_s`` /
    ``measured_ship_bytes``).
  * **Join compute** — each node's chunk-pair batch is shape-bucketed
    and dispatched to the ``kernels/simjoin`` Pallas kernel on that
    node's device, compiled (``interpret=False``) when the platform
    supports it (TPU/GPU; auto-detected, overridable), interpret-mode
    on CPU. Per-node kernel wall-clock is measured and combined with
    the §4.1 ``max_n`` convention into ``measured_compute_s``.

On CPU-only hosts, run under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` so jax exposes N
virtual CPU devices and CI exercises real cross-device placement; with
fewer devices than nodes the node axis wraps (node ``k`` lives on device
``k % n_devices``).

Modeled ``time_*_s`` fields are still reported (computed from the same
plans) so the two backends remain directly comparable; the measured
fields are additive, never substitutes.
"""
from __future__ import annotations

import time
import warnings
from typing import (TYPE_CHECKING, Any, Callable, Dict, List, Optional,
                    Sequence, Tuple)

import numpy as np

if TYPE_CHECKING:
    from repro.core.cache_state import CacheState
    from repro.core.chunk import ChunkMeta
    from repro.core.coordinator import (CacheCoordinator, QueryReport,
                                        SimilarityJoinQuery)
from repro.backend.artifacts import ChunkView
from repro.backend.base import BACKENDS, ExecutedQuery
from repro.backend.cost_model import CostModel
from repro.backend.simulated import SimulatedBackend
from repro.faults.errors import RetryExhaustedError


def compiled_mode_supported() -> bool:
    """Whether the default jax platform compiles Pallas kernels
    (TPU via Mosaic, GPU via Triton); CPU runs interpret-mode only."""
    import jax
    return jax.default_backend() in ("tpu", "gpu")


class JaxMeshBackend(SimulatedBackend):
    """Execution over a one-axis device mesh: nodes -> jax devices."""

    name = "jax_mesh"

    def __init__(self, n_nodes: int, cost_model: Optional[CostModel] = None,
                 devices: Optional[Sequence[Any]] = None,
                 compiled: Optional[bool] = None,
                 execute_joins: bool = True, prune: str = "auto",
                 mqo: str = "off"):
        import jax
        from jax.sharding import Mesh
        # The mesh backend always joins through the Pallas kernel; the
        # simulated parent's executor field holds the dispatch cache and
        # the prune preprocessing shared with the per-node path here.
        interpret = not (compiled_mode_supported() if compiled is None
                         else compiled)
        super().__init__(n_nodes, cost_model=cost_model,
                         join_backend="pallas", execute_joins=execute_joins,
                         interpret=interpret, prune=prune, mqo=mqo)
        self.interpret = interpret
        self.devices = tuple(devices if devices is not None
                             else jax.devices())
        if not self.devices:
            raise ValueError("jax reports no devices")
        if len(self.devices) < n_nodes:
            warnings.warn(
                f"jax_mesh: {n_nodes} nodes over {len(self.devices)} "
                f"devices — the node axis wraps (node k -> device "
                f"k % {len(self.devices)}). Set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n_nodes} for "
                f"one CPU device per node.", RuntimeWarning, stacklevel=2)
        self.mesh = Mesh(np.array(self.devices), ("node",))
        # The parent already built a PallasJoinExecutor; per-node dispatch
        # goes through its iter_batches/dispatch seam below.
        from repro.backend.executors import PallasJoinExecutor
        if not isinstance(self.executor, PallasJoinExecutor):
            raise ImportError(
                "jax_mesh backend requires the Pallas simjoin kernel")
        # Committed cache buffers, one per replica copy: chunk id ->
        # {holder node -> device array}. ``_buffer_node`` tracks the
        # PRIMARY holder (the CacheState ``primary_map`` view the parity
        # assertions compare against); under ``replication="off"`` every
        # inner dict has exactly one entry and the behavior reduces to
        # the seed's single-buffer-per-chunk map.
        self._buffers: Dict[int, Dict[int, Any]] = {}
        self._buffer_node: Dict[int, int] = {}
        # Pinned dispatch batches: the stacked, device-placed kernel
        # inputs of a prepared batch, keyed by (device, fn_key, eps, the
        # ordered artifact keys of the batch's tasks). A repeat query
        # over resident chunks re-dispatches the SAME device buffers
        # instead of re-device_put-ting identical host stacks; entries
        # are invalidated with the chunks they were stacked from, and —
        # because they live in device memory, the scarcest resource —
        # additionally LRU-capped at ``pinned_batch_cap`` entries
        # (insertion order of the dict, refreshed on hit).
        self._pinned: Dict[tuple, tuple] = {}
        self._pinned_by_chunk: Dict[int, set] = {}
        self.pinned_batch_cap = 256
        # Cumulative device-side counters (bench_scalability surfaces them).
        self.device_stats: Dict[str, float] = {
            "committed_bytes_materialized": 0.0,
            "committed_bytes_moved": 0.0,
            "committed_buffers_freed": 0.0,
            "ship_bytes_measured": 0.0,
            "ship_transfers": 0.0,
            "pinned_batch_hits": 0.0,
            "pinned_batch_misses": 0.0,
            "pinned_batches_freed": 0.0,
            # Replication/failover device counters: bytes copied
            # device-to-device to fill a secondary replica buffer, and
            # committed buffers lost to a simulated node crash (kept
            # separate from ``committed_buffers_freed`` so policy-driven
            # frees stay comparable across replication on/off runs).
            "replica_bytes_copied": 0.0,
            "failover_buffers_dropped": 0.0,
        }

    # --------------------------------------------------------- device math

    def device_for_node(self, node: int) -> Any:
        """The mesh device hosting a paper node (wraps when the mesh is
        smaller than the node count). The mesh's device array is the
        single source of truth for the node -> device map."""
        devs = self.mesh.devices
        return devs[node % devs.size]

    def buffer_device(self, chunk_id: int) -> Optional[Any]:
        """The device holding a chunk's PRIMARY committed buffer, or
        ``None`` when the chunk has no committed buffer at all."""
        per_node = self._buffers.get(chunk_id)
        if not per_node:
            return None
        node = self._buffer_node.get(chunk_id)
        buf = per_node.get(node) if node is not None else None
        if buf is None:
            buf = next(iter(per_node.values()))
        (dev,) = buf.devices()
        return dev

    def replica_devices(self, chunk_id: int) -> Dict[int, Any]:
        """Every committed buffer of a chunk: holder node -> device (one
        entry per replica copy; empty when nothing is committed)."""
        out: Dict[int, Any] = {}
        for node, buf in self._buffers.get(chunk_id, {}).items():
            (dev,) = buf.devices()
            out[node] = dev
        return out

    def committed_chunks(self) -> Dict[int, int]:
        """Snapshot of committed buffers: chunk id -> node."""
        return dict(self._buffer_node)

    # ------------------------------------------------------------- binding

    def bind(self, coordinator: "CacheCoordinator") -> None:
        """Attach to the coordinator and register the device-binding
        hooks on its ``CacheState`` so buffers track residency."""
        super().bind(coordinator)
        coordinator.cache.add_listener(self)

    # ------------------------- DeviceBindingListener (cache life-cycle) --

    def _enforce_pinned_cap(self) -> None:
        """Evict least-recently-used pinned batches down to the cap
        (dict insertion order, refreshed on every hit)."""
        while len(self._pinned) > self.pinned_batch_cap:
            old = next(iter(self._pinned))
            del self._pinned[old]
            self.device_stats["pinned_batches_freed"] += 1
            self._unindex_pinned(old)

    def _unindex_pinned(self, key: tuple) -> None:
        """Remove a freed pinned entry from every chunk's key set."""
        for ka, kb in key[3]:
            for cid in (ka[0], kb[0]):
                refs = self._pinned_by_chunk.get(cid)
                if refs is not None:
                    refs.discard(key)
                    if not refs:
                        del self._pinned_by_chunk[cid]

    def _drop_pinned(self, chunk_id: int) -> None:
        """Free every pinned dispatch batch stacked from a chunk (and
        unindex it from the partner chunks' key sets)."""
        for key in self._pinned_by_chunk.pop(chunk_id, ()):
            if self._pinned.pop(key, None) is not None:
                self.device_stats["pinned_batches_freed"] += 1
                self._unindex_pinned(key)

    def on_drop(self, chunk_id: int) -> None:
        """Eviction/placement dropped a chunk: free the device buffer of
        EVERY replica copy and every pinned dispatch batch it
        participated in."""
        per_node = self._buffers.pop(chunk_id, None)
        if per_node:
            self.device_stats["committed_buffers_freed"] += len(per_node)
        self._buffer_node.pop(chunk_id, None)
        self._drop_pinned(chunk_id)

    def on_split(self, parent_id: int, leaves: List["ChunkMeta"]) -> None:
        """A cached chunk split: retire the parent's buffers (every
        replica copy) and pinned batches. The children inherit its
        residency/replica set in ``CacheState`` and materialize on the
        inherited nodes' devices at the next reconcile."""
        per_node = self._buffers.pop(parent_id, None)
        if per_node:
            self.device_stats["committed_buffers_freed"] += len(per_node)
        self._buffer_node.pop(parent_id, None)
        self._drop_pinned(parent_id)

    def reconcile(self, state: "CacheState") -> None:
        """Post-round sync (the device twin of ``sync_coverage``): free
        buffers of chunks no longer resident, materialize buffers for
        newly resident chunks and replica copies, move single-copy
        buffers whose location changed, and free buffers of replicas
        that left the set — so each cached chunk holds exactly one
        committed buffer per node in ``CacheState.replicas_of``."""
        import jax
        import jax.numpy as jnp
        if self.coordinator is None:
            raise RuntimeError("backend not bound — call bind() first")
        chunks = self.coordinator.chunks
        for cid in list(self._buffers):
            if cid not in state.cached:
                self.on_drop(cid)
        # Pinned batches may reference just-scanned chunks that were
        # never admitted (no committed buffer): prune those too, the
        # same never-outlives-residency rule the artifact cache applies.
        for cid in list(self._pinned_by_chunk):
            if cid not in state.cached:
                self._drop_pinned(cid)
        for cid in state.cached:
            want = state.replicas_of(cid)
            if not want:
                # Not yet located (e.g. origin placement before first
                # touch): the chunk lives at its home node.
                if cid not in chunks.chunk_file:
                    continue
                want = (chunks.home_node(cid),)
            have = self._buffers.get(cid, {})
            if len(want) == 1 and len(have) == 1 and want[0] not in have:
                # Single-copy relocation — the seed path: MOVE the one
                # buffer with one device_put, counting neither a free nor
                # a materialization, so replication-off device stats stay
                # bit-identical to the single-valued implementation.
                ((old_node, buf),) = have.items()
                moved = jax.device_put(buf, self.device_for_node(want[0]))
                moved.block_until_ready()
                self._buffers[cid] = {want[0]: moved}
                self._buffer_node[cid] = want[0]
                # Count only relocations that cross physical devices: a
                # node change that wraps onto the same device (mesh
                # smaller than the node count) moves no bytes — the same
                # exclusion _ship applies to transfer routes.
                if (self.device_for_node(old_node)
                        != self.device_for_node(want[0])):
                    self.device_stats["committed_bytes_moved"] += buf.nbytes
                continue
            for node in want:
                if node in have:
                    continue
                src = next(iter(have.values()), None)
                if src is None:
                    meta = chunks.meta_of(cid)
                    if meta is None:   # retired id; re-enters next round
                        break
                    coords = chunks.chunk_coords(cid, meta.file_id)
                    buf = jax.device_put(jnp.asarray(coords, jnp.int32),
                                         self.device_for_node(node))
                    buf.block_until_ready()
                    self.device_stats["committed_bytes_materialized"] += \
                        buf.nbytes
                else:
                    # Replica fill: a real device-to-device copy from an
                    # existing holder — the cheap restore path a failover
                    # re-admission from a surviving replica rides on.
                    buf = jax.device_put(src, self.device_for_node(node))
                    buf.block_until_ready()
                    (src_dev,) = src.devices()
                    if src_dev != self.device_for_node(node):
                        self.device_stats["replica_bytes_copied"] += \
                            buf.nbytes
                have = self._buffers.setdefault(cid, {})
                have[node] = buf
            for node in [n for n in have if n not in want]:
                del have[node]
                self.device_stats["committed_buffers_freed"] += 1
            if not have:
                self._buffers.pop(cid, None)
                self._buffer_node.pop(cid, None)
            else:
                self._buffer_node[cid] = (want[0] if want[0] in have
                                          else next(iter(have)))

    # ------------------------------------------- simulated node failure

    def fail_node(self, node: int) -> Dict[str, float]:
        """Crash-restart one node on the mesh: free every committed
        replica buffer it held (and the pinned dispatch batches staged
        on its device), then run the coordinator's recovery. The
        reconcile the recovery triggers re-materializes the node's lost
        buffers for real — device-to-device from a surviving replica
        (``replica_bytes_copied``) or from host coordinates after a raw
        re-scan (``committed_bytes_materialized``) — so the device
        counters reflect the actual restore traffic."""
        if self.coordinator is None:
            raise RuntimeError("backend not bound — call bind() first")
        for cid in list(self._buffers):
            per_node = self._buffers[cid]
            if node not in per_node:
                continue
            per_node.pop(node)
            self.device_stats["failover_buffers_dropped"] += 1
            self._drop_pinned(cid)
            if not per_node:
                del self._buffers[cid]
                self._buffer_node.pop(cid, None)
            elif self._buffer_node.get(cid) == node:
                self._buffer_node[cid] = next(iter(per_node))
        dev = self.device_for_node(node)
        for key in [k for k in self._pinned if k[0] == dev]:
            del self._pinned[key]
            self.device_stats["pinned_batches_freed"] += 1
            self._unindex_pinned(key)
        return self.coordinator.fail_node(node)

    # ----------------------------------------------------------- execution

    def _mirror_device_stats(self) -> None:
        """Refresh the ``device.*`` registry gauges from the cumulative
        :attr:`device_stats` counters (telemetry-on callers only)."""
        reg = self.telemetry.registry
        for k, v in self.device_stats.items():
            reg.gauge(f"device.{k}").set(v)

    def _ship(self, report: "QueryReport",
              coords_of: Callable[[int], np.ndarray],
              skip: Optional[set] = None
              ) -> Tuple[float, int]:
        """Replay the join plan's ship decisions as real cross-device
        transfers; returns (measured seconds, measured bytes). Routes
        whose src and dest land on the same physical device (mesh wrap)
        move no bytes and are excluded from the byte count, as are
        routes for ``skip`` chunks (transfers already declared degraded
        by the fault guard — no source can produce their payload).
        Wrapped in a ``ship`` span when telemetry is on."""
        import jax
        import jax.numpy as jnp
        if report.join_plan is None:
            return 0.0, 0
        with self.telemetry.tracer.span(
                "ship", routes=len(report.join_plan.transfer_routes)):
            total_s, total_b = self._ship_routes(report, coords_of,
                                                 skip=skip)
        if self.telemetry.enabled:
            self._mirror_device_stats()
        return total_s, total_b

    def _ship_routes(self, report: "QueryReport",
                     coords_of: Callable[[int], np.ndarray],
                     skip: Optional[set] = None
                     ) -> Tuple[float, int]:
        """The transfer-replay loop behind :meth:`_ship`."""
        import jax
        import jax.numpy as jnp
        total_s, total_b = 0.0, 0
        n_transfers = 0
        staged: Dict[int, Any] = {}
        reuse_on = self.coordinator.reuse == "on"
        for cid, src, dst in report.join_plan.transfer_routes:
            if skip and cid in skip:
                continue
            src_dev = self.device_for_node(src)
            dst_dev = self.device_for_node(dst)
            if src_dev == dst_dev:
                continue
            payload = staged.get(cid)
            if payload is None:
                # Without reuse slicing the shipped payload is the whole
                # chunk — exactly the committed buffer when it is already
                # pinned at the source node; stage a fresh copy only when
                # no such buffer exists (just-scanned chunk) or the plan
                # ships a sliced extent.
                if not reuse_on and src in self._buffers.get(cid, {}):
                    payload = self._buffers[cid][src]
                else:
                    payload = jax.device_put(
                        jnp.asarray(coords_of(cid), jnp.int32), src_dev)
                    payload.block_until_ready()
                staged[cid] = payload
            t0 = time.perf_counter()
            shipped = jax.device_put(payload, dst_dev)
            shipped.block_until_ready()
            total_s += time.perf_counter() - t0
            total_b += int(payload.nbytes)
            n_transfers += 1
        self.device_stats["ship_bytes_measured"] += total_b
        self.device_stats["ship_transfers"] += n_transfers
        return total_s, total_b

    def _pinned_key(self, batch, tasks, eps: int, dev) -> Optional[tuple]:
        """The pinned-batch cache key of one prepared batch: the target
        device, the jitted entry's ``fn_key``, eps, and the ORDERED
        artifact keys of the batch's tasks — content-addressed through
        chunk identity, so identical stacks across queries collide.
        ``None`` (uncacheable) when any task side lacks an artifact key."""
        keys = []
        for i in batch.idxs:
            _, a, b, _ = tasks[i]
            ka = a.key if isinstance(a, ChunkView) else None
            kb = b.key if isinstance(b, ChunkView) else None
            if ka is None or kb is None:
                return None
            keys.append((ka, kb))
        return (dev, batch.fn_key, int(eps), tuple(keys))

    def _dispatch_joins(self, tasks, eps: int
                        ) -> Tuple[List[int], float, Dict[str, int]]:
        """Shape-bucketed per-node Pallas dispatch: every bucket's stacked
        batch (dense or block-sparse per the executor's ``prune`` knob)
        is placed on its node's device before the kernel call — ONCE per
        resident chunk set: device-placed stacks are pinned per
        (device, batch content) and re-dispatched directly on repeat
        queries, invalidated with their chunks' residency. Returns
        (per-task match counts, measured compute seconds = max over
        nodes — the §4.1 ``max_n`` convention applied to measured
        per-node wall-clock — and the query's counters)."""
        import contextlib

        import jax
        import jax.numpy as jnp
        node_time: Dict[int, float] = {}
        counts = [0] * len(tasks)
        batches, stats = self.executor.iter_batches(tasks, eps,
                                                    by_node=True)
        telemetry_on = self.telemetry.enabled
        dispatch_span = self.telemetry.tracer.begin("dispatch",
                                                    batches=len(batches))
        t0_all = time.perf_counter()
        for batch in batches:
            dev = self.device_for_node(batch.node)
            ckey = self._pinned_key(batch, tasks, eps, dev)
            arrays = self._pinned.pop(ckey, None) if ckey is not None \
                else None
            if arrays is not None:
                self.device_stats["pinned_batch_hits"] += 1
                self._pinned[ckey] = arrays      # LRU refresh (reinsert)
                self._enforce_pinned_cap()
            else:
                arrays = tuple(jax.device_put(jnp.asarray(x), dev)
                               for x in batch.arrays)
                for x in arrays:
                    x.block_until_ready()
                if ckey is not None:
                    self.device_stats["pinned_batch_misses"] += 1
                    self._pinned[ckey] = arrays
                    for ka, kb in ckey[3]:
                        self._pinned_by_chunk.setdefault(
                            ka[0], set()).add(ckey)
                        self._pinned_by_chunk.setdefault(
                            kb[0], set()).add(ckey)
                    self._enforce_pinned_cap()
            # jax.profiler annotation: names this kernel launch in any
            # captured XLA/Perfetto device profile (telemetry-on only —
            # the off path stays annotation-free).
            annot = (jax.profiler.TraceAnnotation(
                f"simjoin.node{batch.node}") if telemetry_on
                else contextlib.nullcontext())
            t0 = time.perf_counter()
            with annot:
                got = self.executor.dispatch(batch, eps, arrays=arrays)
                got.block_until_ready()
            node_time[batch.node] = (node_time.get(batch.node, 0.0)
                                     + time.perf_counter() - t0)
            for i, c in zip(batch.idxs, np.asarray(got)):
                counts[i] = int(c)
        stats["dispatch_s"] = time.perf_counter() - t0_all
        self.telemetry.tracer.end(dispatch_span)
        if telemetry_on:
            self._mirror_device_stats()
        return counts, max(node_time.values(), default=0.0), stats

    def _count_tasks(self, tasks, eps: int
                     ) -> Tuple[List[int], Dict[str, float]]:
        """Batch-execution seam: per-task counts via the per-node pinned
        dispatch path, with the measured kernel wall-clock (max over
        nodes) folded into the stats under ``measured_compute_s``."""
        counts, node_max_s, stats = self._dispatch_joins(tasks, eps)
        stats["measured_compute_s"] = node_max_s
        return counts, dict(stats)

    def _measured_ship(self, query: "SimilarityJoinQuery",
                       report: "QueryReport",
                       coords_cache: Dict[int, np.ndarray],
                       skip: Optional[set] = None
                       ) -> Tuple[Optional[float], Optional[int]]:
        """Batch-execution seam: replay this query's ship decisions as
        real cross-device transfers (shipping stays per-query under MQO
        — only kernel work is deduplicated across the batch). ``skip``
        chunks degraded by the fault guard are not replayed."""
        cm = {c.chunk_id: c for c in report.queried_chunks}

        def coords_of(cid: int) -> np.ndarray:
            if self.coordinator.reuse == "on":
                if cid not in coords_cache:
                    coords_cache[cid] = self._queried_coords(
                        cid, cm[cid].file_id, query.box)
                return coords_cache[cid]
            return self.coordinator.chunks.chunk_coords(
                cid, cm[cid].file_id)

        return self._ship(report, coords_of, skip=skip)

    def execute(self, query: "SimilarityJoinQuery",
                report: "QueryReport") -> ExecutedQuery:
        """Execute one planned query on the mesh: modeled phase times
        from the shared cost model, plus measured transfer and join
        wall-clock/bytes from the real device work."""
        if self.coordinator is None:
            raise RuntimeError("backend not bound — call bind() first")
        if report.result_cache_hit:
            return self._cached_result(report)
        time_scan = self.modeled_scan_time(report)
        time_net = self.modeled_net_time(report)
        drop, ship_ops = self._guard_transfers(query, report)
        tasks, work_by_node, coords_cache, _ = self.gather_join_tasks(
            query, report, exclude=drop)
        # Ship what the plan ships: the sliced extent under semantic
        # reuse, the whole chunk otherwise (a shipped chunk becomes a
        # full replica the placement round may keep). The host-level
        # fault guard runs first, so only routes with a producible
        # payload replay as real device transfers.
        measured_net, measured_bytes = self._measured_ship(
            query, report, coords_cache, skip=drop)
        matches: Optional[int] = None
        measured_compute = 0.0
        stats: Dict[str, int] = {}
        join_ops: List[str] = []
        if report.join_plan is not None and self.execute_joins:
            try:
                counts, stats = self._guarded_count(tasks, query.eps)
                matches = sum(counts)
                measured_compute = stats.get("measured_compute_s", 0.0)
            except RetryExhaustedError as e:
                join_ops.append(e.op)
                matches = 0
                stats = {}
        time_compute = (max(work_by_node.values(), default=0)
                        / self.cost.cell_pairs_per_sec)
        t_opt = report.opt_time_chunking_s + report.opt_time_evict_place_s
        degraded = self._assemble_degraded(query, report, drop, ship_ops,
                                           join_ops, matches)
        return self._record(ExecutedQuery(
            report=report, time_scan_s=time_scan, time_net_s=time_net,
            time_compute_s=time_compute, time_opt_s=t_opt, matches=matches,
            backend=self.name,
            measured_net_s=measured_net,
            measured_compute_s=measured_compute,
            measured_ship_bytes=measured_bytes,
            block_pairs_total=stats.get("block_pairs_total"),
            block_pairs_evaluated=stats.get("block_pairs_evaluated"),
            prep_s=stats.get("prep_s"),
            dispatch_s=stats.get("dispatch_s"),
            artifact_hits=stats.get("artifact_hits"),
            artifact_misses=stats.get("artifact_misses"),
            block_pairs_bitmap_killed=stats.get("block_pairs_bitmap_killed"),
            bitmap_build_s=stats.get("bitmap_build_s"),
            **self._resilience_fields(report),
            **self._fault_fields(degraded)))


def make_backend(backend: str, n_nodes: int,
                 cost_model: Optional[CostModel] = None,
                 join_fn: Optional[Callable[..., int]] = None,
                 join_backend: str = "numpy", execute_joins: bool = True,
                 devices: Optional[Sequence[Any]] = None,
                 compiled: Optional[bool] = None,
                 prune: str = "auto", mqo: str = "off") -> SimulatedBackend:
    """Build an execution backend by name, degrading ``jax_mesh`` ->
    ``simulated`` with a warning when jax is unavailable. ``prune``
    selects the Pallas join grid (``"dense"`` / ``"block"``-sparse /
    ``"bitmap"`` block-sparse + cell-exact hierarchical-bitmap
    refinement / ``"auto"`` per-task selection on post-bitmap refined
    pair counts, the default) and applies to any backend that joins
    through the Pallas kernel; ``mqo`` toggles cross-batch task dedup
    in ``execute_batch`` (off = seed parity)."""
    if backend == "simulated":
        return SimulatedBackend(n_nodes, cost_model=cost_model,
                                join_fn=join_fn, join_backend=join_backend,
                                execute_joins=execute_joins, prune=prune,
                                mqo=mqo)
    if backend == "jax_mesh":
        if join_fn is not None:
            raise ValueError(
                "join_fn overrides the numpy executor's predicate; the "
                "jax_mesh backend always runs the Pallas simjoin kernel "
                "— pass one or the other")
        try:
            return JaxMeshBackend(n_nodes, cost_model=cost_model,
                                  devices=devices, compiled=compiled,
                                  execute_joins=execute_joins, prune=prune,
                                  mqo=mqo)
        except ImportError as e:
            warnings.warn(f"backend='jax_mesh' unavailable ({e}); "
                          f"falling back to the simulated backend",
                          RuntimeWarning, stacklevel=2)
            return SimulatedBackend(n_nodes, cost_model=cost_model,
                                    join_fn=join_fn,
                                    join_backend=join_backend,
                                    execute_joins=execute_joins, mqo=mqo)
    raise ValueError(f"unknown backend {backend!r}; known: {BACKENDS}")
