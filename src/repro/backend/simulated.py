"""The §4.1 analytical backend: modeled disk/network, real join compute.

This is the seed :class:`repro.core.cluster.RawArrayCluster` execution
path extracted into the backend seam: the container is one box, so disk
and network phases are charged against the calibrated
:class:`~repro.backend.cost_model.CostModel` while the join predicate
itself runs for real (numpy reference or batched Pallas executor).

The modeled-phase helpers (`modeled_scan_time`, `modeled_net_time`,
`gather_join_tasks`) are shared with
:class:`repro.backend.jax_mesh.JaxMeshBackend`, which reports the same
modeled times alongside its measured ones so the two backends stay
directly comparable.
"""
from __future__ import annotations

from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Sequence,
                    Tuple)

import numpy as np

if TYPE_CHECKING:
    from repro.core.coordinator import (CacheCoordinator, QueryReport,
                                        SimilarityJoinQuery)
from repro.backend.artifacts import (ChunkView, JoinArtifactCache,
                                     subset_token)
from repro.backend.base import ExecutedQuery, record_executed
from repro.backend.cost_model import CostModel
from repro.backend.executors import (JoinTask, count_similar_pairs_np,
                                     make_join_executor)
from repro.faults.errors import RetryExhaustedError
from repro.faults.injector import ChecksumRegistry
from repro.faults.retry import DegradedResult, make_degraded
from repro.obs.telemetry import NULL_TELEMETRY, Telemetry

# Cross-batch multi-query optimization knob: "off" preserves the seed
# per-query execution exactly; "on" deduplicates join tasks by sharing
# signature across each admission batch (execute once, fan counts out).
MQO_MODES = ("off", "on")


class SimulatedBackend:
    """Cost-modeled execution over one process (the paper's simulator)."""

    name = "simulated"

    def __init__(self, n_nodes: int, cost_model: Optional[CostModel] = None,
                 join_fn: Optional[Callable[..., int]] = None,
                 join_backend: str = "numpy", execute_joins: bool = True,
                 interpret: bool = True, prune: str = "auto",
                 mqo: str = "off"):
        if mqo not in MQO_MODES:
            raise ValueError(f"unknown mqo mode {mqo!r}; "
                             f"expected one of {MQO_MODES}")
        self.n_nodes = n_nodes
        self.cost = cost_model or CostModel()
        self.join_fn = join_fn or count_similar_pairs_np
        self.execute_joins = execute_joins
        self.mqo = mqo
        self.executor = make_join_executor(join_backend, self.join_fn,
                                           interpret=interpret, prune=prune)
        # The pallas executor owns a JoinArtifactCache; the backend wires
        # its invalidation into CacheState at bind time (the numpy
        # executor has no host prep to memoize — artifacts stays None).
        self.artifacts: Optional[JoinArtifactCache] = getattr(
            self.executor, "artifacts", None)
        self.coordinator: Optional["CacheCoordinator"] = None
        # Replaced with the coordinator's telemetry bundle at bind time;
        # the no-op default keeps an unbound backend span/metric-free.
        self.telemetry: Telemetry = NULL_TELEMETRY
        # Transient-fault plumbing, adopted from the coordinator at bind
        # time (all None/zero when the faults knob is off — the guarded
        # paths collapse to the seed-exact ones).
        self.faults = None
        self.retrier = None
        self.checksums: Optional[ChecksumRegistry] = None
        self._reroutes = 0
        self._raw_fallbacks = 0
        self._fault_seen: Dict[str, float] = {}

    # ------------------------------------------------------------- binding

    def bind(self, coordinator: "CacheCoordinator") -> None:
        """Attach to the coordinator whose plans this backend executes,
        registering the join-artifact cache as a residency listener so
        memoized prep artifacts are invalidated in lockstep with
        eviction and split-remap (they never outlive their chunk). The
        coordinator's telemetry bundle is adopted here, and its tracer
        handed to the join executor (prep/dispatch spans)."""
        self.coordinator = coordinator
        self.telemetry = coordinator.telemetry
        if self.telemetry.enabled:
            self.executor.tracer = self.telemetry.tracer
        if self.artifacts is not None:
            coordinator.cache.add_listener(self.artifacts)
        # Adopt the coordinator's transient-fault pipeline: the shared
        # injector/retrier (so planner and backend draw from the same
        # deterministic schedules and retry budget), per-chunk payload
        # checksums for corruption faults, and the invariant auditor's
        # backend attachment (enables its device-buffer checks).
        self.faults = coordinator.faults
        self.retrier = coordinator.retrier
        self.checksums = (ChecksumRegistry()
                          if self.faults is not None else None)
        if self.checksums is not None:
            # Lifecycle hygiene: recorded CRCs die with their chunks
            # (split-remap/evict), like every other derived tier.
            coordinator.cache.add_listener(self.checksums)
        self._fault_seen = self._fault_totals()
        if coordinator.auditor is not None:
            coordinator.auditor.attach(self)

    def _record(self, eq: ExecutedQuery) -> ExecutedQuery:
        """Mirror a freshly built ExecutedQuery into the live metrics
        registry (every construction site funnels through here, so
        registry totals equal ``workload_summary`` by construction);
        a no-op with telemetry off."""
        if self.telemetry.enabled:
            record_executed(self.telemetry.registry, eq)
        return eq

    def _queried_coords(self, chunk_id: int, file_id: int,
                        box) -> np.ndarray:
        """Cell coordinates of a queried unit restricted to the query box."""
        # Imported here: the backend package must not import repro.core at
        # module level (repro.core.cluster imports repro.backend).
        from repro.core.geometry import points_in_box
        coords = self.coordinator.chunks.chunk_coords(chunk_id, file_id)
        return coords[points_in_box(coords, box)]

    # ------------------------------------------------------ modeled phases

    def modeled_scan_time(self, report: "QueryReport") -> float:
        """max_n of disk-scan + format-decode time under the cost model."""
        scan_n: Dict[int, float] = {}
        for node, nbytes in report.scan_bytes_by_node.items():
            scan_n[node] = nbytes / self.cost.disk_bw
        for node, per_fmt in report.decode_cells_by_node.items():
            for fmt, cells in per_fmt.items():
                scan_n[node] = (scan_n.get(node, 0.0)
                                + cells / self.cost.decode_rates[fmt])
        return max(scan_n.values(), default=0.0)

    def modeled_net_time(self, report: "QueryReport") -> float:
        """max_n of full-duplex link time for join shipping + placement
        fallback transfers under the cost model."""
        time_net = 0.0
        if report.join_plan is not None:
            per_node = []
            for n in range(self.n_nodes):
                bi = report.join_plan.bytes_in.get(n, 0)
                bo = report.join_plan.bytes_out.get(n, 0)
                per_node.append(max(bi, bo))
            time_net = max(per_node, default=0) / self.cost.net_bw
        return time_net + report.placement_extra_bytes / self.cost.net_bw

    def gather_join_tasks(self, query: "SimilarityJoinQuery",
                          report: "QueryReport",
                          exclude: Optional[set] = None
                          ) -> Tuple[List[JoinTask], Dict[int, int],
                                     Dict[int, np.ndarray], List[
                                         Optional[tuple]]]:
        """Materialize the plan's chunk-pair work: (tasks, per-node
        cell-pair load, per-chunk queried coordinates, per-task sharing
        signatures).

        ``exclude`` names chunk ids whose transfers exhausted their
        retry budget (see ``_guard_transfers``): every pair touching one
        is skipped — its region is served as a degraded sub-box instead
        of crashing the query — and its cell-pair work is not charged.

        With a pallas executor each task side is a
        :class:`~repro.backend.artifacts.ChunkView` keyed by chunk
        identity and queried subset, so the executor's artifact cache
        can memoize host-side prep across queries (numpy tasks stay raw
        arrays — the seed shape).

        The signature list runs parallel to ``tasks``: each entry is
        ``((a, subset_a), (b, subset_b), same)`` built from
        :func:`~repro.backend.artifacts.subset_token` — the
        content-addressed identity of the task's computation, which is
        what cross-batch MQO deduplicates on (``None`` marks an
        unshareable task). Signatures are derived for *every* executor
        (the numpy path has no ChunkViews but shares identically).

        A pair with an empty sliced side contributes no matches; under
        the semantic-reuse knob such pairs are skipped before dispatch
        (gated so a custom ``join_fn`` still sees every pair under the
        seed-parity configuration).
        """
        if self.coordinator is None:
            raise RuntimeError("backend not bound — call bind() first")
        cm = {c.chunk_id: c for c in report.queried_chunks}
        tasks: List[JoinTask] = []
        sigs: List[Optional[tuple]] = []
        work_by_node: Dict[int, int] = {}
        coords_cache: Dict[int, np.ndarray] = {}
        views: Dict[int, ChunkView] = {}
        tokens: Dict[int, Optional[tuple]] = {}
        if report.join_plan is None:
            return tasks, work_by_node, coords_cache, sigs
        skip_empty = self.coordinator.reuse == "on"
        for (a, b), node in report.join_plan.pair_node.items():
            if exclude and (a in exclude or b in exclude):
                continue
            for cid in (a, b):
                if cid not in coords_cache:
                    coords_cache[cid] = self._queried_coords(
                        cid, cm[cid].file_id, query.box)
                    tokens[cid] = subset_token(cm[cid].box, query.box)
            ca, cb = coords_cache[a], coords_cache[b]
            work_by_node[node] = (work_by_node.get(node, 0)
                                  + ca.shape[0] * cb.shape[0])
            if skip_empty and (ca.shape[0] == 0 or cb.shape[0] == 0):
                continue
            ta, tb = tokens[a], tokens[b]
            sigs.append(None if ta is None or tb is None
                        else ((a, ta), (b, tb), a == b))
            if self.artifacts is not None:
                for cid in (a, b):
                    if cid not in views:
                        views[cid] = self.artifacts.view(
                            cid, cm[cid].box, query.box, coords_cache[cid])
                tasks.append((node, views[a], views[b], a == b))
            else:
                tasks.append((node, ca, cb, a == b))
        return tasks, work_by_node, coords_cache, sigs

    # ------------------------------------------- failure / replication

    def fail_node(self, node: int) -> Dict[str, float]:
        """Simulate a crash-restart of one node: every cached copy it
        held is lost and the coordinator immediately re-admits what it
        can — cheaply from surviving replicas, else by re-scanning raw
        files. Returns the recovery event's counters (also attached to
        the next ExecutedQuery)."""
        if self.coordinator is None:
            raise RuntimeError("backend not bound — call bind() first")
        return self.coordinator.fail_node(node)

    def _resilience_fields(self, report: "QueryReport") -> Dict[str, object]:
        """Replication/failover counter fields for one ExecutedQuery:
        per-query replica hits plus the coordinator's pending
        round/recovery counters (drained here, so each event is
        attributed to exactly one query — the first executed after it).
        Empty when replication is off and no failure occurred, keeping
        the single-copy ExecutedQuery bit-identical to the seed's."""
        out: Dict[str, object] = {}
        coord = self.coordinator
        if coord is None:
            return out
        pending = coord.drain_exec_counters()
        if coord.replication != "off":
            jp = report.join_plan
            out["replica_hits"] = (int(jp.replica_hits)
                                   if jp is not None else 0)
            out["replicas_dropped"] = int(pending.get("replicas_dropped", 0))
        if "failover_readmits" in pending:
            out["failover_readmits"] = int(pending["failover_readmits"])
            out["recovery_bytes_from_replica"] = int(
                pending.get("recovery_bytes_from_replica", 0))
            out["recovery_bytes_from_raw"] = int(
                pending.get("recovery_bytes_from_raw", 0))
            out["recovery_s"] = float(pending.get("recovery_s", 0.0))
        return out

    # ----------------------------------------------- transient faults

    def _fault_totals(self) -> Dict[str, float]:
        """Cumulative fault-pipeline totals across every shared source:
        the injector, the retrier, the checksum registry, the auditor,
        and the backend-local re-route / raw-fallback counters. Per-query
        attribution is the delta between two snapshots (see
        :meth:`_fault_fields`) — all zeros when the pipeline is off."""
        coord = self.coordinator
        auditor = coord.auditor if coord is not None else None
        return {
            "faults_injected": float(
                self.faults.injected if self.faults is not None else 0),
            "retries": float(
                self.retrier.retries if self.retrier is not None else 0),
            "retry_backoff_s": float(
                self.retrier.backoff_s if self.retrier is not None else 0.0),
            "retry_giveups": float(
                self.retrier.giveups if self.retrier is not None else 0),
            "transfer_reroutes": float(self._reroutes),
            "raw_fallbacks": float(self._raw_fallbacks),
            "checksum_mismatch": float(
                self.checksums.mismatches
                if self.checksums is not None else 0),
            "audit_violations": float(
                auditor.violations_total if auditor is not None else 0),
        }

    def _fault_fields(self, degraded: Optional[DegradedResult]
                      ) -> Dict[str, object]:
        """Fault/retry/audit counter fields for one ExecutedQuery,
        attributed by snapshot delta against the totals recorded at the
        previous query (batched execution attributes its shared
        guard-phase work to the batch's first assembled query — sums
        stay exact). Empty when faults and auditing are both off,
        keeping the default ExecutedQuery bit-identical to the seed's."""
        coord = self.coordinator
        if coord is None or (coord.faults is None and coord.auditor is None):
            return {}
        now = self._fault_totals()
        delta = {k: now[k] - self._fault_seen.get(k, 0.0) for k in now}
        self._fault_seen = now
        out: Dict[str, object] = {}
        if coord.faults is not None:
            out["faults_injected"] = int(delta["faults_injected"])
            out["retries"] = int(delta["retries"])
            out["retry_backoff_s"] = float(delta["retry_backoff_s"])
            out["retry_giveups"] = int(delta["retry_giveups"])
            out["transfer_reroutes"] = int(delta["transfer_reroutes"])
            out["raw_fallbacks"] = int(delta["raw_fallbacks"])
            out["checksum_mismatch"] = int(delta["checksum_mismatch"])
            out["degraded_queries"] = 1 if degraded is not None else 0
            out["degraded"] = degraded
        if coord.auditor is not None:
            out["audit_violations"] = int(delta["audit_violations"])
        return out

    def _guard_transfers(self, query: "SimilarityJoinQuery",
                         report: "QueryReport"
                         ) -> Tuple[set, List[str]]:
        """Arm the ``ship.transfer`` fault point once per planned
        transfer route, retrying with replica re-routing (attempt ``a``
        re-sources from surviving replica ``a % len(replicas)``) and
        falling back to a raw-file re-scan before declaring a chunk
        degraded.

        Returns ``(drop, ops)``: chunk ids whose payload no source could
        produce (their join pairs are excluded and their query overlap
        becomes a degraded sub-box) plus the operation names whose
        budgets were exhausted. Payloads are checksummed on first sight,
        so corruption faults surface as
        :class:`~repro.faults.errors.ChecksumError` and retry like any
        other transient."""
        drop: set = set()
        ops: List[str] = []
        coord = self.coordinator
        if (self.faults is None or coord is None
                or report.join_plan is None):
            return drop, ops
        cm = {c.chunk_id: c for c in report.queried_chunks}
        for cid, src, dst in report.join_plan.transfer_routes:
            if cid in drop or cid not in cm:
                continue
            payload = coord.chunks.chunk_coords(cid, cm[cid].file_id)
            if payload is not None:
                self.checksums.record(cid, payload)
            reps = sorted(coord.cache.replicas_of(cid)) or [src]

            def attempt(a: int, cid=cid, src=src, dst=dst,
                        payload=payload, reps=reps):
                source = src
                if a > 0 and len(reps) > 1:
                    source = reps[a % len(reps)]
                    if source != src:
                        self._reroutes += 1
                got = self.faults.fault_point(
                    "ship.transfer", payload=payload, chunk=cid,
                    src=source, dst=dst, attempt=a)
                if payload is not None and got is not None:
                    self.checksums.verify(cid, got)
                return got

            try:
                self.retrier.call("ship.transfer", attempt)
            except RetryExhaustedError as e:
                # Every replica route is spent — last resort is a fresh
                # raw-file scan of the chunk's home file.
                try:
                    self.retrier.call(
                        "scan.read",
                        lambda a, cid=cid: self.faults.fault_point(
                            "scan.read", chunk=cid, attempt=a))
                    self._raw_fallbacks += 1
                except RetryExhaustedError as e2:
                    ops.extend([e.op, e2.op])
                    drop.add(cid)
        return drop, ops

    def _arm_join_points(self, n_tasks: int) -> None:
        """Arm the executor's declared fault points (host prep and/or
        kernel dispatch) ahead of a join round; raises
        RetryExhaustedError once a budget is spent. The join compute
        itself is pure, so a retry that re-arms the point without
        re-running the kernel is semantically a redo — the result is
        identical by determinism."""
        for point in getattr(self.executor, "fault_points",
                             ("prep.build", "dispatch.kernel")):
            self.retrier.call(
                point,
                lambda a, point=point: self.faults.fault_point(
                    point, tasks=n_tasks, attempt=a))

    def _guarded_count(self, tasks: List[JoinTask], eps: int
                       ) -> Tuple[List[int], Dict[str, float]]:
        """:meth:`_count_tasks` behind the prep/dispatch fault points
        (a direct pass-through when the faults knob is off)."""
        if self.faults is not None:
            self._arm_join_points(len(tasks))
        return self._count_tasks(tasks, eps)

    def _assemble_degraded(self, query: "SimilarityJoinQuery",
                           report: "QueryReport", drop: set,
                           ship_ops: List[str], join_ops: List[str],
                           matches: Optional[int]
                           ) -> Optional[DegradedResult]:
        """Fold planner-side degradation (scan failures recorded on the
        report), dropped transfer chunks, and whole-join failures into
        one :class:`~repro.faults.retry.DegradedResult`; ``None`` when
        the query completed cleanly."""
        boxes = list(report.degraded_boxes)
        ops: List[str] = list(report.failed_ops) + list(ship_ops)
        cm = {c.chunk_id: c for c in report.queried_chunks}
        for cid in sorted(drop):
            inter = cm[cid].box.intersection(query.box)
            if inter is not None:
                boxes.append(inter)
        if join_ops:
            # The whole join round failed: every queried region is
            # unserved regardless of how its data arrived.
            for c in report.queried_chunks:
                inter = c.box.intersection(query.box)
                if inter is not None:
                    boxes.append(inter)
            ops.extend(join_ops)
        if not boxes and not ops:
            return None
        return make_degraded(query.box, tuple(boxes), tuple(ops),
                             matches or 0)

    # ----------------------------------------------------------- execution

    def _cached_result(self, report: "QueryReport") -> ExecutedQuery:
        """The ExecutedQuery of a result-cache hit: the match count is
        served from the coordinator's versioned result tier and nothing
        is scanned, shipped, or joined — every phase time is zero."""
        return self._record(ExecutedQuery(
            report=report, time_scan_s=0.0, time_net_s=0.0,
            time_compute_s=0.0, time_opt_s=0.0,
            matches=report.cached_matches, backend=self.name,
            **self._resilience_fields(report),
            **self._fault_fields(None)))

    def _measured_ship(self, query: "SimilarityJoinQuery",
                       report: "QueryReport",
                       coords_cache: Dict[int, np.ndarray],
                       skip: Optional[set] = None
                       ) -> Tuple[Optional[float], Optional[int]]:
        """Per-query measured transfer replay: the simulated backend
        moves no real bytes (the mesh backend overrides this with real
        ``jax.device_put`` shipping, skipping ``skip``'s degraded
        chunks)."""
        return None, None

    def _count_tasks(self, tasks: List[JoinTask], eps: int
                     ) -> Tuple[List[int], Dict[str, float]]:
        """Run a task list through the join executor; returns the
        per-task match counts and the executor's dispatch stats."""
        counts = self.executor.count_pairs(tasks, eps)
        return counts, dict(getattr(self.executor, "last_stats", None) or {})

    def execute(self, query: "SimilarityJoinQuery",
                report: "QueryReport") -> ExecutedQuery:
        """Apply the cost model and run the join plan's compute."""
        if report.result_cache_hit:
            return self._cached_result(report)
        time_scan = self.modeled_scan_time(report)
        time_net = self.modeled_net_time(report)

        drop, ship_ops = self._guard_transfers(query, report)
        matches: Optional[int] = None
        stats: Dict[str, float] = {}
        join_ops: List[str] = []
        tasks, work_by_node, _, _ = self.gather_join_tasks(
            query, report, exclude=drop)
        if report.join_plan is not None and self.execute_joins:
            try:
                got, stats = self._guarded_count(tasks, query.eps)
                matches = sum(got)
            except RetryExhaustedError as e:
                join_ops.append(e.op)
                matches = 0
                stats = {}
        time_compute = (max(work_by_node.values(), default=0)
                        / self.cost.cell_pairs_per_sec)

        t_opt = report.opt_time_chunking_s + report.opt_time_evict_place_s
        degraded = self._assemble_degraded(query, report, drop, ship_ops,
                                           join_ops, matches)
        return self._record(ExecutedQuery(
            report=report, time_scan_s=time_scan, time_net_s=time_net,
            time_compute_s=time_compute, time_opt_s=t_opt, matches=matches,
            backend=self.name,
            block_pairs_total=stats.get("block_pairs_total"),
            block_pairs_evaluated=stats.get("block_pairs_evaluated"),
            prep_s=stats.get("prep_s"),
            dispatch_s=stats.get("dispatch_s"),
            artifact_hits=stats.get("artifact_hits"),
            artifact_misses=stats.get("artifact_misses"),
            block_pairs_bitmap_killed=stats.get("block_pairs_bitmap_killed"),
            bitmap_build_s=stats.get("bitmap_build_s"),
            **self._resilience_fields(report),
            **self._fault_fields(degraded)))

    # ----------------------------------- cross-batch MQO (execute_batch)

    @staticmethod
    def _dedup_tasks(gathered: List[Optional[tuple]], eps_list: List[int]
                     ) -> Tuple[List[Tuple[JoinTask, int]],
                                List[Optional[List[int]]],
                                List[Optional[Tuple[int, int, int]]]]:
        """Build the batch's unique-task table: walk every query's tasks
        in admission order, keep the FIRST occurrence of each sharing
        signature (+ eps) as the executed representative, and point
        later subscribers at it. Returns ``(unique, refs, counters)``:
        ``unique`` is the (task, eps) list to execute, ``refs[i]`` maps
        query ``i``'s tasks to unique indices, and ``counters[i]`` is
        its ``(tasks_total, tasks_executed, shared_hits)`` triple
        (``None`` entries mirror result-cache hits, which carry no
        tasks). Signature-less tasks are never shared."""
        unique: List[Tuple[JoinTask, int]] = []
        refs: List[Optional[List[int]]] = []
        counters: List[Optional[Tuple[int, int, int]]] = []
        seen: Dict[tuple, int] = {}
        for g, eps in zip(gathered, eps_list):
            if g is None:
                refs.append(None)
                counters.append(None)
                continue
            tasks, _, _, sigs = g
            my: List[int] = []
            executed = shared = 0
            for task, sig in zip(tasks, sigs):
                key = None if sig is None else (sig, int(eps))
                idx = seen.get(key) if key is not None else None
                if idx is not None:
                    shared += 1
                else:
                    idx = len(unique)
                    unique.append((task, int(eps)))
                    executed += 1
                    if key is not None:
                        seen[key] = idx
                my.append(idx)
            refs.append(my)
            counters.append((len(tasks), executed, shared))
        return unique, refs, counters

    def _execute_unique(self, unique: List[Tuple[JoinTask, int]]
                        ) -> Tuple[List[int], Dict[str, float]]:
        """Execute the deduplicated task table — one dispatch round per
        distinct eps (a batch almost always has one) — and merge the
        executor stats across rounds by summing."""
        counts = [0] * len(unique)
        by_eps: Dict[int, List[int]] = {}
        for idx, (_, eps) in enumerate(unique):
            by_eps.setdefault(eps, []).append(idx)
        merged: Dict[str, float] = {}
        for eps in sorted(by_eps):
            idxs = by_eps[eps]
            got, stats = self._count_tasks([unique[i][0] for i in idxs], eps)
            for i, c in zip(idxs, got):
                counts[i] = int(c)
            for k, v in stats.items():
                if v is not None:
                    merged[k] = merged.get(k, 0) + v
        return counts, merged

    def execute_batch(self, queries: Sequence["SimilarityJoinQuery"],
                      reports: Sequence["QueryReport"]
                      ) -> List[ExecutedQuery]:
        """Execute one admission batch. With ``mqo="off"`` (the seed
        default) this is a per-query :meth:`execute` loop. With
        ``mqo="on"`` the batch's join tasks are deduplicated by sharing
        signature — each distinct ``(chunk_a, chunk_b, subset, eps,
        same)`` task executes exactly once and its match count fans out
        to every subscribing query, so batch kernel work scales with
        *unique* tasks, not query count. Per-query *modeled* phase times
        are unchanged (they describe the plan, keeping MQO-on/off rows
        comparable); the batch-level executor stats (block-pair
        counters, prep/dispatch wall-clock, measured compute) are
        attributed to the batch's last planned query, mirroring how the
        coordinator attributes its per-batch policy-round time."""
        queries = list(queries)
        reports = list(reports)
        if self.mqo != "on":
            return [self.execute(q, r) for q, r in zip(queries, reports)]
        guards = [None if r.result_cache_hit
                  else self._guard_transfers(q, r)
                  for q, r in zip(queries, reports)]
        gathered = [None if g is None
                    else self.gather_join_tasks(q, r, exclude=g[0])
                    for g, q, r in zip(guards, queries, reports)]
        unique, refs, counters = self._dedup_tasks(
            gathered, [q.eps for q in queries])
        counts: List[int] = []
        batch_stats: Dict[str, float] = {}
        batch_failed_op: Optional[str] = None
        if self.execute_joins and unique:
            try:
                if self.faults is not None:
                    self._arm_join_points(len(unique))
                counts, batch_stats = self._execute_unique(unique)
            except RetryExhaustedError as e:
                # The batch's single shared join round failed: every
                # live query is served degraded (zero-count tasks).
                batch_failed_op = e.op
                counts = [0] * len(unique)
                batch_stats = {}
        live = [i for i, g in enumerate(gathered) if g is not None]
        last_live = live[-1] if live else None
        out: List[ExecutedQuery] = []
        for i, (q, r) in enumerate(zip(queries, reports)):
            if gathered[i] is None:
                out.append(self._cached_result(r))
                continue
            drop, ship_ops = guards[i]
            _, work_by_node, coords_cache, _ = gathered[i]
            m_net, m_bytes = self._measured_ship(q, r, coords_cache,
                                                 skip=drop)
            matches: Optional[int] = None
            if r.join_plan is not None and self.execute_joins:
                matches = sum(counts[u] for u in refs[i])
            join_ops = [batch_failed_op] if batch_failed_op else []
            degraded = self._assemble_degraded(q, r, drop, ship_ops,
                                               join_ops, matches)
            stats = batch_stats if i == last_live else {}
            measuring = m_net is not None
            m_compute = (stats.get("measured_compute_s",
                                   0.0 if measuring else None)
                         if measuring else None)
            t_opt = r.opt_time_chunking_s + r.opt_time_evict_place_s
            total, executed, shared = counters[i]
            out.append(self._record(ExecutedQuery(
                report=r, time_scan_s=self.modeled_scan_time(r),
                time_net_s=self.modeled_net_time(r),
                time_compute_s=(max(work_by_node.values(), default=0)
                                / self.cost.cell_pairs_per_sec),
                time_opt_s=t_opt, matches=matches, backend=self.name,
                measured_net_s=m_net, measured_compute_s=m_compute,
                measured_ship_bytes=m_bytes,
                block_pairs_total=stats.get("block_pairs_total"),
                block_pairs_evaluated=stats.get("block_pairs_evaluated"),
                prep_s=stats.get("prep_s"),
                dispatch_s=stats.get("dispatch_s"),
                artifact_hits=stats.get("artifact_hits"),
                artifact_misses=stats.get("artifact_misses"),
                block_pairs_bitmap_killed=stats.get(
                    "block_pairs_bitmap_killed"),
                bitmap_build_s=stats.get("bitmap_build_s"),
                mqo_tasks_total=total, mqo_tasks_executed=executed,
                mqo_shared_hits=shared,
                **self._resilience_fields(r),
                **self._fault_fields(degraded))))
        return out
