"""The §4.1 analytical backend: modeled disk/network, real join compute.

This is the seed :class:`repro.core.cluster.RawArrayCluster` execution
path extracted into the backend seam: the container is one box, so disk
and network phases are charged against the calibrated
:class:`~repro.backend.cost_model.CostModel` while the join predicate
itself runs for real (numpy reference or batched Pallas executor).

The modeled-phase helpers (`modeled_scan_time`, `modeled_net_time`,
`gather_join_tasks`) are shared with
:class:`repro.backend.jax_mesh.JaxMeshBackend`, which reports the same
modeled times alongside its measured ones so the two backends stay
directly comparable.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Tuple

import numpy as np

if TYPE_CHECKING:
    from repro.core.coordinator import (CacheCoordinator, QueryReport,
                                        SimilarityJoinQuery)
from repro.backend.artifacts import ChunkView, JoinArtifactCache
from repro.backend.base import ExecutedQuery
from repro.backend.cost_model import CostModel
from repro.backend.executors import (JoinTask, count_similar_pairs_np,
                                     make_join_executor)


class SimulatedBackend:
    """Cost-modeled execution over one process (the paper's simulator)."""

    name = "simulated"

    def __init__(self, n_nodes: int, cost_model: Optional[CostModel] = None,
                 join_fn: Optional[Callable[..., int]] = None,
                 join_backend: str = "numpy", execute_joins: bool = True,
                 interpret: bool = True, prune: str = "auto"):
        self.n_nodes = n_nodes
        self.cost = cost_model or CostModel()
        self.join_fn = join_fn or count_similar_pairs_np
        self.execute_joins = execute_joins
        self.executor = make_join_executor(join_backend, self.join_fn,
                                           interpret=interpret, prune=prune)
        # The pallas executor owns a JoinArtifactCache; the backend wires
        # its invalidation into CacheState at bind time (the numpy
        # executor has no host prep to memoize — artifacts stays None).
        self.artifacts: Optional[JoinArtifactCache] = getattr(
            self.executor, "artifacts", None)
        self.coordinator: Optional["CacheCoordinator"] = None

    # ------------------------------------------------------------- binding

    def bind(self, coordinator: "CacheCoordinator") -> None:
        """Attach to the coordinator whose plans this backend executes,
        registering the join-artifact cache as a residency listener so
        memoized prep artifacts are invalidated in lockstep with
        eviction and split-remap (they never outlive their chunk)."""
        self.coordinator = coordinator
        if self.artifacts is not None:
            coordinator.cache.add_listener(self.artifacts)

    def _queried_coords(self, chunk_id: int, file_id: int,
                        box) -> np.ndarray:
        """Cell coordinates of a queried unit restricted to the query box."""
        # Imported here: the backend package must not import repro.core at
        # module level (repro.core.cluster imports repro.backend).
        from repro.core.geometry import points_in_box
        coords = self.coordinator.chunks.chunk_coords(chunk_id, file_id)
        return coords[points_in_box(coords, box)]

    # ------------------------------------------------------ modeled phases

    def modeled_scan_time(self, report: "QueryReport") -> float:
        """max_n of disk-scan + format-decode time under the cost model."""
        scan_n: Dict[int, float] = {}
        for node, nbytes in report.scan_bytes_by_node.items():
            scan_n[node] = nbytes / self.cost.disk_bw
        for node, per_fmt in report.decode_cells_by_node.items():
            for fmt, cells in per_fmt.items():
                scan_n[node] = (scan_n.get(node, 0.0)
                                + cells / self.cost.decode_rates[fmt])
        return max(scan_n.values(), default=0.0)

    def modeled_net_time(self, report: "QueryReport") -> float:
        """max_n of full-duplex link time for join shipping + placement
        fallback transfers under the cost model."""
        time_net = 0.0
        if report.join_plan is not None:
            per_node = []
            for n in range(self.n_nodes):
                bi = report.join_plan.bytes_in.get(n, 0)
                bo = report.join_plan.bytes_out.get(n, 0)
                per_node.append(max(bi, bo))
            time_net = max(per_node, default=0) / self.cost.net_bw
        return time_net + report.placement_extra_bytes / self.cost.net_bw

    def gather_join_tasks(self, query: "SimilarityJoinQuery",
                          report: "QueryReport"
                          ) -> Tuple[List[JoinTask], Dict[int, int],
                                     Dict[int, np.ndarray]]:
        """Materialize the plan's chunk-pair work: (tasks, per-node
        cell-pair load, per-chunk queried coordinates).

        With a pallas executor each task side is a
        :class:`~repro.backend.artifacts.ChunkView` keyed by chunk
        identity and queried subset, so the executor's artifact cache
        can memoize host-side prep across queries (numpy tasks stay raw
        arrays — the seed shape).

        A pair with an empty sliced side contributes no matches; under
        the semantic-reuse knob such pairs are skipped before dispatch
        (gated so a custom ``join_fn`` still sees every pair under the
        seed-parity configuration).
        """
        assert self.coordinator is not None, "backend not bound"
        cm = {c.chunk_id: c for c in report.queried_chunks}
        tasks: List[JoinTask] = []
        work_by_node: Dict[int, int] = {}
        coords_cache: Dict[int, np.ndarray] = {}
        views: Dict[int, ChunkView] = {}
        if report.join_plan is None:
            return tasks, work_by_node, coords_cache
        skip_empty = self.coordinator.reuse == "on"
        for (a, b), node in report.join_plan.pair_node.items():
            for cid in (a, b):
                if cid not in coords_cache:
                    coords_cache[cid] = self._queried_coords(
                        cid, cm[cid].file_id, query.box)
            ca, cb = coords_cache[a], coords_cache[b]
            work_by_node[node] = (work_by_node.get(node, 0)
                                  + ca.shape[0] * cb.shape[0])
            if skip_empty and (ca.shape[0] == 0 or cb.shape[0] == 0):
                continue
            if self.artifacts is not None:
                for cid in (a, b):
                    if cid not in views:
                        views[cid] = self.artifacts.view(
                            cid, cm[cid].box, query.box, coords_cache[cid])
                tasks.append((node, views[a], views[b], a == b))
            else:
                tasks.append((node, ca, cb, a == b))
        return tasks, work_by_node, coords_cache

    # ----------------------------------------------------------- execution

    def execute(self, query: "SimilarityJoinQuery",
                report: "QueryReport") -> ExecutedQuery:
        """Apply the cost model and run the join plan's compute."""
        time_scan = self.modeled_scan_time(report)
        time_net = self.modeled_net_time(report)

        matches: Optional[int] = None
        stats = None
        tasks, work_by_node, _ = self.gather_join_tasks(query, report)
        if report.join_plan is not None and self.execute_joins:
            matches = sum(self.executor.count_pairs(tasks, query.eps))
            stats = getattr(self.executor, "last_stats", None)
        time_compute = (max(work_by_node.values(), default=0)
                        / self.cost.cell_pairs_per_sec)

        t_opt = report.opt_time_chunking_s + report.opt_time_evict_place_s
        stats = stats or {}
        return ExecutedQuery(report=report, time_scan_s=time_scan,
                             time_net_s=time_net,
                             time_compute_s=time_compute,
                             time_opt_s=t_opt, matches=matches,
                             backend=self.name,
                             block_pairs_total=stats.get("block_pairs_total"),
                             block_pairs_evaluated=stats.get(
                                 "block_pairs_evaluated"),
                             prep_s=stats.get("prep_s"),
                             dispatch_s=stats.get("dispatch_s"),
                             artifact_hits=stats.get("artifact_hits"),
                             artifact_misses=stats.get("artifact_misses"))
