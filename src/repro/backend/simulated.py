"""The §4.1 analytical backend: modeled disk/network, real join compute.

This is the seed :class:`repro.core.cluster.RawArrayCluster` execution
path extracted into the backend seam: the container is one box, so disk
and network phases are charged against the calibrated
:class:`~repro.backend.cost_model.CostModel` while the join predicate
itself runs for real (numpy reference or batched Pallas executor).

The modeled-phase helpers (`modeled_scan_time`, `modeled_net_time`,
`gather_join_tasks`) are shared with
:class:`repro.backend.jax_mesh.JaxMeshBackend`, which reports the same
modeled times alongside its measured ones so the two backends stay
directly comparable.
"""
from __future__ import annotations

from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Sequence,
                    Tuple)

import numpy as np

if TYPE_CHECKING:
    from repro.core.coordinator import (CacheCoordinator, QueryReport,
                                        SimilarityJoinQuery)
from repro.backend.artifacts import (ChunkView, JoinArtifactCache,
                                     subset_token)
from repro.backend.base import ExecutedQuery, record_executed
from repro.backend.cost_model import CostModel
from repro.backend.executors import (JoinTask, count_similar_pairs_np,
                                     make_join_executor)
from repro.obs.telemetry import NULL_TELEMETRY, Telemetry

# Cross-batch multi-query optimization knob: "off" preserves the seed
# per-query execution exactly; "on" deduplicates join tasks by sharing
# signature across each admission batch (execute once, fan counts out).
MQO_MODES = ("off", "on")


class SimulatedBackend:
    """Cost-modeled execution over one process (the paper's simulator)."""

    name = "simulated"

    def __init__(self, n_nodes: int, cost_model: Optional[CostModel] = None,
                 join_fn: Optional[Callable[..., int]] = None,
                 join_backend: str = "numpy", execute_joins: bool = True,
                 interpret: bool = True, prune: str = "auto",
                 mqo: str = "off"):
        if mqo not in MQO_MODES:
            raise ValueError(f"unknown mqo mode {mqo!r}; "
                             f"expected one of {MQO_MODES}")
        self.n_nodes = n_nodes
        self.cost = cost_model or CostModel()
        self.join_fn = join_fn or count_similar_pairs_np
        self.execute_joins = execute_joins
        self.mqo = mqo
        self.executor = make_join_executor(join_backend, self.join_fn,
                                           interpret=interpret, prune=prune)
        # The pallas executor owns a JoinArtifactCache; the backend wires
        # its invalidation into CacheState at bind time (the numpy
        # executor has no host prep to memoize — artifacts stays None).
        self.artifacts: Optional[JoinArtifactCache] = getattr(
            self.executor, "artifacts", None)
        self.coordinator: Optional["CacheCoordinator"] = None
        # Replaced with the coordinator's telemetry bundle at bind time;
        # the no-op default keeps an unbound backend span/metric-free.
        self.telemetry: Telemetry = NULL_TELEMETRY

    # ------------------------------------------------------------- binding

    def bind(self, coordinator: "CacheCoordinator") -> None:
        """Attach to the coordinator whose plans this backend executes,
        registering the join-artifact cache as a residency listener so
        memoized prep artifacts are invalidated in lockstep with
        eviction and split-remap (they never outlive their chunk). The
        coordinator's telemetry bundle is adopted here, and its tracer
        handed to the join executor (prep/dispatch spans)."""
        self.coordinator = coordinator
        self.telemetry = coordinator.telemetry
        if self.telemetry.enabled:
            self.executor.tracer = self.telemetry.tracer
        if self.artifacts is not None:
            coordinator.cache.add_listener(self.artifacts)

    def _record(self, eq: ExecutedQuery) -> ExecutedQuery:
        """Mirror a freshly built ExecutedQuery into the live metrics
        registry (every construction site funnels through here, so
        registry totals equal ``workload_summary`` by construction);
        a no-op with telemetry off."""
        if self.telemetry.enabled:
            record_executed(self.telemetry.registry, eq)
        return eq

    def _queried_coords(self, chunk_id: int, file_id: int,
                        box) -> np.ndarray:
        """Cell coordinates of a queried unit restricted to the query box."""
        # Imported here: the backend package must not import repro.core at
        # module level (repro.core.cluster imports repro.backend).
        from repro.core.geometry import points_in_box
        coords = self.coordinator.chunks.chunk_coords(chunk_id, file_id)
        return coords[points_in_box(coords, box)]

    # ------------------------------------------------------ modeled phases

    def modeled_scan_time(self, report: "QueryReport") -> float:
        """max_n of disk-scan + format-decode time under the cost model."""
        scan_n: Dict[int, float] = {}
        for node, nbytes in report.scan_bytes_by_node.items():
            scan_n[node] = nbytes / self.cost.disk_bw
        for node, per_fmt in report.decode_cells_by_node.items():
            for fmt, cells in per_fmt.items():
                scan_n[node] = (scan_n.get(node, 0.0)
                                + cells / self.cost.decode_rates[fmt])
        return max(scan_n.values(), default=0.0)

    def modeled_net_time(self, report: "QueryReport") -> float:
        """max_n of full-duplex link time for join shipping + placement
        fallback transfers under the cost model."""
        time_net = 0.0
        if report.join_plan is not None:
            per_node = []
            for n in range(self.n_nodes):
                bi = report.join_plan.bytes_in.get(n, 0)
                bo = report.join_plan.bytes_out.get(n, 0)
                per_node.append(max(bi, bo))
            time_net = max(per_node, default=0) / self.cost.net_bw
        return time_net + report.placement_extra_bytes / self.cost.net_bw

    def gather_join_tasks(self, query: "SimilarityJoinQuery",
                          report: "QueryReport"
                          ) -> Tuple[List[JoinTask], Dict[int, int],
                                     Dict[int, np.ndarray], List[
                                         Optional[tuple]]]:
        """Materialize the plan's chunk-pair work: (tasks, per-node
        cell-pair load, per-chunk queried coordinates, per-task sharing
        signatures).

        With a pallas executor each task side is a
        :class:`~repro.backend.artifacts.ChunkView` keyed by chunk
        identity and queried subset, so the executor's artifact cache
        can memoize host-side prep across queries (numpy tasks stay raw
        arrays — the seed shape).

        The signature list runs parallel to ``tasks``: each entry is
        ``((a, subset_a), (b, subset_b), same)`` built from
        :func:`~repro.backend.artifacts.subset_token` — the
        content-addressed identity of the task's computation, which is
        what cross-batch MQO deduplicates on (``None`` marks an
        unshareable task). Signatures are derived for *every* executor
        (the numpy path has no ChunkViews but shares identically).

        A pair with an empty sliced side contributes no matches; under
        the semantic-reuse knob such pairs are skipped before dispatch
        (gated so a custom ``join_fn`` still sees every pair under the
        seed-parity configuration).
        """
        if self.coordinator is None:
            raise RuntimeError("backend not bound — call bind() first")
        cm = {c.chunk_id: c for c in report.queried_chunks}
        tasks: List[JoinTask] = []
        sigs: List[Optional[tuple]] = []
        work_by_node: Dict[int, int] = {}
        coords_cache: Dict[int, np.ndarray] = {}
        views: Dict[int, ChunkView] = {}
        tokens: Dict[int, Optional[tuple]] = {}
        if report.join_plan is None:
            return tasks, work_by_node, coords_cache, sigs
        skip_empty = self.coordinator.reuse == "on"
        for (a, b), node in report.join_plan.pair_node.items():
            for cid in (a, b):
                if cid not in coords_cache:
                    coords_cache[cid] = self._queried_coords(
                        cid, cm[cid].file_id, query.box)
                    tokens[cid] = subset_token(cm[cid].box, query.box)
            ca, cb = coords_cache[a], coords_cache[b]
            work_by_node[node] = (work_by_node.get(node, 0)
                                  + ca.shape[0] * cb.shape[0])
            if skip_empty and (ca.shape[0] == 0 or cb.shape[0] == 0):
                continue
            ta, tb = tokens[a], tokens[b]
            sigs.append(None if ta is None or tb is None
                        else ((a, ta), (b, tb), a == b))
            if self.artifacts is not None:
                for cid in (a, b):
                    if cid not in views:
                        views[cid] = self.artifacts.view(
                            cid, cm[cid].box, query.box, coords_cache[cid])
                tasks.append((node, views[a], views[b], a == b))
            else:
                tasks.append((node, ca, cb, a == b))
        return tasks, work_by_node, coords_cache, sigs

    # ------------------------------------------- failure / replication

    def fail_node(self, node: int) -> Dict[str, float]:
        """Simulate a crash-restart of one node: every cached copy it
        held is lost and the coordinator immediately re-admits what it
        can — cheaply from surviving replicas, else by re-scanning raw
        files. Returns the recovery event's counters (also attached to
        the next ExecutedQuery)."""
        if self.coordinator is None:
            raise RuntimeError("backend not bound — call bind() first")
        return self.coordinator.fail_node(node)

    def _resilience_fields(self, report: "QueryReport") -> Dict[str, object]:
        """Replication/failover counter fields for one ExecutedQuery:
        per-query replica hits plus the coordinator's pending
        round/recovery counters (drained here, so each event is
        attributed to exactly one query — the first executed after it).
        Empty when replication is off and no failure occurred, keeping
        the single-copy ExecutedQuery bit-identical to the seed's."""
        out: Dict[str, object] = {}
        coord = self.coordinator
        if coord is None:
            return out
        pending = coord.drain_exec_counters()
        if coord.replication != "off":
            jp = report.join_plan
            out["replica_hits"] = (int(jp.replica_hits)
                                   if jp is not None else 0)
            out["replicas_dropped"] = int(pending.get("replicas_dropped", 0))
        if "failover_readmits" in pending:
            out["failover_readmits"] = int(pending["failover_readmits"])
            out["recovery_bytes_from_replica"] = int(
                pending.get("recovery_bytes_from_replica", 0))
            out["recovery_bytes_from_raw"] = int(
                pending.get("recovery_bytes_from_raw", 0))
            out["recovery_s"] = float(pending.get("recovery_s", 0.0))
        return out

    # ----------------------------------------------------------- execution

    def _cached_result(self, report: "QueryReport") -> ExecutedQuery:
        """The ExecutedQuery of a result-cache hit: the match count is
        served from the coordinator's versioned result tier and nothing
        is scanned, shipped, or joined — every phase time is zero."""
        return self._record(ExecutedQuery(
            report=report, time_scan_s=0.0, time_net_s=0.0,
            time_compute_s=0.0, time_opt_s=0.0,
            matches=report.cached_matches, backend=self.name,
            **self._resilience_fields(report)))

    def _measured_ship(self, query: "SimilarityJoinQuery",
                       report: "QueryReport",
                       coords_cache: Dict[int, np.ndarray]
                       ) -> Tuple[Optional[float], Optional[int]]:
        """Per-query measured transfer replay: the simulated backend
        moves no real bytes (the mesh backend overrides this with real
        ``jax.device_put`` shipping)."""
        return None, None

    def _count_tasks(self, tasks: List[JoinTask], eps: int
                     ) -> Tuple[List[int], Dict[str, float]]:
        """Run a task list through the join executor; returns the
        per-task match counts and the executor's dispatch stats."""
        counts = self.executor.count_pairs(tasks, eps)
        return counts, dict(getattr(self.executor, "last_stats", None) or {})

    def execute(self, query: "SimilarityJoinQuery",
                report: "QueryReport") -> ExecutedQuery:
        """Apply the cost model and run the join plan's compute."""
        if report.result_cache_hit:
            return self._cached_result(report)
        time_scan = self.modeled_scan_time(report)
        time_net = self.modeled_net_time(report)

        matches: Optional[int] = None
        stats = None
        tasks, work_by_node, _, _ = self.gather_join_tasks(query, report)
        if report.join_plan is not None and self.execute_joins:
            matches = sum(self.executor.count_pairs(tasks, query.eps))
            stats = getattr(self.executor, "last_stats", None)
        time_compute = (max(work_by_node.values(), default=0)
                        / self.cost.cell_pairs_per_sec)

        t_opt = report.opt_time_chunking_s + report.opt_time_evict_place_s
        stats = stats or {}
        return self._record(ExecutedQuery(
            report=report, time_scan_s=time_scan, time_net_s=time_net,
            time_compute_s=time_compute, time_opt_s=t_opt, matches=matches,
            backend=self.name,
            block_pairs_total=stats.get("block_pairs_total"),
            block_pairs_evaluated=stats.get("block_pairs_evaluated"),
            prep_s=stats.get("prep_s"),
            dispatch_s=stats.get("dispatch_s"),
            artifact_hits=stats.get("artifact_hits"),
            artifact_misses=stats.get("artifact_misses"),
            block_pairs_bitmap_killed=stats.get("block_pairs_bitmap_killed"),
            bitmap_build_s=stats.get("bitmap_build_s"),
            **self._resilience_fields(report)))

    # ----------------------------------- cross-batch MQO (execute_batch)

    @staticmethod
    def _dedup_tasks(gathered: List[Optional[tuple]], eps_list: List[int]
                     ) -> Tuple[List[Tuple[JoinTask, int]],
                                List[Optional[List[int]]],
                                List[Optional[Tuple[int, int, int]]]]:
        """Build the batch's unique-task table: walk every query's tasks
        in admission order, keep the FIRST occurrence of each sharing
        signature (+ eps) as the executed representative, and point
        later subscribers at it. Returns ``(unique, refs, counters)``:
        ``unique`` is the (task, eps) list to execute, ``refs[i]`` maps
        query ``i``'s tasks to unique indices, and ``counters[i]`` is
        its ``(tasks_total, tasks_executed, shared_hits)`` triple
        (``None`` entries mirror result-cache hits, which carry no
        tasks). Signature-less tasks are never shared."""
        unique: List[Tuple[JoinTask, int]] = []
        refs: List[Optional[List[int]]] = []
        counters: List[Optional[Tuple[int, int, int]]] = []
        seen: Dict[tuple, int] = {}
        for g, eps in zip(gathered, eps_list):
            if g is None:
                refs.append(None)
                counters.append(None)
                continue
            tasks, _, _, sigs = g
            my: List[int] = []
            executed = shared = 0
            for task, sig in zip(tasks, sigs):
                key = None if sig is None else (sig, int(eps))
                idx = seen.get(key) if key is not None else None
                if idx is not None:
                    shared += 1
                else:
                    idx = len(unique)
                    unique.append((task, int(eps)))
                    executed += 1
                    if key is not None:
                        seen[key] = idx
                my.append(idx)
            refs.append(my)
            counters.append((len(tasks), executed, shared))
        return unique, refs, counters

    def _execute_unique(self, unique: List[Tuple[JoinTask, int]]
                        ) -> Tuple[List[int], Dict[str, float]]:
        """Execute the deduplicated task table — one dispatch round per
        distinct eps (a batch almost always has one) — and merge the
        executor stats across rounds by summing."""
        counts = [0] * len(unique)
        by_eps: Dict[int, List[int]] = {}
        for idx, (_, eps) in enumerate(unique):
            by_eps.setdefault(eps, []).append(idx)
        merged: Dict[str, float] = {}
        for eps in sorted(by_eps):
            idxs = by_eps[eps]
            got, stats = self._count_tasks([unique[i][0] for i in idxs], eps)
            for i, c in zip(idxs, got):
                counts[i] = int(c)
            for k, v in stats.items():
                if v is not None:
                    merged[k] = merged.get(k, 0) + v
        return counts, merged

    def execute_batch(self, queries: Sequence["SimilarityJoinQuery"],
                      reports: Sequence["QueryReport"]
                      ) -> List[ExecutedQuery]:
        """Execute one admission batch. With ``mqo="off"`` (the seed
        default) this is a per-query :meth:`execute` loop. With
        ``mqo="on"`` the batch's join tasks are deduplicated by sharing
        signature — each distinct ``(chunk_a, chunk_b, subset, eps,
        same)`` task executes exactly once and its match count fans out
        to every subscribing query, so batch kernel work scales with
        *unique* tasks, not query count. Per-query *modeled* phase times
        are unchanged (they describe the plan, keeping MQO-on/off rows
        comparable); the batch-level executor stats (block-pair
        counters, prep/dispatch wall-clock, measured compute) are
        attributed to the batch's last planned query, mirroring how the
        coordinator attributes its per-batch policy-round time."""
        queries = list(queries)
        reports = list(reports)
        if self.mqo != "on":
            return [self.execute(q, r) for q, r in zip(queries, reports)]
        gathered = [None if r.result_cache_hit
                    else self.gather_join_tasks(q, r)
                    for q, r in zip(queries, reports)]
        unique, refs, counters = self._dedup_tasks(
            gathered, [q.eps for q in queries])
        counts: List[int] = []
        batch_stats: Dict[str, float] = {}
        if self.execute_joins and unique:
            counts, batch_stats = self._execute_unique(unique)
        live = [i for i, g in enumerate(gathered) if g is not None]
        last_live = live[-1] if live else None
        out: List[ExecutedQuery] = []
        for i, (q, r) in enumerate(zip(queries, reports)):
            if gathered[i] is None:
                out.append(self._cached_result(r))
                continue
            _, work_by_node, coords_cache, _ = gathered[i]
            m_net, m_bytes = self._measured_ship(q, r, coords_cache)
            matches: Optional[int] = None
            if r.join_plan is not None and self.execute_joins:
                matches = sum(counts[u] for u in refs[i])
            stats = batch_stats if i == last_live else {}
            measuring = m_net is not None
            m_compute = (stats.get("measured_compute_s",
                                   0.0 if measuring else None)
                         if measuring else None)
            t_opt = r.opt_time_chunking_s + r.opt_time_evict_place_s
            total, executed, shared = counters[i]
            out.append(self._record(ExecutedQuery(
                report=r, time_scan_s=self.modeled_scan_time(r),
                time_net_s=self.modeled_net_time(r),
                time_compute_s=(max(work_by_node.values(), default=0)
                                / self.cost.cell_pairs_per_sec),
                time_opt_s=t_opt, matches=matches, backend=self.name,
                measured_net_s=m_net, measured_compute_s=m_compute,
                measured_ship_bytes=m_bytes,
                block_pairs_total=stats.get("block_pairs_total"),
                block_pairs_evaluated=stats.get("block_pairs_evaluated"),
                prep_s=stats.get("prep_s"),
                dispatch_s=stats.get("dispatch_s"),
                artifact_hits=stats.get("artifact_hits"),
                artifact_misses=stats.get("artifact_misses"),
                block_pairs_bitmap_killed=stats.get(
                    "block_pairs_bitmap_killed"),
                bitmap_build_s=stats.get("bitmap_build_s"),
                mqo_tasks_total=total, mqo_tasks_executed=executed,
                mqo_shared_hits=shared,
                **self._resilience_fields(r))))
        return out
