from repro.configs.base import MambaConfig, ModelConfig, MoEConfig
from repro.configs.registry import ARCHS, get, list_archs, reduced
from repro.configs.shapes import (SHAPES, SHAPES_BY_NAME, ShapeConfig,
                                  cells_for, shape_applicable)

__all__ = ["MambaConfig", "ModelConfig", "MoEConfig", "ARCHS", "get",
           "list_archs", "reduced", "SHAPES", "SHAPES_BY_NAME",
           "ShapeConfig", "cells_for", "shape_applicable"]
