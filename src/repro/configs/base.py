"""Model configuration schema shared by all ten assigned architectures.

A model is a stack of ``n_layers`` blocks described by a repeating
``layer_pattern`` of (mixer, mlp) pairs — the *period*. Scan-over-layers
iterates periods (keeps HLO size O(period), compile time flat in depth):

  mixer ∈ {"attn", "mamba", "slstm", "mlstm"}
  mlp   ∈ {"dense", "moe", "none"}

Dense transformers have pattern ``(("attn","dense"),)``; Jamba's 1:7
attention:Mamba interleave with MoE every other layer is an 8-entry pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

Mixer = str
Mlp = str


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0            # always-on shared experts (DeepSeekMoE)
    d_expert: int = 0            # expert FFN width (0 -> use d_ff)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | audio | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    layer_pattern: Tuple[Tuple[Mixer, Mlp], ...] = (("attn", "dense"),)
    head_dim: int = 0            # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"     # swiglu | relu2 | gelu
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm | nonparam_ln
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    max_seq_len: int = 32_768
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    frontend: Optional[str] = None      # None | audio_frames | vision_patches
    encoder_only: bool = False
    # xLSTM block projection factor (mLSTM up-projection, paper uses 2).
    xlstm_proj_factor: float = 2.0
    # mLSTM execution: "auto" (chunkwise for S >= 128), "chunkwise",
    # "sequential" (the pre-hillclimb baseline; see EXPERIMENTS.md §Perf A1).
    mlstm_impl: str = "auto"
    # MoE dispatch: "sort" (scatter/gather slots) or "einsum" (GShard
    # one-hot baseline; see EXPERIMENTS.md §Perf B1).
    moe_dispatch: str = "sort"
    # Decode KV-cache write: "scatter" (indexed, in-place) or "onehot"
    # (baseline full-cache blend; see EXPERIMENTS.md §Perf C1).
    kv_update: str = "scatter"
    # Megatron-style sequence parallelism: constrain the residual stream's
    # sequence axis onto the TP mesh axis between blocks, so norms/residual
    # traffic shard 1/TP and the TP all-reduce splits into RS+AG
    # (EXPERIMENTS.md §Perf D). Requires a mesh with a "model" axis.
    seq_parallel: bool = False
    # Notes for DESIGN.md §Arch-applicability (free text, not used by code).
    notes: str = ""

    def __post_init__(self):
        if self.n_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern period {len(self.layer_pattern)}")
        for mixer, mlp in self.layer_pattern:
            if mixer not in ("attn", "mamba", "slstm", "mlstm"):
                raise ValueError(f"unknown mixer {mixer!r}")
            if mlp not in ("dense", "moe", "none"):
                raise ValueError(f"unknown mlp {mlp!r}")
            if mlp == "moe" and self.moe is None:
                raise ValueError(f"{self.name}: moe block without MoEConfig")
            if mixer == "mamba" and self.mamba is None:
                raise ValueError(f"{self.name}: mamba block without MambaConfig")

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_expert_resolved(self) -> int:
        assert self.moe is not None
        return self.moe.d_expert or self.d_ff

    @property
    def uses_attention(self) -> bool:
        return any(m == "attn" for m, _ in self.layer_pattern)

    @property
    def subquadratic(self) -> bool:
        """True if no full-attention *training* path is quadratic in seq —
        i.e. the long_500k shape is runnable (SSM / hybrid archs)."""
        return all(m != "attn" for m, _ in self.layer_pattern) or \
            self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs and memory
        budgeting in the roofline report)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        q_dim = self.n_heads * hd
        kv_dim = self.n_kv_heads * hd
        total = v * d                       # embed
        if not self.tie_embeddings and not self.encoder_only:
            total += v * d                  # unembed
        if self.encoder_only:
            total += d * v                  # output head
        per_pattern = []
        for mixer, mlp in self.layer_pattern:
            p = 0
            if mixer == "attn":
                p += d * (q_dim + 2 * kv_dim) + q_dim * d
                if self.qkv_bias:
                    p += q_dim + 2 * kv_dim
            elif mixer == "mamba":
                assert self.mamba is not None
                di = self.mamba.expand * d
                p += d * 2 * di                    # in_proj (x and z)
                p += di * self.mamba.d_conv        # conv
                p += di * (self.mamba.d_state * 2 + 1)   # B, C, dt proj (approx)
                p += di * self.mamba.d_state       # A
                p += di * d                        # out_proj
            elif mixer in ("slstm", "mlstm"):
                dp = int(self.xlstm_proj_factor * d)
                p += d * dp * 2 + dp * d           # up (x2) + down
                p += 4 * dp * dp if mixer == "slstm" else 3 * dp * dp
            if mlp == "dense":
                mult = 3 if self.mlp_type == "swiglu" else 2
                p += mult * d * dff
            elif mlp == "moe":
                assert self.moe is not None
                de = self.d_expert_resolved
                mult = 3 if self.mlp_type == "swiglu" else 2
                p += (self.moe.n_experts + self.moe.n_shared) * mult * d * de
                p += d * self.moe.n_experts        # router
            p += 2 * d if self.norm_type != "nonparam_ln" else 0  # norms
            per_pattern.append(p)
        total += self.n_periods * sum(per_pattern)
        total += d if self.norm_type != "nonparam_ln" else 0      # final norm
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        de = self.d_expert_resolved
        mult = 3 if self.mlp_type == "swiglu" else 2
        n_moe_layers = self.n_periods * sum(
            1 for _, mlp in self.layer_pattern if mlp == "moe")
        all_e = n_moe_layers * self.moe.n_experts * mult * self.d_model * de
        act_e = n_moe_layers * (self.moe.top_k + self.moe.n_shared) * \
            mult * self.d_model * de
        shared = n_moe_layers * self.moe.n_shared * mult * self.d_model * de
        return full - (all_e + shared) + act_e
