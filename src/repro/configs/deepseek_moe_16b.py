"""deepseek-moe-16b — MoE, 28L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=102400, 2 shared + 64 routed top-6 fine-grained [arXiv:2401.06066].

Deviation noted in DESIGN.md: the HF model uses a dense first layer
(d_ff=10944); we apply the MoE pattern uniformly so the period stays 1."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    layer_pattern=(("attn", "moe"),),
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
    notes="fine-grained experts (d_expert=1408), 2 shared always-on.",
)
