"""hubert-xlarge — audio, encoder-only, 48L d_model=1280 16H d_ff=5120
vocab=504 (masked-unit prediction targets) [arXiv:2106.07447].

The CNN waveform frontend is a stub: ``input_specs`` provides precomputed
frame embeddings (B, S, d_model), per the assignment."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    layer_pattern=(("attn", "dense"),),
    mlp_type="gelu",
    norm_type="layernorm",
    encoder_only=True,
    frontend="audio_frames",
    notes="encoder-only (bidirectional attention); no decode shapes.",
)
