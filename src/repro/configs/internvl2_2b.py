"""internvl2-2b — VLM, 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553: InternViT frontend (stub) + InternLM2-1.8B-style decoder
[arXiv:2404.16821].

The vision tower is a stub per the assignment: ``input_specs`` supplies
projector-output patch embeddings (B, 256, d_model) prepended to the text."""
from repro.configs.base import ModelConfig

N_PATCHES = 256

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    layer_pattern=(("attn", "dense"),),
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    notes="decoder backbone only; 256 patch embeddings prepended.",
)
