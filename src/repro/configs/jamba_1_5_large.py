"""jamba-1.5-large-398b — hybrid, 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, Mamba:attention 7:1 interleave, MoE 16e top-2 every
other layer [arXiv:2403.19887]."""
from repro.configs.base import ModelConfig, MambaConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    # Jamba period: 8 layers = 7 Mamba + 1 attention (index 3), MoE on odd.
    layer_pattern=(
        ("mamba", "dense"), ("mamba", "moe"),
        ("mamba", "dense"), ("attn", "moe"),
        ("mamba", "dense"), ("mamba", "moe"),
        ("mamba", "dense"), ("mamba", "moe"),
    ),
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_expert=24_576),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    notes="hybrid: KV cache only for 1-in-8 layers; long_500k runnable "
          "(attention KV sharded over sequence, Mamba state O(1)).",
)
