"""nemotron-4-340b — dense, 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU MLP [arXiv:2402.16819]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73_728,
    vocab_size=256_000,
    layer_pattern=(("attn", "dense"),),
    mlp_type="relu2",
    norm_type="layernorm",
    rope_theta=10_000.0,
    notes="squared-ReLU MLP (no gate); GQA kv=8; largest dense arch.",
)
