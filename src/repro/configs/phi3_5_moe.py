"""phi3.5-moe-42b-a6.6b — MoE, 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32_064,
    layer_pattern=(("attn", "moe"),),
    mlp_type="swiglu",
    norm_type="layernorm",
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_expert=6400),
    notes="16 experts top-2, no shared experts.",
)
