"""Architecture registry: ``--arch <id>`` resolution and reduced (smoke)
variants that preserve each architecture's structure."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import MambaConfig, ModelConfig, MoEConfig
from repro.configs.qwen1_5_0_5b import CONFIG as QWEN
from repro.configs.nemotron_4_340b import CONFIG as NEMOTRON
from repro.configs.olmo_1b import CONFIG as OLMO
from repro.configs.llama3_2_3b import CONFIG as LLAMA
from repro.configs.deepseek_moe_16b import CONFIG as DEEPSEEK
from repro.configs.phi3_5_moe import CONFIG as PHI
from repro.configs.xlstm_125m import CONFIG as XLSTM
from repro.configs.hubert_xlarge import CONFIG as HUBERT
from repro.configs.jamba_1_5_large import CONFIG as JAMBA
from repro.configs.internvl2_2b import CONFIG as INTERNVL

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in (
    QWEN, NEMOTRON, OLMO, LLAMA, DEEPSEEK, PHI, XLSTM, HUBERT, JAMBA,
    INTERNVL)}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> List[str]:
    return sorted(ARCHS)


def reduced(cfg: ModelConfig, d_model: int = 64, n_periods: int = 2,
            vocab: int = 256) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving family, pattern,
    norm/mlp kinds, bias flags, and GQA ratio."""
    ratio = max(1, cfg.n_heads // cfg.n_kv_heads)
    n_heads = 4
    n_kv = max(1, n_heads // ratio)
    moe = None
    if cfg.moe is not None:
        # capacity_factor high enough that smoke tests never drop tokens —
        # keeps teacher-forced decode bit-consistent with parallel forward.
        moe = MoEConfig(n_experts=min(8, cfg.moe.n_experts),
                        top_k=min(2, cfg.moe.top_k),
                        n_shared=min(1, cfg.moe.n_shared),
                        d_expert=d_model * 2 if cfg.moe.d_expert else 0,
                        capacity_factor=8.0)
    mamba = MambaConfig(d_state=8, d_conv=4, expand=2) if cfg.mamba else None
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_periods * cfg.period,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=0,
        d_ff=d_model * 4 if cfg.d_ff else 0,
        vocab_size=vocab,
        max_seq_len=512,
        moe=moe,
        mamba=mamba,
    )
