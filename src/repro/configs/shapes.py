"""Assigned input shapes and per-(arch x shape) applicability rules.

  train_4k     seq 4,096   global_batch 256   lowers train_step
  prefill_32k  seq 32,768  global_batch 32    lowers prefill (forward)
  decode_32k   seq 32,768  global_batch 128   lowers serve_step (1 token, KV=seq)
  long_500k    seq 524,288 global_batch 1     lowers serve_step; sub-quadratic only

Skips follow the assignment rules (DESIGN.md §Shape skips): encoder-only
archs have no decode; long_500k runs only for SSM/hybrid archs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig
                     ) -> Tuple[bool, Optional[str]]:
    """Returns (runnable, skip_reason)."""
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only arch: no autoregressive decode step"
    if shape.name == "long_500k":
        if cfg.family not in ("ssm", "hybrid"):
            return False, ("pure full-attention arch: long_500k requires "
                           "sub-quadratic sequence mixing")
    return True, None


def cells_for(cfg: ModelConfig):
    """All (shape, runnable, reason) cells for an architecture."""
    return [(s,) + shape_applicable(cfg, s) for s in SHAPES]
