"""xlstm-125m — SSM family, 12L d_model=768 4H vocab=50304, sLSTM + mLSTM
blocks (d_ff=0: projections live inside the xLSTM blocks)
[arXiv:2405.04517]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    # 3:1 mLSTM:sLSTM interleave; no separate FFN (pattern mlp='none').
    layer_pattern=(("mlstm", "none"), ("mlstm", "none"),
                   ("mlstm", "none"), ("slstm", "none")),
    mlp_type="gelu",
    norm_type="layernorm",
    tie_embeddings=True,
    xlstm_proj_factor=2.0,
    notes="attention-free; O(1) decode state; long_500k runnable.",
)
