"""Core of the paper: distributed cost-based caching for raw arrays.

Public API:
  * geometry.Box — integer hyper-rectangles
  * rtree.EvolvingRTree — query-driven chunking (Alg. 1)
  * eviction.cost_based_eviction — Alg. 2 (+ LRUCache baselines)
  * placement.cost_based_placement — Alg. 3 (+ static baseline)
  * coordinator.CacheCoordinator — the Figure-2 planning pipeline
  * cluster.RawArrayCluster — simulated shared-nothing execution + cost model
  * workload — PTF-1 / PTF-2 / GEO query generators
"""
from repro.core.geometry import Box, bounding_box, expand
from repro.core.chunk import Chunk, ChunkMeta, FileMeta
from repro.core.rtree import EvolvingRTree, RefineStats
from repro.core.eviction import (LRUCache, Triple, EvictionResult,
                                 cost_based_eviction)
from repro.core.placement import (JoinRecord, PlacementResult,
                                  cost_based_placement, static_placement)
from repro.core.join_planner import JoinPlan, candidate_pairs, plan_join
from repro.core.coordinator import (CacheCoordinator, QueryReport,
                                    SimilarityJoinQuery)
from repro.core.cluster import (CostModel, ExecutedQuery, RawArrayCluster,
                                count_similar_pairs_np, workload_summary)

__all__ = [
    "Box", "bounding_box", "expand", "Chunk", "ChunkMeta", "FileMeta",
    "EvolvingRTree", "RefineStats", "LRUCache", "Triple", "EvictionResult",
    "cost_based_eviction", "JoinRecord", "PlacementResult",
    "cost_based_placement", "static_placement", "JoinPlan",
    "candidate_pairs", "plan_join", "CacheCoordinator", "QueryReport",
    "SimilarityJoinQuery", "CostModel", "ExecutedQuery", "RawArrayCluster",
    "count_similar_pairs_np", "workload_summary",
]
