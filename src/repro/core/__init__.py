"""Core of the paper: distributed cost-based caching for raw arrays,
grown into a layered planning engine.

Public API by layer:
  * geometry.Box — integer hyper-rectangles (+ box_subtract residuals)
  * rtree.EvolvingRTree — query-driven chunking (Alg. 1)
  * chunk_manager.ChunkManager — chunk lifecycle, split remap, size tables
  * cache_state.CacheState — residency, locations, budget scopes
  * coverage.CoverageIndex — semantic cache reuse: covered-extent index
    and query rewrite (covered slices + residual region)
  * eviction.cost_based_eviction — Alg. 2 (+ LRU/LFU cache structures)
  * placement.cost_based_placement — Alg. 3 (+ static baseline)
  * policies — EvictionPolicy/PlacementPolicy protocols + combo registry
  * coordinator.CacheCoordinator — the Figure-2 pipeline; batched admission
  * cluster.RawArrayCluster — shared-nothing execution façade over the
    pluggable backends in ``repro.backend`` (simulated §4.1 cost model,
    or a real jax device mesh with measured transfers + Pallas joins)
  * workload — PTF-1 / PTF-2 / GEO query generators
"""
from repro.core.geometry import (Box, bounding_box, box_subtract, expand,
                                 residual_boxes)
from repro.core.chunk import Chunk, ChunkMeta, FileMeta
from repro.core.rtree import EvolvingRTree, RefineStats
from repro.core.chunk_manager import ChunkManager
from repro.core.cache_state import CacheState
from repro.core.coverage import CoverageIndex, CoveredSlice, QueryRewrite
from repro.core.eviction import (LFUCache, LRUCache, Triple, EvictionResult,
                                 cost_based_eviction)
from repro.core.placement import (JoinRecord, PlacementResult,
                                  cost_based_placement, static_placement)
from repro.core.policies import (POLICIES, POLICY_REGISTRY, PolicySpec,
                                 register_policy, resolve_policy)
from repro.core.join_planner import JoinPlan, candidate_pairs, plan_join
from repro.core.result_cache import (RESULT_CACHE_MODES, ResultCache,
                                     ResultEntry)
from repro.core.coordinator import (CacheCoordinator, QueryReport,
                                    SimilarityJoinQuery)
from repro.core.cluster import (BACKENDS, CostModel, ExecutedQuery,
                                NumpyJoinExecutor, PallasJoinExecutor,
                                RawArrayCluster, count_similar_pairs_np,
                                make_backend, workload_summary)

__all__ = [
    "Box", "bounding_box", "box_subtract", "expand", "residual_boxes",
    "Chunk", "ChunkMeta", "FileMeta",
    "EvolvingRTree", "RefineStats", "ChunkManager", "CacheState",
    "CoverageIndex", "CoveredSlice", "QueryRewrite",
    "LFUCache", "LRUCache", "Triple", "EvictionResult",
    "cost_based_eviction", "JoinRecord", "PlacementResult",
    "cost_based_placement", "static_placement", "POLICIES",
    "POLICY_REGISTRY", "PolicySpec", "register_policy", "resolve_policy",
    "JoinPlan", "candidate_pairs", "plan_join", "RESULT_CACHE_MODES",
    "ResultCache", "ResultEntry", "CacheCoordinator",
    "QueryReport", "SimilarityJoinQuery", "BACKENDS", "CostModel",
    "ExecutedQuery", "NumpyJoinExecutor", "PallasJoinExecutor",
    "RawArrayCluster", "count_similar_pairs_np", "make_backend",
    "workload_summary",
]
