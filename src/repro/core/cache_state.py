"""Layer 2 of the planning engine: cache residency and byte accounting.

``CacheState`` is the single source of truth for *what is cached where*:
the resident chunk-id set, the chunk -> replica-node map, and the byte
budgets the policy layer plans against. Policies mutate it; the
coordinator and the cluster read it.

Locations are **multi-valued**: every cached chunk maps to a non-empty
tuple of holder nodes, primary-first. A single-copy deployment (the
default, ``replication="off"``) keeps every tuple at length one, which
makes the multi-valued representation bit-for-bit equivalent to the old
single-valued map. Hot-chunk replication (``repro.core.policies.
HotChunkReplication``) appends secondary holders; the join planner
routes pair work to whichever replica is least loaded and eviction
treats secondaries as strictly cheaper to drop than sole copies (they
are simply not re-applied when budget tightens).

All readers and writers outside this module go through the accessor
surface (:meth:`node_of`, :meth:`replicas_of`, :meth:`set_replicas`,
:meth:`assign_locations`, ...) — never through the raw ``locations``
dict — so no caller can hold a stale single-valued view of a
multi-valued entry (``tests/test_replication_failover.py`` greps for
bypasses).

``budget_scope`` makes the budget semantics a first-class option:

  * ``"global"`` — the paper's §4.2.1 setting: all cluster memory is one
    unified pool. Eviction enforces ``sum(bytes) <= B_total`` and
    placement packs against the aggregate, optimizing location only.
  * ``"node"``   — per-node hard limits: placement packs each node
    against ``node_budget_bytes`` and chunks that fit nowhere are
    dropped from cache. This is the regime of real shared-nothing
    deployments where a worker cannot borrow a neighbor's DRAM.

Replica copies are charged at every holder: :meth:`bytes_by_node` sums
per-replica, so under ``budget_scope="node"`` a secondary consumes the
holding node's budget exactly like a primary.
"""
from __future__ import annotations

from typing import (Callable, Dict, FrozenSet, List, Optional, Set, Tuple,
                    Union)

from repro.core.chunk import ChunkMeta
from repro.core.coverage import CoverageIndex

BUDGET_SCOPES = ("global", "node")

# A location value as accepted by the mutator surface: a bare node id
# (normalized to a one-tuple) or an ordered replica tuple, primary-first.
LocationValue = Union[int, Tuple[int, ...]]


def _as_replicas(value: LocationValue) -> Tuple[int, ...]:
    """Normalize a location value to an ordered, de-duplicated replica
    tuple (primary-first). Bare ints become one-tuples — the compat path
    that keeps single-copy callers (and the paper's single-location
    placement results) working unchanged."""
    if isinstance(value, int):
        return (value,)
    seen: Set[int] = set()
    out: List[int] = []
    for n in value:
        n = int(n)
        if n not in seen:
            seen.add(n)
            out.append(n)
    return tuple(out)


class CacheState:
    """Residency, replica locations, and per-node byte accounting.

    Also owns the :class:`~repro.core.coverage.CoverageIndex` over resident
    chunk extents (the semantic-reuse structure): ``drop`` and
    ``remap_split`` keep it in sync point-wise, and ``sync_coverage``
    reconciles it after policy rounds that reassign ``cached`` wholesale
    (eviction/placement replace the resident set rather than mutating it).
    """

    def __init__(self, n_nodes: int, node_budget_bytes: int,
                 budget_scope: str = "global"):
        if budget_scope not in BUDGET_SCOPES:
            raise ValueError(f"unknown budget scope {budget_scope!r}; "
                             f"expected one of {BUDGET_SCOPES}")
        self.n_nodes = n_nodes
        self.node_budget = node_budget_bytes
        self.budget_scope = budget_scope
        self.cached: Set[int] = set()            # resident chunk ids
        # Cached chunk -> ordered holder-node tuple, primary first. Never
        # read or written directly outside this module — use the accessor
        # surface below.
        self.locations: Dict[int, Tuple[int, ...]] = {}
        self.coverage = CoverageIndex()          # boxes of resident chunks
        # Residency listeners (repro.backend.base.DeviceBindingListener):
        # components whose state is derived from resident chunks register
        # here so it moves/frees in lockstep with residency — execution
        # backends committing device buffers (JaxMeshBackend), the
        # join-artifact cache memoizing host-side prep
        # (repro.backend.artifacts.JoinArtifactCache), and the versioned
        # result tier (repro.core.result_cache.ResultCache), which bumps
        # its version stamp on every residency event. Point-wise events
        # fire from ``drop`` and ``remap_split``; ``sync_devices``
        # reconciles after policy rounds that reassign the resident set
        # wholesale.
        self.listeners: List = []

    # ------------------------------------------------------------- budgets

    @property
    def total_budget(self) -> int:
        """Aggregate cache bytes across the cluster (§4.2.1 unified pool)."""
        return self.node_budget * self.n_nodes

    def placement_budgets(self) -> Dict[int, int]:
        """Per-node byte budgets handed to the placement policy."""
        per_node = (self.total_budget if self.budget_scope == "global"
                    else self.node_budget)
        return {n: per_node for n in range(self.n_nodes)}

    # ---------------------------------------------------------- accounting

    def cached_bytes(self, chunk_bytes: Dict[int, int]) -> int:
        """Total resident bytes, charging every replica copy. Retired
        (split) ids missing from the size table contribute nothing —
        their cells live on in the children."""
        return sum(chunk_bytes.get(cid, 0) * max(len(self.replicas_of(cid)),
                                                 1)
                   for cid in self.cached)

    def bytes_by_node(self, chunk_bytes: Dict[int, int]) -> Dict[int, int]:
        """Resident bytes per node: every replica is charged at its
        holder, so the sum over nodes equals the sum of per-replica
        charges (single-copy tuples reproduce the old per-primary map)."""
        out = {n: 0 for n in range(self.n_nodes)}
        for cid in self.cached:
            for node in self.replicas_of(cid):
                out[node] = out.get(node, 0) + chunk_bytes.get(cid, 0)
        return out

    # ----------------------------------------------------------- listeners

    def add_listener(self, listener) -> None:
        """Register a device-binding listener (idempotent)."""
        if listener not in self.listeners:
            self.listeners.append(listener)

    def sync_devices(self) -> None:
        """Ask every device-binding listener to reconcile its committed
        buffers with the current ``cached``/location view — the
        device twin of :meth:`sync_coverage`, run by the coordinator
        after each eviction/placement round."""
        for listener in self.listeners:
            listener.reconcile(self)

    # ----------------------------------------------- location accessors
    # The ONE read/write surface for chunk locations. Everything outside
    # this module (policies, coordinator, backends, result tier) goes
    # through these methods so the multi-valued migration cannot leave a
    # stale single-valued read path behind.

    def node_of(self, chunk_id: int, default: Optional[int] = None
                ) -> Optional[int]:
        """The PRIMARY node of a cached chunk, else ``default`` — the
        compat accessor every old single-valued ``locations.get`` call
        site now routes through."""
        reps = self.locations.get(chunk_id)
        return reps[0] if reps else default

    def location_of(self, chunk_id: int, default: Optional[int] = None
                    ) -> Optional[int]:
        """Seed-API alias of :meth:`node_of` (primary holder)."""
        return self.node_of(chunk_id, default)

    def replicas_of(self, chunk_id: int) -> Tuple[int, ...]:
        """Every node holding a copy of the chunk, primary first; the
        empty tuple for unlocated/unknown ids."""
        return self.locations.get(chunk_id, ())

    def set_replicas(self, chunk_id: int,
                     nodes: LocationValue) -> None:
        """Assign a chunk's full replica set (primary = first element).
        An empty set clears the entry."""
        reps = _as_replicas(nodes)
        if reps:
            self.locations[chunk_id] = reps
        else:
            self.locations.pop(chunk_id, None)

    def ensure_location(self, chunk_id: int, node: int) -> None:
        """Record a location for a chunk that has none yet (setdefault
        semantics — an existing replica set is left untouched)."""
        if chunk_id not in self.locations:
            self.locations[chunk_id] = (node,)

    def clear_location(self, chunk_id: int) -> None:
        """Forget a chunk's replica set (all copies at once)."""
        self.locations.pop(chunk_id, None)

    def assign_locations(self, mapping: Dict[int, LocationValue]) -> None:
        """Wholesale location reassignment — the policy-round path
        (placement results are single-valued; replication re-applies
        secondaries afterwards). Values may be bare node ids or replica
        tuples; each is normalized through :func:`_as_replicas`."""
        self.locations = {cid: _as_replicas(v) for cid, v in mapping.items()
                          if _as_replicas(v)}

    def primary_map(self) -> Dict[int, int]:
        """A ``chunk -> primary node`` snapshot (the seed-era
        single-valued view, for display and legacy assertions)."""
        return {cid: reps[0] for cid, reps in self.locations.items() if reps}

    def location_items(self) -> List[Tuple[int, Tuple[int, ...]]]:
        """Snapshot of ``(chunk, replica-tuple)`` pairs (stable view for
        iteration while mutating)."""
        return list(self.locations.items())

    def location_snapshot(self) -> FrozenSet[Tuple[int, Tuple[int, ...]]]:
        """A hashable snapshot of the full replica map — what the result
        tier's ``reconcile`` diffs to detect relocation (including a
        replica-set change with an unchanged primary)."""
        return frozenset(self.locations.items())

    def audit_locations(self, n_nodes: int) -> List[str]:
        """Well-formedness check over the replica map for the invariant
        auditor: every location tuple must be non-empty and
        duplicate-free, name only nodes in ``[0, n_nodes)``, and belong
        to a resident chunk. Returns human-readable violation strings
        (empty when the map is consistent)."""
        problems: List[str] = []
        for cid, reps in self.locations.items():
            if not reps:
                problems.append(f"chunk {cid} has an empty replica tuple")
                continue
            if len(set(reps)) != len(reps):
                problems.append(
                    f"chunk {cid} replica tuple {reps} has duplicates")
            bad = [n for n in reps if not 0 <= n < n_nodes]
            if bad:
                problems.append(
                    f"chunk {cid} replica tuple {reps} names unknown "
                    f"node(s) {bad} (cluster has {n_nodes})")
            if cid not in self.cached:
                problems.append(
                    f"chunk {cid} has locations {reps} but is not "
                    f"resident")
        return problems

    # ------------------------------------------------------------ mutation

    def remap_split(self, parent_id: int, leaves: List[ChunkMeta]) -> None:
        """A cached chunk was split: children inherit residency, the full
        replica tuple, and coverage-index membership from the retired
        parent (§3.3 split remapping through historical cache state)."""
        self.cached.discard(parent_id)
        reps = self.locations.pop(parent_id, None)
        for cm in leaves:
            self.cached.add(cm.chunk_id)
            if reps:
                self.locations[cm.chunk_id] = reps
        self.coverage.remap_split(parent_id, leaves)
        for listener in self.listeners:
            listener.on_split(parent_id, leaves)

    def drop(self, chunk_id: int) -> None:
        """Remove a chunk (every replica) from residency, locations, and
        the coverage index."""
        self.cached.discard(chunk_id)
        self.locations.pop(chunk_id, None)
        self.coverage.remove(chunk_id)
        for listener in self.listeners:
            listener.on_drop(chunk_id)

    def drop_replica(self, chunk_id: int, node: int) -> bool:
        """Remove ONE copy of a chunk. Returns True if other replicas
        survive (residency intact; listeners see the change at the next
        ``sync_devices``); when the last copy goes this degenerates to a
        full :meth:`drop` (point-wise listener events fire)."""
        reps = self.replicas_of(chunk_id)
        if node not in reps:
            return bool(reps)
        survivors = tuple(n for n in reps if n != node)
        if survivors:
            self.locations[chunk_id] = survivors
            return True
        self.drop(chunk_id)
        return False

    def sync_coverage(self, meta_of: Callable[[int], Optional[ChunkMeta]]
                      ) -> None:
        """Reconcile the coverage index with ``cached`` after a policy
        round. ``meta_of`` resolves a resident chunk id to its metadata
        (``ChunkManager.meta_of``); ids it cannot resolve (retired between
        rounds) are left unindexed and re-enter on the next sync."""
        for cid in self.coverage.ids() - self.cached:
            self.coverage.remove(cid)
        for cid in self.cached - self.coverage.ids():
            meta = meta_of(cid)
            if meta is not None:
                self.coverage.add(meta)
