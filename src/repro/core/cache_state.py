"""Layer 2 of the planning engine: cache residency and byte accounting.

``CacheState`` is the single source of truth for *what is cached where*:
the resident chunk-id set, the chunk -> node location map, and the byte
budgets the policy layer plans against. Policies mutate it; the
coordinator and the cluster read it.

``budget_scope`` makes the budget semantics a first-class option:

  * ``"global"`` — the paper's §4.2.1 setting: all cluster memory is one
    unified pool. Eviction enforces ``sum(bytes) <= B_total`` and
    placement packs against the aggregate, optimizing location only.
  * ``"node"``   — per-node hard limits: placement packs each node
    against ``node_budget_bytes`` and chunks that fit nowhere are
    dropped from cache. This is the regime of real shared-nothing
    deployments where a worker cannot borrow a neighbor's DRAM.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Set

from repro.core.chunk import ChunkMeta
from repro.core.coverage import CoverageIndex

BUDGET_SCOPES = ("global", "node")


class CacheState:
    """Residency, locations, and per-node byte accounting.

    Also owns the :class:`~repro.core.coverage.CoverageIndex` over resident
    chunk extents (the semantic-reuse structure): ``drop`` and
    ``remap_split`` keep it in sync point-wise, and ``sync_coverage``
    reconciles it after policy rounds that reassign ``cached`` wholesale
    (eviction/placement replace the resident set rather than mutating it).
    """

    def __init__(self, n_nodes: int, node_budget_bytes: int,
                 budget_scope: str = "global"):
        if budget_scope not in BUDGET_SCOPES:
            raise ValueError(f"unknown budget scope {budget_scope!r}; "
                             f"expected one of {BUDGET_SCOPES}")
        self.n_nodes = n_nodes
        self.node_budget = node_budget_bytes
        self.budget_scope = budget_scope
        self.cached: Set[int] = set()            # resident chunk ids
        self.locations: Dict[int, int] = {}      # cached chunk -> node
        self.coverage = CoverageIndex()          # boxes of resident chunks
        # Residency listeners (repro.backend.base.DeviceBindingListener):
        # components whose state is derived from resident chunks register
        # here so it moves/frees in lockstep with residency — execution
        # backends committing device buffers (JaxMeshBackend), the
        # join-artifact cache memoizing host-side prep
        # (repro.backend.artifacts.JoinArtifactCache), and the versioned
        # result tier (repro.core.result_cache.ResultCache), which bumps
        # its version stamp on every residency event. Point-wise events
        # fire from ``drop`` and ``remap_split``; ``sync_devices``
        # reconciles after policy rounds that reassign the resident set
        # wholesale.
        self.listeners: List = []

    # ------------------------------------------------------------- budgets

    @property
    def total_budget(self) -> int:
        """Aggregate cache bytes across the cluster (§4.2.1 unified pool)."""
        return self.node_budget * self.n_nodes

    def placement_budgets(self) -> Dict[int, int]:
        """Per-node byte budgets handed to the placement policy."""
        per_node = (self.total_budget if self.budget_scope == "global"
                    else self.node_budget)
        return {n: per_node for n in range(self.n_nodes)}

    # ---------------------------------------------------------- accounting

    def cached_bytes(self, chunk_bytes: Dict[int, int]) -> int:
        """Total resident bytes. Retired (split) ids missing from the size
        table contribute nothing — their cells live on in the children."""
        return sum(chunk_bytes.get(cid, 0) for cid in self.cached)

    def bytes_by_node(self, chunk_bytes: Dict[int, int]) -> Dict[int, int]:
        """Resident bytes per node, from the location map."""
        out = {n: 0 for n in range(self.n_nodes)}
        for cid in self.cached:
            node = self.locations.get(cid)
            if node is not None:
                out[node] = out.get(node, 0) + chunk_bytes.get(cid, 0)
        return out

    # ----------------------------------------------------------- listeners

    def add_listener(self, listener) -> None:
        """Register a device-binding listener (idempotent)."""
        if listener not in self.listeners:
            self.listeners.append(listener)

    def sync_devices(self) -> None:
        """Ask every device-binding listener to reconcile its committed
        buffers with the current ``cached``/``locations`` view — the
        device twin of :meth:`sync_coverage`, run by the coordinator
        after each eviction/placement round."""
        for listener in self.listeners:
            listener.reconcile(self)

    # ------------------------------------------------------------ mutation

    def location_of(self, chunk_id: int, default: Optional[int] = None
                    ) -> Optional[int]:
        """The node currently holding a cached chunk, else ``default``."""
        return self.locations.get(chunk_id, default)

    def remap_split(self, parent_id: int, leaves: List[ChunkMeta]) -> None:
        """A cached chunk was split: children inherit residency, location,
        and coverage-index membership from the retired parent (§3.3 split
        remapping through historical cache state)."""
        self.cached.discard(parent_id)
        loc = self.locations.pop(parent_id, None)
        for cm in leaves:
            self.cached.add(cm.chunk_id)
            if loc is not None:
                self.locations[cm.chunk_id] = loc
        self.coverage.remap_split(parent_id, leaves)
        for listener in self.listeners:
            listener.on_split(parent_id, leaves)

    def drop(self, chunk_id: int) -> None:
        """Remove a chunk from residency, location, and coverage index."""
        self.cached.discard(chunk_id)
        self.locations.pop(chunk_id, None)
        self.coverage.remove(chunk_id)
        for listener in self.listeners:
            listener.on_drop(chunk_id)

    def sync_coverage(self, meta_of: Callable[[int], Optional[ChunkMeta]]
                      ) -> None:
        """Reconcile the coverage index with ``cached`` after a policy
        round. ``meta_of`` resolves a resident chunk id to its metadata
        (``ChunkManager.meta_of``); ids it cannot resolve (retired between
        rounds) are left unindexed and re-enter on the next sync."""
        for cid in self.coverage.ids() - self.cached:
            self.coverage.remove(cid)
        for cid in self.cached - self.coverage.ids():
            meta = meta_of(cid)
            if meta is not None:
                self.coverage.add(meta)
