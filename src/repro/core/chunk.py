"""Chunk and raw-file metadata objects shared by the caching framework.

A *chunk* (§3.1) is a set of cells from exactly one raw file, with a bounding
box derived from the cells assigned to it. Chunks partition each file's cells
(cover + non-overlap invariant of the evolving R-tree). The coordinator keeps
chunk *metadata* (box, counts, sizes) persistently; chunk *data* lives in node
caches and is lost on eviction — it must be recreated by a full raw-file scan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.geometry import Box


@dataclasses.dataclass
class FileMeta:
    """Catalog entry for one raw file (§2.1, Figure 1)."""

    file_id: int
    node: int                      # home node storing the raw file
    path: str                      # identifier into the arrayio layer
    fmt: str                       # 'csv' | 'fits' | 'hdf5'
    box: Box                       # file-level bounding box (from the catalog)
    n_cells: int
    file_bytes: int                # raw on-disk size — cost of one full scan
    cell_bytes: int                # in-memory size of one extracted cell


@dataclasses.dataclass
class Chunk:
    """A leaf of the evolving R-tree.

    ``cell_idx`` indexes into the owning file's coordinate table. ``box`` is
    always the tight bounding box of those cells. ``chunk_id`` is globally
    unique and stable until the chunk is split (split children get new ids;
    the parent id is retired and remapped via ``EvolvingRTree.descendants``).
    """

    chunk_id: int
    file_id: int
    box: Box
    cell_idx: np.ndarray           # (n,) int64 indices into file cell table
    cell_bytes: int                # per-cell in-memory size

    @property
    def n_cells(self) -> int:
        """Number of cells assigned to this chunk."""
        return int(self.cell_idx.shape[0])

    @property
    def nbytes(self) -> int:
        """In-memory size of the chunk's extracted cells (cache cost)."""
        return self.n_cells * self.cell_bytes

    def __hash__(self) -> int:
        return hash(self.chunk_id)

    def __eq__(self, other: object) -> bool:
        return isinstance(other, Chunk) and other.chunk_id == self.chunk_id

    def __repr__(self) -> str:
        return (f"Chunk(id={self.chunk_id}, file={self.file_id}, "
                f"n={self.n_cells}, box={self.box.lo}..{self.box.hi})")


@dataclasses.dataclass(frozen=True)
class ChunkMeta:
    """Coordinator-side view of a chunk — no cell data, metadata only."""

    chunk_id: int
    file_id: int
    box: Box
    n_cells: int
    nbytes: int

    @staticmethod
    def of(c: Chunk) -> "ChunkMeta":
        """Project a data-bearing ``Chunk`` to its metadata view."""
        return ChunkMeta(c.chunk_id, c.file_id, c.box, c.n_cells, c.nbytes)
