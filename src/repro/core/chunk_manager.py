"""Layer 1 of the planning engine: chunk lifecycle and size metadata.

The ``ChunkManager`` owns everything about *what the cache units are*:
the per-file evolving R-trees (Alg. 1), the global chunk-id space, the
chunk -> file mapping, split remapping, and the chunk/file size tables the
eviction and placement layers consume. It never decides *what to keep* or
*where to put it* — that is the policy layer (``repro.core.policies``)
operating on ``repro.core.cache_state.CacheState``.

Two granularities are supported:

  * ``chunk`` — cells are grouped by the query-driven R-tree refinement;
  * ``file``  — every raw file is a single-chunk unit (the paper's
    ``file_lru`` baseline). File units draw ids from the same positive
    id space as tree chunks, which removes the seed's negative-chunk-id
    encoding: downstream layers treat both granularities uniformly.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

if TYPE_CHECKING:  # duck-typed at runtime to avoid a package cycle
    from repro.arrayio.catalog import Catalog, FileReader

import numpy as np

from repro.core.chunk import ChunkMeta, FileMeta
from repro.core.rtree import EvolvingRTree
from repro.obs.clock import Clock, MONOTONIC


class ChunkManager:
    """R-tree lifecycle, split remapping, and size tables."""

    def __init__(self, catalog: "Catalog", reader: "FileReader",
                 min_cells: int, node_budget_bytes: int,
                 clock: Optional[Clock] = None):
        self.catalog = catalog
        self.reader = reader
        self.min_cells = min_cells
        self.node_budget = node_budget_bytes
        # Injectable time source threaded into every tree's refinement
        # timing (RefineStats.split_eval_s) — repro.obs satellite.
        self.clock = clock if clock is not None else MONOTONIC
        self._chunk_counter = 0
        self.trees: Dict[int, EvolvingRTree] = {}
        self.chunk_file: Dict[int, int] = {}       # chunk_id -> file_id
        self._file_units: Dict[int, ChunkMeta] = {}  # file_id -> unit meta

    # ------------------------------------------------------------- id space

    def next_chunk_id(self) -> int:
        """Allocate the next globally-unique (positive) chunk id."""
        self._chunk_counter += 1
        return self._chunk_counter

    # --------------------------------------------------- chunk granularity

    def tree(self, meta: FileMeta) -> EvolvingRTree:
        """The file's evolving R-tree, built (one full read) on first touch."""
        tree = self.trees.get(meta.file_id)
        if tree is None:
            coords, _ = self.reader.read(meta.file_id)
            # Cap chunk size at a quarter of one node's budget so placement
            # can always pack what eviction retains (rtree.py max_cells).
            max_cells = max(2 * self.min_cells,
                            self.node_budget // (4 * meta.cell_bytes))
            tree = EvolvingRTree(meta.file_id, coords, meta.cell_bytes,
                                 self.min_cells, self.next_chunk_id,
                                 max_cells=max_cells, clock=self.clock)
            self.trees[meta.file_id] = tree
            self.chunk_file[tree.leaves()[0].chunk_id] = meta.file_id
        return tree

    def descendants(self, chunk_id: int) -> List[int]:
        """Current leaf ids holding the cells of a (possibly split) chunk."""
        fid = self.chunk_file.get(chunk_id)
        if fid is None:
            return []
        if fid in self.trees:
            return self.trees[fid].descendants(chunk_id)
        return [chunk_id]          # file units never split

    def remap_after_splits(self, tree: EvolvingRTree, cache_state,
                           eviction_policy) -> None:
        """Propagate split chunk ids through cache bookkeeping: children
        inherit residency, location, and coverage-index membership from the
        retired parent, and the eviction policy's recency/frequency
        structures are renamed (§3.3 — historical state survives Alg. 1
        refinement)."""
        for cid, children in list(tree.split_children.items()):
            for ch in children:
                self.chunk_file.setdefault(ch, tree.file_id)
            if cid in cache_state.cached:
                cache_state.remap_split(
                    cid, [ChunkMeta.of(tree.get_chunk(d))
                          for d in tree.descendants(cid)])
            if eviction_policy.tracks(cid):
                kids = [(ch, tree.get_chunk(ch).nbytes)
                        for ch in tree.descendants(cid)]
                eviction_policy.on_split(cid, kids)

    # ---------------------------------------------------- file granularity

    def file_unit(self, meta: FileMeta) -> ChunkMeta:
        """The whole file as a single-chunk cache/join unit."""
        unit = self._file_units.get(meta.file_id)
        if unit is None:
            unit = ChunkMeta(chunk_id=self.next_chunk_id(),
                             file_id=meta.file_id, box=meta.box,
                             n_cells=meta.n_cells,
                             nbytes=meta.n_cells * meta.cell_bytes)
            self._file_units[meta.file_id] = unit
            self.chunk_file[unit.chunk_id] = meta.file_id
        return unit

    # ------------------------------------------------------------- lookups

    def cell_indices(self, chunk_id: int, file_id: int
                     ) -> Optional[np.ndarray]:
        """Indices into the file's cell table for a unit, or ``None``
        meaning the whole file (file-granularity units). A chunk retired
        by a later split in the same admission batch resolves to its
        descendants' cells (splits partition the parent exactly)."""
        unit = self._file_units.get(file_id)
        if unit is not None and unit.chunk_id == chunk_id:
            return None
        tree = self.trees[file_id]
        ds = tree.descendants(chunk_id)
        if ds == [chunk_id]:
            return tree.get_chunk(chunk_id).cell_idx
        return np.concatenate([tree.get_chunk(d).cell_idx for d in ds])

    def chunk_coords(self, chunk_id: int, file_id: int) -> np.ndarray:
        """Cell coordinates of a unit — tree leaf or whole file."""
        idx = self.cell_indices(chunk_id, file_id)
        if idx is None:
            coords, _ = self.reader.read(file_id)
            return coords
        return self.trees[file_id].coords[idx]

    def current_units(self, cm: ChunkMeta) -> List[ChunkMeta]:
        """A queried unit remapped onto the present leaf set. Identity for
        live leaves and file units; a chunk retired by a later split (which
        only happens under batched admission) expands to its descendants."""
        unit = self._file_units.get(cm.file_id)
        if unit is not None and unit.chunk_id == cm.chunk_id:
            return [cm]
        tree = self.trees.get(cm.file_id)
        if tree is None:
            return [cm]
        ds = tree.descendants(cm.chunk_id)
        if ds == [cm.chunk_id]:
            return [cm]
        return [ChunkMeta.of(tree.get_chunk(d)) for d in ds]

    def meta_of(self, chunk_id: int) -> Optional[ChunkMeta]:
        """Metadata for a *live* unit (tree leaf or file unit), or ``None``
        for retired/unknown ids — the coverage-index sync's resolver."""
        fid = self.chunk_file.get(chunk_id)
        if fid is None:
            return None
        unit = self._file_units.get(fid)
        if unit is not None and unit.chunk_id == chunk_id:
            return unit
        tree = self.trees.get(fid)
        if tree is None:
            return None
        try:
            return ChunkMeta.of(tree.get_chunk(chunk_id))
        except KeyError:
            return None

    def home_node(self, chunk_id: int) -> int:
        """The node storing the raw file a unit belongs to."""
        return self.catalog.by_id(self.chunk_file[chunk_id]).node

    def size_tables(self) -> Tuple[Dict[int, int], Dict[int, int]]:
        """(chunk_id -> bytes, file_id -> raw scan bytes) over all live
        units: R-tree leaves plus file-granularity units."""
        chunk_bytes: Dict[int, int] = {}
        for tree in self.trees.values():
            for c in tree.leaves():
                chunk_bytes[c.chunk_id] = c.nbytes
        for unit in self._file_units.values():
            chunk_bytes[unit.chunk_id] = unit.nbytes
        file_bytes = {f.file_id: f.file_bytes for f in self.catalog.files}
        return chunk_bytes, file_bytes
