"""Shared-nothing cluster simulator + cost model (§4.1 System).

The *algorithms* (chunking, planning, eviction, placement) and the *join
compute* run for real; disk and network are replaced by a calibrated cost
model (the container is one box, the paper's testbed was 8 workers + 1
coordinator on HDD + GbE). Algorithmic quantities — bytes scanned, bytes
shipped, cache contents, chunk counts, plan times — are exact; wall-clock is
modeled as

    t(query) = max_n scan_n + max_n net_n + max_n compute_n + t_opt(measured)

with scan_n = scanned_bytes/disk_bw + decoded_cells/decode_rate(fmt),
net_n = max(bytes_in, bytes_out)/net_bw (full-duplex switch), and
compute_n = assigned cell-pair work / pair_rate. Defaults follow §4.1:
125 MB/s disk and network. A TPU-pod profile (PCIe host link + ICI) is
provided for the framework integration experiments.

Join execution backends (``join_backend``):

  * ``"numpy"``  — the reference executor: one blocked numpy evaluation
    per chunk pair (``join_fn`` override preserved).
  * ``"pallas"`` — the batched executor: each node's chunk-pair work is
    grouped, coordinate sets are padded to the kernel's 128-wide BLOCK,
    and shape-bucketed pair batches are dispatched to the
    ``kernels/simjoin`` Pallas kernel (interpret-mode by default, so it
    runs on CPU CI and compiles on TPU).
"""
from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Sequence,
                    Tuple)

import numpy as np

if TYPE_CHECKING:  # duck-typed at runtime to avoid a package cycle
    from repro.arrayio.catalog import Catalog, FileReader
from repro.arrayio.formats import DECODE_CELLS_PER_SEC
from repro.core.coordinator import (CacheCoordinator, QueryReport,
                                    SimilarityJoinQuery)
from repro.core.geometry import points_in_box

JOIN_BACKENDS = ("numpy", "pallas")


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Calibrated per-node bandwidths/rates for the §4.1 time model."""

    disk_bw: float = 125e6               # B/s  (§4.1: HDD ~ GbE)
    net_bw: float = 125e6                # B/s per node link
    cell_pairs_per_sec: float = 5e8      # join predicate throughput per node
    decode_rates: Dict[str, float] = dataclasses.field(
        default_factory=lambda: dict(DECODE_CELLS_PER_SEC))

    @staticmethod
    def tpu_pod_host() -> "CostModel":
        """v5e-host profile: raw shards on host NVMe/DRAM, PCIe to device,
        ICI between pods' hosts (DESIGN.md hardware-adaptation notes)."""
        return CostModel(disk_bw=3.2e9, net_bw=50e9, cell_pairs_per_sec=2e11,
                         decode_rates={k: v * 50 for k, v in
                                       DECODE_CELLS_PER_SEC.items()})


def count_similar_pairs_np(a: np.ndarray, b: np.ndarray, eps: int,
                           same: bool, block: int = 4096) -> int:
    """Unordered (x != y) L1-neighbor pairs between cell coordinate sets.
    Blocked to bound memory; numpy reference executor."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return 0
    total = 0
    for i0 in range(0, a.shape[0], block):
        ai = a[i0:i0 + block]
        for j0 in range(0, b.shape[0], block):
            bj = b[j0:j0 + block]
            dist = np.abs(ai[:, None, :].astype(np.int64)
                          - bj[None, :, :].astype(np.int64)).sum(axis=2)
            hit = dist <= eps
            if same:
                # Count each unordered pair once; drop identical cells.
                ii = i0 + np.arange(ai.shape[0])[:, None]
                jj = j0 + np.arange(bj.shape[0])[None, :]
                hit &= ii < jj
            total += int(hit.sum())
    return total


# ---------------------------------------------------------------------------
# Join executors: per-node grouped chunk-pair work -> match counts.
# ---------------------------------------------------------------------------

# One unit of join work: (node, a coords, b coords, self-join?).
JoinTask = Tuple[int, np.ndarray, np.ndarray, bool]


class NumpyJoinExecutor:
    """Reference executor: evaluate each pair independently."""

    def __init__(self, join_fn: Callable[..., int]):
        self.join_fn = join_fn

    def count_pairs(self, tasks: Sequence[JoinTask], eps: int) -> List[int]:
        """Per-task match counts via the (overridable) numpy predicate."""
        return [self.join_fn(a, b, eps, same) for _, a, b, same in tasks]


class PallasJoinExecutor:
    """Batched executor over the ``kernels/simjoin`` Pallas kernel.

    Each node's chunk-pair tasks are padded to BLOCK and bucketed by
    padded shape and self-join mode; each bucket is dispatched as ONE
    stacked kernel call — turning a pair-at-a-time python loop into a
    handful of jit'd launches per query. Buckets span nodes because the
    simulator executes every node's work on this one device; a real
    multi-host backend would key buckets by node as well."""

    def __init__(self, interpret: bool = True):
        # Imported lazily so the numpy backend never pulls in jax.
        from repro.kernels.simjoin import ops, simjoin
        self._ops = ops
        self._block = simjoin.BLOCK
        self._sentinel = simjoin.SENTINEL
        self.interpret = interpret

    def count_pairs(self, tasks: Sequence[JoinTask], eps: int) -> List[int]:
        """Per-task match counts via bucketed batched kernel dispatch."""
        import jax.numpy as jnp
        counts = [0] * len(tasks)
        buckets: Dict[Tuple[bool, int, int], List[int]] = {}
        for i in range(len(tasks)):
            _, a, b, same = tasks[i]
            if a.shape[0] == 0 or b.shape[0] == 0:
                continue
            na = -(-a.shape[0] // self._block) * self._block
            nb = -(-b.shape[0] // self._block) * self._block
            buckets.setdefault((same, na, nb), []).append(i)
        for (same, _, _), idxs in buckets.items():
            a_stack = np.stack([self._ops.pad_cm_np(tasks[i][1],
                                                    self._sentinel)
                                for i in idxs])
            b_stack = np.stack([self._ops.pad_cm_np(tasks[i][2],
                                                    -self._sentinel)
                                for i in idxs])
            got = self._ops.count_similar_pairs_batch(
                jnp.asarray(a_stack), jnp.asarray(b_stack), int(eps),
                bool(same), interpret=self.interpret)
            for i, c in zip(idxs, np.asarray(got)):
                counts[i] = int(c)
        return counts


def make_join_executor(backend: str, join_fn: Callable[..., int],
                       interpret: bool = True):
    """Build a join executor for ``backend``, degrading pallas -> numpy
    with a warning when jax is unavailable."""
    if backend == "numpy":
        return NumpyJoinExecutor(join_fn)
    if backend == "pallas":
        try:
            return PallasJoinExecutor(interpret=interpret)
        except ImportError as e:                 # jax not available: degrade
            import warnings
            warnings.warn(f"join_backend='pallas' unavailable ({e}); "
                          f"falling back to the numpy executor",
                          RuntimeWarning, stacklevel=3)
            return NumpyJoinExecutor(join_fn)
    raise ValueError(f"unknown join backend {backend!r}; "
                     f"known: {JOIN_BACKENDS}")


@dataclasses.dataclass
class ExecutedQuery:
    """A query's planning report plus its modeled phase times and the
    (really computed) join match count."""

    report: QueryReport
    time_scan_s: float
    time_net_s: float
    time_compute_s: float
    time_opt_s: float
    matches: Optional[int]

    @property
    def time_total_s(self) -> float:
        """Modeled end-to-end latency: scan + net + compute + opt (§4.1)."""
        return (self.time_scan_s + self.time_net_s + self.time_compute_s
                + self.time_opt_s)


class RawArrayCluster:
    """N simulated worker nodes + coordinator, wired to the caching stack."""

    def __init__(self, catalog: "Catalog", reader: "FileReader", n_nodes: int,
                 node_budget_bytes: int, policy: str = "cost",
                 placement_mode: str = "dynamic", min_cells: int = 256,
                 cost_model: Optional[CostModel] = None,
                 join_fn: Optional[Callable[..., int]] = None,
                 execute_joins: bool = True,
                 join_backend: str = "numpy",
                 budget_scope: str = "global",
                 reuse: str = "off"):
        if join_fn is not None and join_backend != "numpy":
            raise ValueError(
                "join_fn overrides the join predicate of the numpy "
                "executor; the pallas backend always runs the L1 simjoin "
                "kernel — pass one or the other")
        self.catalog = catalog
        self.reader = reader
        self.n_nodes = n_nodes
        self.cost = cost_model or CostModel()
        self.join_fn = join_fn or count_similar_pairs_np
        self.execute_joins = execute_joins
        self.executor = make_join_executor(join_backend, self.join_fn)
        self.coordinator = CacheCoordinator(
            catalog, reader, n_nodes, node_budget_bytes, policy=policy,
            placement_mode=placement_mode, min_cells=min_cells,
            budget_scope=budget_scope, reuse=reuse)

    # ----------------------------------------------------------- execution

    def _queried_coords(self, chunk_id: int, file_id: int,
                        box) -> np.ndarray:
        coords = self.coordinator.chunks.chunk_coords(chunk_id, file_id)
        return coords[points_in_box(coords, box)]

    def _execute(self, query: SimilarityJoinQuery,
                 report: QueryReport) -> ExecutedQuery:
        """Apply the cost model and run the join plan's compute."""
        cm = {c.chunk_id: c for c in report.queried_chunks}

        # --- modeled scan phase
        scan_n: Dict[int, float] = {}
        for node, nbytes in report.scan_bytes_by_node.items():
            scan_n[node] = nbytes / self.cost.disk_bw
        for node, per_fmt in report.decode_cells_by_node.items():
            for fmt, cells in per_fmt.items():
                scan_n[node] = (scan_n.get(node, 0.0)
                                + cells / self.cost.decode_rates[fmt])
        time_scan = max(scan_n.values(), default=0.0)

        # --- modeled network phase (join shipping + placement fallbacks)
        time_net = 0.0
        if report.join_plan is not None:
            per_node = []
            for n in range(self.n_nodes):
                bi = report.join_plan.bytes_in.get(n, 0)
                bo = report.join_plan.bytes_out.get(n, 0)
                per_node.append(max(bi, bo))
            time_net = max(per_node, default=0) / self.cost.net_bw
        time_net += report.placement_extra_bytes / self.cost.net_bw

        # --- join execution (real compute over queried cells)
        matches: Optional[int] = None
        work_by_node: Dict[int, int] = {}
        # Semantic-reuse fast path: a pair with an empty sliced side can
        # contribute no matches — skip the executor dispatch entirely.
        # Gated on the reuse knob so a custom ``join_fn`` still sees every
        # pair under the seed-parity configuration.
        skip_empty = self.coordinator.reuse == "on"
        if report.join_plan is not None:
            tasks: List[JoinTask] = []
            coords_cache: Dict[int, np.ndarray] = {}
            for (a, b), node in report.join_plan.pair_node.items():
                for cid in (a, b):
                    if cid not in coords_cache:
                        coords_cache[cid] = self._queried_coords(
                            cid, cm[cid].file_id, query.box)
                ca, cb = coords_cache[a], coords_cache[b]
                work_by_node[node] = (work_by_node.get(node, 0)
                                      + ca.shape[0] * cb.shape[0])
                if skip_empty and (ca.shape[0] == 0 or cb.shape[0] == 0):
                    continue
                if self.execute_joins:
                    tasks.append((node, ca, cb, a == b))
            if self.execute_joins:
                matches = sum(self.executor.count_pairs(tasks, query.eps))
        time_compute = (max(work_by_node.values(), default=0)
                        / self.cost.cell_pairs_per_sec)

        t_opt = report.opt_time_chunking_s + report.opt_time_evict_place_s
        return ExecutedQuery(report=report, time_scan_s=time_scan,
                             time_net_s=time_net,
                             time_compute_s=time_compute,
                             time_opt_s=t_opt, matches=matches)

    def run_query(self, query: SimilarityJoinQuery) -> ExecutedQuery:
        """Admit one query through the coordinator and execute its plan."""
        report = self.coordinator.process_query(query)
        return self._execute(query, report)

    def run_workload(self, queries: Sequence[SimilarityJoinQuery],
                     batch_size: Optional[int] = None
                     ) -> List[ExecutedQuery]:
        """Run a workload. ``batch_size=N`` admits queries through the
        coordinator's batched planning path (shared raw-file scans, one
        eviction/placement round per batch); ``None``/1 preserves the
        per-query admission of the paper's experiments."""
        if batch_size is None or batch_size <= 1:
            return [self.run_query(q) for q in queries]
        out: List[ExecutedQuery] = []
        for i in range(0, len(queries), batch_size):
            batch = list(queries[i:i + batch_size])
            reports = self.coordinator.process_batch(batch)
            out.extend(self._execute(q, r)
                       for q, r in zip(batch, reports))
        return out


def workload_summary(executed: Sequence[ExecutedQuery]) -> Dict[str, float]:
    """Aggregate modeled times, scan volume, and semantic-reuse counters
    over an executed workload (the quantities the benchmarks report)."""
    return {
        "total_time_s": sum(e.time_total_s for e in executed),
        "scan_time_s": sum(e.time_scan_s for e in executed),
        "net_time_s": sum(e.time_net_s for e in executed),
        "compute_time_s": sum(e.time_compute_s for e in executed),
        "opt_time_s": sum(e.time_opt_s for e in executed),
        "bytes_scanned": float(sum(sum(e.report.scan_bytes_by_node.values())
                                   for e in executed)),
        "files_scanned": float(sum(len(e.report.files_scanned)
                                   for e in executed)),
        "queries": float(len(executed)),
        "reuse_hits": float(sum(e.report.reuse_hits for e in executed)),
        "reuse_bytes_served": float(sum(e.report.reuse_bytes_served
                                        for e in executed)),
        "residual_bytes_scanned": float(sum(e.report.residual_bytes_scanned
                                            for e in executed)),
        "reuse_scan_skips": float(sum(e.report.reuse_scan_skips
                                      for e in executed)),
    }
