"""Shared-nothing cluster façade over the pluggable execution backends.

The *algorithms* (chunking, planning, eviction, placement) and the *join
compute* always run for real; how disk, network, and device placement are
carried out is the backend's job (``repro.backend``):

  * ``backend="simulated"`` — the §4.1 calibrated cost model (the seed
    behavior, extracted into :class:`repro.backend.SimulatedBackend`):
    the container is one box, wall-clock is modeled as

        t(query) = max_n scan_n + max_n net_n + max_n compute_n + t_opt

    with scan_n = scanned_bytes/disk_bw + decoded_cells/decode_rate(fmt),
    net_n = max(bytes_in, bytes_out)/net_bw (full-duplex switch), and
    compute_n = assigned cell-pair work / pair_rate.
  * ``backend="jax_mesh"`` — real execution over a jax device mesh
    (:class:`repro.backend.JaxMeshBackend`): cached chunks become
    device-resident buffers pinned to the nodes of their ``CacheState``
    replica set, ship decisions become measured cross-device transfers, and
    each node's simjoin batch dispatches to the Pallas kernel on that
    node's device (compiled where the platform supports it).

Join execution backends for the simulated path (``join_backend``):

  * ``"numpy"``  — the reference executor: one blocked numpy evaluation
    per chunk pair (``join_fn`` override preserved).
  * ``"pallas"`` — the batched executor: BLOCK-padded, shape-bucketed
    pair batches dispatched to the ``kernels/simjoin`` Pallas kernel
    (interpret-mode by default, so it runs on CPU CI and compiles on
    TPU). Its ``prune`` knob selects the grid per task: ``"dense"``
    (every block pair evaluated), ``"block"`` (spatially sorted
    coordinates, host-pruned block pairs scalar-prefetched into the
    kernel), ``"bitmap"`` (block-sparse plus a cell-exact second stage
    — hierarchical occupancy bitmaps kill bbox-surviving pairs whose
    occupied cells are provably > eps apart), or ``"auto"`` (default —
    block-sparse only where the padded bitmap-refined pair list is
    shorter than the dense grid, so single-block and near-dense chunk
    pairs skip prune overhead). Match counts are identical across all
    four; the work done is reported per query as
    ``ExecutedQuery.block_pairs_evaluated / block_pairs_total`` (plus
    ``block_pairs_bitmap_killed``/``bitmap_build_s`` when the bitmap
    stage engaged).
    Host-side prep (sort/boxes/padding/pair lists) is memoized per
    resident chunk in a ``JoinArtifactCache`` invalidated with cache
    residency; the per-query ``prep_s``/``dispatch_s`` split and
    ``artifact_hits``/``artifact_misses`` land on ``ExecutedQuery``.

This module re-exports the cost model, executors, ``ExecutedQuery``, and
``workload_summary`` from ``repro.backend`` so seed-era imports keep
working.
"""
from __future__ import annotations

from typing import (TYPE_CHECKING, Any, Callable, List, Optional, Sequence)

if TYPE_CHECKING:  # duck-typed at runtime to avoid a package cycle
    from repro.arrayio.catalog import Catalog, FileReader
from repro.backend import (BACKENDS, CostModel, ExecutedQuery, JOIN_BACKENDS,
                           JoinTask, NumpyJoinExecutor, PallasJoinExecutor,
                           count_similar_pairs_np, make_backend,
                           make_join_executor, workload_summary)
from repro.core.coordinator import CacheCoordinator, SimilarityJoinQuery
from repro.obs.telemetry import Telemetry

__all__ = ["BACKENDS", "CostModel", "ExecutedQuery", "JOIN_BACKENDS",
           "JoinTask", "NumpyJoinExecutor", "PallasJoinExecutor",
           "RawArrayCluster", "count_similar_pairs_np", "make_backend",
           "make_join_executor", "workload_summary"]


class RawArrayCluster:
    """N worker nodes + coordinator, wired to the caching stack and an
    execution backend (simulated cost model or real jax device mesh)."""

    def __init__(self, catalog: "Catalog", reader: "FileReader", n_nodes: int,
                 node_budget_bytes: int, policy: str = "cost",
                 placement_mode: str = "dynamic", min_cells: int = 256,
                 cost_model: Optional[CostModel] = None,
                 join_fn: Optional[Callable[..., int]] = None,
                 execute_joins: bool = True,
                 join_backend: str = "numpy",
                 budget_scope: str = "global",
                 reuse: str = "off",
                 backend: str = "simulated",
                 devices: Optional[Sequence[Any]] = None,
                 compiled: Optional[bool] = None,
                 prune: str = "auto",
                 mqo: str = "off",
                 result_cache: str = "off",
                 result_cache_capacity: int = 256,
                 result_cache_ttl_s: Optional[float] = None,
                 replication: str = "off",
                 replica_k: int = 2,
                 replication_threshold: float = 3.0,
                 telemetry: "str | Telemetry | None" = "off",
                 faults: Any = "off",
                 retry: Any = None,
                 audit: str = "auto"):
        if join_fn is not None and join_backend != "numpy":
            raise ValueError(
                "join_fn overrides the join predicate of the numpy "
                "executor; the pallas backend always runs the L1 simjoin "
                "kernel — pass one or the other")
        self.catalog = catalog
        self.reader = reader
        self.n_nodes = n_nodes
        self.backend = make_backend(
            backend, n_nodes, cost_model=cost_model, join_fn=join_fn,
            join_backend=join_backend, execute_joins=execute_joins,
            devices=devices, compiled=compiled, prune=prune, mqo=mqo)
        self.coordinator = CacheCoordinator(
            catalog, reader, n_nodes, node_budget_bytes, policy=policy,
            placement_mode=placement_mode, min_cells=min_cells,
            budget_scope=budget_scope, reuse=reuse,
            result_cache=result_cache,
            result_cache_capacity=result_cache_capacity,
            result_cache_ttl_s=result_cache_ttl_s,
            replication=replication, replica_k=replica_k,
            replication_threshold=replication_threshold,
            telemetry=telemetry, faults=faults, retry=retry, audit=audit)
        self.backend.bind(self.coordinator)

    @property
    def telemetry(self) -> Telemetry:
        """The shared telemetry bundle (``"off"`` default = the no-op
        tracer/registry; pass ``telemetry="on"`` or a ``Telemetry``
        instance to record spans and metrics)."""
        return self.coordinator.telemetry

    def export_trace(self, path: str) -> str:
        """Write the recorded spans as Chrome trace-event JSON to
        ``path`` (Perfetto/``chrome://tracing``-loadable); returns
        ``path``. An off-mode cluster writes an empty—but well-formed—
        trace."""
        return self.telemetry.export_trace(path)

    def summary(self, executed: Sequence[ExecutedQuery]):
        """``workload_summary`` over ``executed``, also surfacing any
        replication/failover events still pending in the coordinator's
        event channel (e.g. a ``fail_node`` after the last query)."""
        return workload_summary(executed, coordinator=self.coordinator)

    # -------------------------------------------------- failure injection

    def fail_node(self, node: int):
        """Simulate a crash-restart of one worker node (see
        ``SimulatedBackend.fail_node``): its cached copies are lost,
        device buffers freed, and the coordinator re-admits what it can
        from surviving replicas or raw files. Returns the recovery
        event's counters."""
        return self.backend.fail_node(node)

    # ------------------------------------------------ backend-state views

    @property
    def cost(self) -> CostModel:
        """The backend's calibrated cost model (seed-API view)."""
        return self.backend.cost

    @property
    def join_fn(self) -> Callable[..., int]:
        """The numpy executor's join predicate (seed-API view)."""
        return self.backend.join_fn

    @property
    def executor(self):
        """The backend's join executor (seed-API view)."""
        return self.backend.executor

    @property
    def execute_joins(self) -> bool:
        """Whether join compute actually runs (seed-API view)."""
        return self.backend.execute_joins

    # ----------------------------------------------------------- execution

    def run_query(self, query: SimilarityJoinQuery) -> ExecutedQuery:
        """Admit one query through the coordinator and execute its plan
        (a result-cache hit report short-circuits execution; a planned
        query's computed match count is written back to the tier).
        Traced as one ``query`` span when telemetry is on."""
        with self.telemetry.tracer.span("query", cat="query"):
            report = self.coordinator.process_query(query)
            executed = self.backend.execute(query, report)
            self.coordinator.record_result(query, executed)
        return executed

    def run_workload(self, queries: Sequence[SimilarityJoinQuery],
                     batch_size: Optional[int] = None
                     ) -> List[ExecutedQuery]:
        """Run a workload. ``batch_size=N`` admits queries through the
        coordinator's batched planning path (shared raw-file scans, one
        eviction/placement round per batch) and the backend's
        ``execute_batch`` (cross-batch join-task dedup under the ``mqo``
        knob); ``None``/1 preserves the per-query admission of the
        paper's experiments. Traced as a root ``workload`` span whose
        direct children (``query`` / ``batch`` spans) tile the run."""
        tracer = self.telemetry.tracer
        root = tracer.begin("workload", cat="workload",
                            queries=len(queries))
        try:
            if batch_size is None or batch_size <= 1:
                return [self.run_query(q) for q in queries]
            out: List[ExecutedQuery] = []
            for i in range(0, len(queries), batch_size):
                batch = list(queries[i:i + batch_size])
                with tracer.span("batch", cat="query", size=len(batch)):
                    reports = self.coordinator.process_batch(batch)
                    executed = self.backend.execute_batch(batch, reports)
                    for q, e in zip(batch, executed):
                        self.coordinator.record_result(q, e)
                out.extend(executed)
            return out
        finally:
            tracer.end(root)
