"""Shared-nothing cluster simulator + cost model (§4.1 System).

The *algorithms* (chunking, planning, eviction, placement) and the *join
compute* run for real; disk and network are replaced by a calibrated cost
model (the container is one box, the paper's testbed was 8 workers + 1
coordinator on HDD + GbE). Algorithmic quantities — bytes scanned, bytes
shipped, cache contents, chunk counts, plan times — are exact; wall-clock is
modeled as

    t(query) = max_n scan_n + max_n net_n + max_n compute_n + t_opt(measured)

with scan_n = scanned_bytes/disk_bw + decoded_cells/decode_rate(fmt),
net_n = max(bytes_in, bytes_out)/net_bw (full-duplex switch), and
compute_n = assigned cell-pair work / pair_rate. Defaults follow §4.1:
125 MB/s disk and network. A TPU-pod profile (PCIe host link + ICI) is
provided for the framework integration experiments.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Sequence

import numpy as np

if TYPE_CHECKING:  # duck-typed at runtime to avoid a package cycle
    from repro.arrayio.catalog import Catalog, FileReader
from repro.arrayio.formats import DECODE_CELLS_PER_SEC
from repro.core.coordinator import (CacheCoordinator, QueryReport,
                                    SimilarityJoinQuery)
from repro.core.geometry import Box, points_in_box


@dataclasses.dataclass(frozen=True)
class CostModel:
    disk_bw: float = 125e6               # B/s  (§4.1: HDD ~ GbE)
    net_bw: float = 125e6                # B/s per node link
    cell_pairs_per_sec: float = 5e8      # join predicate throughput per node
    decode_rates: Dict[str, float] = dataclasses.field(
        default_factory=lambda: dict(DECODE_CELLS_PER_SEC))

    @staticmethod
    def tpu_pod_host() -> "CostModel":
        """v5e-host profile: raw shards on host NVMe/DRAM, PCIe to device,
        ICI between pods' hosts (DESIGN.md hardware-adaptation notes)."""
        return CostModel(disk_bw=3.2e9, net_bw=50e9, cell_pairs_per_sec=2e11,
                         decode_rates={k: v * 50 for k, v in
                                       DECODE_CELLS_PER_SEC.items()})


def count_similar_pairs_np(a: np.ndarray, b: np.ndarray, eps: int,
                           same: bool, block: int = 4096) -> int:
    """Unordered (x != y) L1-neighbor pairs between cell coordinate sets.
    Blocked to bound memory; numpy reference executor."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return 0
    total = 0
    for i0 in range(0, a.shape[0], block):
        ai = a[i0:i0 + block]
        for j0 in range(0, b.shape[0], block):
            bj = b[j0:j0 + block]
            dist = np.abs(ai[:, None, :].astype(np.int64)
                          - bj[None, :, :].astype(np.int64)).sum(axis=2)
            hit = dist <= eps
            if same:
                # Count each unordered pair once; drop identical cells.
                ii = i0 + np.arange(ai.shape[0])[:, None]
                jj = j0 + np.arange(bj.shape[0])[None, :]
                hit &= ii < jj
            total += int(hit.sum())
    return total


@dataclasses.dataclass
class ExecutedQuery:
    report: QueryReport
    time_scan_s: float
    time_net_s: float
    time_compute_s: float
    time_opt_s: float
    matches: Optional[int]

    @property
    def time_total_s(self) -> float:
        return (self.time_scan_s + self.time_net_s + self.time_compute_s
                + self.time_opt_s)


class RawArrayCluster:
    """N simulated worker nodes + coordinator, wired to the caching stack."""

    def __init__(self, catalog: "Catalog", reader: "FileReader", n_nodes: int,
                 node_budget_bytes: int, policy: str = "cost",
                 placement_mode: str = "dynamic", min_cells: int = 256,
                 cost_model: Optional[CostModel] = None,
                 join_fn: Optional[Callable[..., int]] = None,
                 execute_joins: bool = True):
        self.catalog = catalog
        self.reader = reader
        self.n_nodes = n_nodes
        self.cost = cost_model or CostModel()
        self.join_fn = join_fn or count_similar_pairs_np
        self.execute_joins = execute_joins
        self.coordinator = CacheCoordinator(
            catalog, reader, n_nodes, node_budget_bytes, policy=policy,
            placement_mode=placement_mode, min_cells=min_cells)

    # ----------------------------------------------------------- execution

    def _queried_coords(self, chunk_id: int, file_id: int,
                        box: Box) -> np.ndarray:
        if chunk_id < 0:   # file-granularity unit (file_lru)
            coords, _ = self.reader.read(file_id)
        else:
            tree = self.coordinator.trees[file_id]
            chunk = tree.get_chunk(chunk_id)
            coords = tree.coords[chunk.cell_idx]
        return coords[points_in_box(coords, box)]

    def run_query(self, query: SimilarityJoinQuery) -> ExecutedQuery:
        report = self.coordinator.process_query(query)
        cm = {c.chunk_id: c for c in report.queried_chunks}

        # --- modeled scan phase
        scan_n: Dict[int, float] = {}
        for node, nbytes in report.scan_bytes_by_node.items():
            scan_n[node] = nbytes / self.cost.disk_bw
        for node, per_fmt in report.decode_cells_by_node.items():
            for fmt, cells in per_fmt.items():
                scan_n[node] = (scan_n.get(node, 0.0)
                                + cells / self.cost.decode_rates[fmt])
        time_scan = max(scan_n.values(), default=0.0)

        # --- modeled network phase (join shipping + placement fallbacks)
        time_net = 0.0
        if report.join_plan is not None:
            per_node = []
            for n in range(self.n_nodes):
                bi = report.join_plan.bytes_in.get(n, 0)
                bo = report.join_plan.bytes_out.get(n, 0)
                per_node.append(max(bi, bo))
            time_net = max(per_node, default=0) / self.cost.net_bw
        time_net += report.placement_extra_bytes / self.cost.net_bw

        # --- join execution (real compute over queried cells)
        matches: Optional[int] = None
        work_by_node: Dict[int, int] = {}
        if report.join_plan is not None:
            if self.execute_joins:
                matches = 0
            coords_cache: Dict[int, np.ndarray] = {}
            for (a, b), node in report.join_plan.pair_node.items():
                for cid in (a, b):
                    if cid not in coords_cache:
                        coords_cache[cid] = self._queried_coords(
                            cid, cm[cid].file_id, query.box)
                ca, cb = coords_cache[a], coords_cache[b]
                work_by_node[node] = (work_by_node.get(node, 0)
                                      + ca.shape[0] * cb.shape[0])
                if self.execute_joins:
                    matches += self.join_fn(ca, cb, query.eps, a == b)
        time_compute = (max(work_by_node.values(), default=0)
                        / self.cost.cell_pairs_per_sec)

        t_opt = report.opt_time_chunking_s + report.opt_time_evict_place_s
        return ExecutedQuery(report=report, time_scan_s=time_scan,
                             time_net_s=time_net,
                             time_compute_s=time_compute,
                             time_opt_s=t_opt, matches=matches)

    def run_workload(self, queries: Sequence[SimilarityJoinQuery]
                     ) -> List[ExecutedQuery]:
        return [self.run_query(q) for q in queries]


def workload_summary(executed: Sequence[ExecutedQuery]) -> Dict[str, float]:
    return {
        "total_time_s": sum(e.time_total_s for e in executed),
        "scan_time_s": sum(e.time_scan_s for e in executed),
        "net_time_s": sum(e.time_net_s for e in executed),
        "compute_time_s": sum(e.time_compute_s for e in executed),
        "opt_time_s": sum(e.time_opt_s for e in executed),
        "bytes_scanned": float(sum(sum(e.report.scan_bytes_by_node.values())
                                   for e in executed)),
        "files_scanned": float(sum(len(e.report.files_scanned)
                                   for e in executed)),
        "queries": float(len(executed)),
    }
