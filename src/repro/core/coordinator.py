"""Cache coordinator: the Figure-2 planning pipeline, as thin layers.

For each similarity-join admission batch the coordinator runs:

  1. chunking refinement per query (Alg. 1) — ``ChunkManager``;
  2. join execution plan per query (chunk pair -> node, [63]-style);
  3. ONE cache eviction round over the batch (Alg. 2 / LRU / LFU) —
     ``EvictionPolicy`` from the registry;
  4. ONE cache placement round (Alg. 3 / static / origin) —
     ``PlacementPolicy`` from the registry, against ``CacheState``
     budgets (global pool or per-node hard limits via ``budget_scope``).

The coordinator sees only metadata (bounding boxes, counts, sizes, cache
content tables) — cell data stays on the nodes (the cluster layer).
``process_query`` is the single-query admission path (a batch of one);
``process_batch`` amortizes raw-file scans across the batch: a file
materialized for one query is not rescanned by a later query in the same
batch, and eviction/placement run once over the union touch set.

Policy combos (see ``repro.core.policies``): ``cost``, ``chunk_lru``,
``file_lru`` reproduce the paper's three configurations; ``cost_static``,
``chunk_lfu``, ``file_lfu`` are registry-provided extensions.
"""
from __future__ import annotations

import dataclasses
import operator
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Sequence,
                    Set, Tuple, Union)

if TYPE_CHECKING:  # duck-typed at runtime to avoid a package cycle
    from repro.arrayio.catalog import Catalog, FileReader
    from repro.faults import FaultInjector, RetryPolicy
from repro.core.cache_state import CacheState
from repro.core.chunk import ChunkMeta
from repro.core.chunk_manager import ChunkManager
from repro.core.coverage import QueryRewrite
from repro.core.geometry import Box, points_in_box
from repro.core.join_planner import JoinPlan, plan_join
from repro.core.placement import JoinRecord, PlacementResult
from repro.core.policies import (EvictionContext, PlacementContext, POLICIES,
                                 QueryAccess, REPLICATION_MODES,
                                 ReplicationContext, build_eviction,
                                 build_placement, build_replication,
                                 resolve_policy)
from repro.core.result_cache import (RESULT_CACHE_MODES, ResultCache,
                                     ResultEntry)
from repro.core.rtree import RefineStats
from repro.faults.audit import InvariantAuditor
from repro.faults.errors import (BatchInFlightError, RetryExhaustedError,
                                 ScanError)
from repro.faults.injector import make_faults
from repro.faults.retry import Retrier, make_retry
from repro.obs.clock import Clock, as_clock
from repro.obs.telemetry import EventChannel, Telemetry, make_telemetry

__all__ = ["AUDIT_MODES", "POLICIES", "REPLICATION_MODES", "REUSE_MODES",
           "RESULT_CACHE_MODES", "SimilarityJoinQuery", "QueryReport",
           "CacheCoordinator"]

# Invariant-auditor knob: "auto" (default) audits whenever fault
# injection is armed, "on" always audits, "off" never does.
AUDIT_MODES = ("auto", "on", "off")

# Semantic cache reuse knob: "off" preserves the seed pipeline exactly
# (every query goes through the catalog/scan path, whole chunks ship);
# "on" consults the CoverageIndex before a query's scan plan is built.
REUSE_MODES = ("off", "on")


@dataclasses.dataclass(frozen=True)
class SimilarityJoinQuery:
    """A similarity self-join over the cells inside ``box`` (§2.2): count
    unordered L1-neighbor pairs within radius ``eps``."""

    box: Box
    eps: int = 1


@dataclasses.dataclass
class QueryReport:
    """Per-query planning observables (the quantities Figures 5-8 plot,
    plus the semantic-reuse counters added by the CoverageIndex layer)."""

    query_index: int
    policy: str
    files_considered: int
    files_pruned: int
    files_scanned: List[int]
    scan_bytes_by_node: Dict[int, int]
    decode_cells_by_node: Dict[int, Dict[str, int]]
    queried_chunks: List[ChunkMeta]
    queried_cells: int
    join_plan: Optional[JoinPlan]
    placement: Optional[PlacementResult]
    placement_extra_bytes: int
    cached_bytes_after: int
    cached_chunks_after: int
    evicted_items: int
    opt_time_chunking_s: float
    opt_time_evict_place_s: float
    refine_stats: RefineStats
    batch_size: int = 1
    # Semantic-reuse observables (all zero when the reuse knob is "off").
    reuse_hits: int = 0                 # cached chunks served by slicing
    reuse_bytes_served: int = 0         # sliced extent bytes from cache
    residual_bytes_scanned: int = 0     # raw bytes the residual path scanned
    reuse_scan_skips: int = 0           # file scans avoided by containment
    reuse_fully_covered: bool = False   # box-level residual was empty
    # Result-cache observables: a hit report is planning-free — the
    # coordinator served the stored match count (``cached_matches``)
    # before chunking/join-planning/policy rounds ran for this query.
    result_cache_hit: bool = False
    cached_matches: Optional[int] = None
    # Degraded-mode observables (both empty unless a retry budget was
    # exhausted during planning — see ``repro.faults``): sub-boxes of
    # the query that could not be served, and the operations that gave
    # up on them. The backend folds execution-time failures in and
    # surfaces the union as ``ExecutedQuery.degraded``.
    degraded_boxes: Tuple[Box, ...] = ()
    failed_ops: Tuple[str, ...] = ()


@dataclasses.dataclass
class _QueryPlan:
    """Per-query planning output, pending the batch eviction/placement."""

    query: SimilarityJoinQuery
    query_index: int
    files_considered: int
    files_pruned: int
    files_scanned: List[int]
    scan_bytes_by_node: Dict[int, int]
    decode_cells_by_node: Dict[int, Dict[str, int]]
    queried: List[ChunkMeta]
    queried_cells: int
    join_plan: JoinPlan
    opt_time_chunking_s: float
    refine_stats: RefineStats
    online_evicted: int = 0
    rewrite: Optional[QueryRewrite] = None
    reuse_hits: int = 0
    reuse_bytes_served: int = 0
    reuse_scan_skips: int = 0
    degraded_boxes: List[Box] = dataclasses.field(default_factory=list)
    failed_ops: List[str] = dataclasses.field(default_factory=list)


class CacheCoordinator:
    """The Figure-2 planning pipeline as a thin conductor over the layers.

    ``process_query`` admits a batch of one; ``process_batch`` shares
    raw-file scans across a batch and runs one eviction/placement round.
    ``reuse="on"`` enables the semantic cache-reuse rewrite: before a
    query's scan plan is built the coordinator consults the
    ``CacheState.coverage`` index, serves covered sub-regions from
    resident chunks sliced in place (shipping only the sliced extent), and
    sends only the residual region down the catalog/scan path — a file
    scan is skipped when every actually-queried cell of that file lives in
    a covering cached chunk (box-level prune + cell-exact containment
    test). ``reuse="off"`` (default) preserves seed-exact behavior.
    Cumulative reuse counters live in :attr:`stats`.
    """

    # Per-round multiplicative decay of the replication policy's access
    # frequencies (steady-state frequency of a chunk touched every query
    # is 1/(1-decay) = 5.0 — the default promotion threshold of 3.0 sits
    # comfortably below it).
    REPLICA_FREQ_DECAY = 0.8

    def __init__(self, catalog: "Catalog", reader: "FileReader", n_nodes: int,
                 node_budget_bytes: int, policy: str = "cost",
                 placement_mode: str = "dynamic", min_cells: int = 256,
                 decay: float = 2.0, history_window: int = 64,
                 budget_scope: str = "global", reuse: str = "off",
                 result_cache: str = "off",
                 result_cache_capacity: int = 256,
                 result_cache_ttl_s: Optional[float] = None,
                 replication: str = "off", replica_k: int = 2,
                 replication_threshold: float = 3.0,
                 telemetry: Union[str, Telemetry, None] = None,
                 clock: Union[Clock, Callable[[], float], None] = None,
                 faults: "Union[str, FaultInjector, Dict[str, float], None]"
                 = "off",
                 retry: "Union[str, RetryPolicy, Dict[str, float], None]"
                 = None,
                 audit: str = "auto"):
        if reuse not in REUSE_MODES:
            raise ValueError(f"unknown reuse mode {reuse!r}; "
                             f"expected one of {REUSE_MODES}")
        if result_cache not in RESULT_CACHE_MODES:
            raise ValueError(
                f"unknown result_cache mode {result_cache!r}; "
                f"expected one of {RESULT_CACHE_MODES}")
        if replication not in REPLICATION_MODES:
            raise ValueError(f"unknown replication mode {replication!r}; "
                             f"expected one of {REPLICATION_MODES}")
        self.spec = resolve_policy(policy, placement_mode)
        self.catalog = catalog
        self.reader = reader
        self.n_nodes = n_nodes
        self.policy = policy
        self.placement_mode = placement_mode
        self.decay = decay
        self.history_window = history_window
        self.reuse = reuse
        # Telemetry bundle (off = shared no-op tracer/registry, seed
        # parity) and the ONE clock every planning-side timing reads —
        # override ``clock`` to make phase timings deterministic.
        self.telemetry = make_telemetry(telemetry)
        self.clock = (as_clock(clock) if clock is not None
                      else self.telemetry.clock)
        # Transient-fault pipeline (see ``repro.faults``): the seeded
        # injector behind the ``fault_point`` seam (None = seam never
        # consulted, seed-exact), the shared retrier both the planner
        # and the execution backend route transient failures through,
        # and the cross-layer invariant auditor ("auto" = armed with
        # faults). All off by default.
        if audit not in AUDIT_MODES:
            raise ValueError(f"unknown audit mode {audit!r}; "
                             f"expected one of {AUDIT_MODES}")
        self.faults = make_faults(faults, clock=self.clock)
        self.retry_policy = make_retry(retry)
        self.retrier = (Retrier(self.retry_policy, clock=self.clock,
                                tracer=self.telemetry.tracer)
                        if self.faults is not None else None)
        self.auditor: Optional[InvariantAuditor] = None
        if audit == "on" or (audit == "auto" and self.faults is not None):
            self.auditor = InvariantAuditor(self)
        # fail_node guard rails: reject crash-restarts mid-batch and
        # double-failing a node before any admission round re-ran.
        self._in_batch = False
        self._last_failed: Optional[int] = None

        self.chunks = ChunkManager(catalog, reader, min_cells,
                                   node_budget_bytes, clock=self.clock)
        self.cache = CacheState(n_nodes, node_budget_bytes, budget_scope)
        self.eviction = build_eviction(self.spec, self.cache.total_budget,
                                       decay, history_window)
        self.placement = build_placement(self.spec)
        # Hot-chunk replication round (a no-op object under "off" — the
        # pipeline stays bit-for-bit the single-copy path: the round,
        # frequency tracking, and per-query counters are all skipped).
        self.replication = replication
        self.replicator = build_replication(replication, k=replica_k,
                                            threshold=replication_threshold)
        # Decayed per-chunk access frequency (the replication policy's
        # workload stats): +1 per query touch, x REPLICA_FREQ_DECAY per
        # policy round. Maintained only when replication is on.
        self.access_freq: Dict[int, float] = {}
        # Counters the execution backend attaches to the next
        # ExecutedQuery it builds (drained once — see
        # :meth:`drain_exec_counters`); ``workload_summary`` surfaces
        # anything still pending after the last query, so post-workload
        # events are never silently lost.
        self.events = EventChannel(self.telemetry.registry)
        self.join_history: List[JoinRecord] = []   # Alg. 3 workload W
        self.query_counter = 0
        # Queries that went through the planning pipeline (a result-cache
        # hit does NOT increment this — the counter is the observable
        # proving repeats bypass chunking/planning/policy rounds).
        self.planner_invocations = 0
        # The versioned result tier (None when the knob is off); rides
        # the same CacheState listener surface as device buffers and
        # join artifacts so residency churn invalidates stored results.
        self.result_cache: Optional[ResultCache] = None
        if result_cache == "on":
            self.result_cache = ResultCache(capacity=result_cache_capacity,
                                            ttl_s=result_cache_ttl_s,
                                            clock=self.clock)
            self.cache.add_listener(self.result_cache)
        if self.auditor is not None:
            # Listener registration is observational only (the auditor's
            # hooks never mutate); the actual invariant passes run via
            # explicit ``auditor.audit()`` calls after sync points.
            self.cache.add_listener(self.auditor)
        # Cumulative semantic-reuse counters (bench_caching surfaces them).
        self.stats: Dict[str, float] = {
            "reuse_hits": 0, "reuse_bytes_served": 0,
            "residual_bytes_scanned": 0, "reuse_scan_skips": 0,
            "reuse_fully_covered_queries": 0,
            "result_cache_hits": 0, "result_cache_misses": 0,
            # Replication/failover counters (stay 0 when the replication
            # knob is off and no node ever fails).
            "replica_hits": 0, "replicas_dropped": 0,
            "node_failures": 0, "failover_readmits": 0,
            "recovery_bytes_from_replica": 0, "recovery_bytes_from_raw": 0,
            "recovery_s": 0.0,
        }
        # Resident-set snapshot the cache-health instrumentation diffs
        # against (residency churn per policy round; telemetry-on only).
        self._prev_resident: Set[int] = set()

    # ------------------------------------------------- legacy-shaped views

    @property
    def trees(self):
        """Per-file evolving R-trees (seed-API view of ChunkManager)."""
        return self.chunks.trees

    @property
    def chunk_file(self) -> Dict[int, int]:
        """chunk id -> owning file id (seed-API view of ChunkManager)."""
        return self.chunks.chunk_file

    @property
    def cached(self) -> Set[int]:
        """Resident chunk-id set (seed-API view of CacheState)."""
        return self.cache.cached

    @property
    def locations(self) -> Dict[int, int]:
        """Cached chunk -> PRIMARY node snapshot (seed-API view of
        CacheState; the full replica tuples live behind
        ``cache.replicas_of``)."""
        return self.cache.primary_map()

    @property
    def node_budget(self) -> int:
        """Per-node cache budget in bytes (seed-API view of CacheState)."""
        return self.cache.node_budget

    @property
    def total_budget(self) -> int:
        """Aggregate cache budget in bytes (seed-API view of CacheState)."""
        return self.cache.total_budget

    @property
    def min_cells(self) -> int:
        """Alg. 1 minimum chunk population (seed-API view)."""
        return self.chunks.min_cells

    # ------------------------------------------------------------- queries

    def process_query(self, query: SimilarityJoinQuery) -> QueryReport:
        """Admit one query (a batch of one): the paper's per-query
        admission path, including the semantic-reuse rewrite when the
        ``reuse`` knob is on."""
        return self.process_batch([query])[0]

    def process_batch(self, queries: Sequence[SimilarityJoinQuery]
                      ) -> List[QueryReport]:
        """Admit a batch: per-query chunking + join planning with raw-file
        scans shared across the batch, then a single eviction/placement
        round over the union touch set.

        With the ``result_cache`` knob on, every query is first probed
        against the versioned result tier — a hit yields a planning-free
        hit report (``result_cache_hit=True``) and the query skips
        chunking, join planning, and the policy round entirely; a batch
        of pure hits runs no policy round at all."""
        if not queries:
            return []
        queries = list(queries)
        hit_reports: Dict[int, QueryReport] = {}
        to_plan: List[SimilarityJoinQuery] = []
        plan_pos: List[int] = []           # position in the batch
        for i, q in enumerate(queries):
            entry = (self.result_cache.lookup(
                ResultCache.key_of(q.box, q.eps))
                if self.result_cache is not None else None)
            if entry is not None:
                self.query_counter += 1
                self.stats["result_cache_hits"] += 1
                hit_reports[i] = self._result_cache_report(
                    q, entry, len(queries))
            else:
                if self.result_cache is not None:
                    self.stats["result_cache_misses"] += 1
                to_plan.append(q)
                plan_pos.append(i)
        if not to_plan:                    # pure-hit batch: planner untouched
            return [hit_reports[i] for i in range(len(queries))]
        # Planning + policy rounds mutate residency wholesale; a
        # crash-restart interleaved here would corrupt accounting, so
        # fail_node is rejected while the flag is up (typed error).
        self._in_batch = True
        try:
            plans: List[_QueryPlan] = []
            batch_scanned: Set[int] = set()    # files materialized this batch
            for q in to_plan:
                self.query_counter += 1
                self.planner_invocations += 1
                if self.spec.granularity == "file":
                    plans.append(self._plan_file_query(q, self.query_counter))
                else:
                    plans.append(self._plan_chunked_query(
                        q, self.query_counter, batch_scanned))

            tracer = self.telemetry.tracer
            t0 = self.clock.now()
            chunk_bytes, file_bytes = self.chunks.size_tables()
            # An early query's chunk may have been split by a later query in
            # the same batch: remap every access onto the present leaf set
            # (identity for a batch of one) before the policy rounds.
            accesses: List[QueryAccess] = []
            for p in plans:
                queried_now: List[ChunkMeta] = []
                by_file_now: Dict[int, List[int]] = {}
                for cm in p.queried:
                    for u in self.chunks.current_units(cm):
                        queried_now.append(u)
                        by_file_now.setdefault(u.file_id, []).append(u.chunk_id)
                accesses.append(QueryAccess(p.query_index, queried_now,
                                            by_file_now))
            deferred_evicted = 0
            if self.spec.granularity == "chunk":
                # File units admit online during the scan loop; chunk units
                # admit here, in one Alg.-2/LRU/LFU round over the batch.
                with tracer.span("policy.evict", queries=len(plans)):
                    deferred_evicted = self.eviction.finalize_batch(
                        EvictionContext(
                            accesses=accesses, chunk_bytes=chunk_bytes,
                            file_bytes=file_bytes, state=self.cache,
                            chunks=self.chunks))

            replicas: Dict[int, Set[int]] = {}
            for p in plans:
                for cid, nodes in p.join_plan.replicas.items():
                    replicas.setdefault(cid, set()).update(nodes)
            with tracer.span("policy.place", queries=len(plans)):
                placement, extra_bytes = self.placement.place(PlacementContext(
                    replicas=replicas,
                    queried=[cm for acc in accesses for cm in acc.queried],
                    join_history=self.join_history, chunk_bytes=chunk_bytes,
                    node_budgets=self.cache.placement_budgets(),
                    state=self.cache, home_of=self.chunks.home_node,
                    decay=self.decay, history_window=self.history_window))
            if placement is not None:
                # Keep the eviction policy's residency view in sync with
                # placement drops (no-op for cost: triples re-enter as
                # uncached bytes next round, the seed behavior).
                for cid in placement.dropped:
                    self.eviction.discard(cid)
            if self.replication != "off":
                # Replication round: update the decayed access frequencies
                # from this batch's (remapped) touch set, then let the policy
                # re-apply/promote secondaries into whatever budget the
                # eviction/placement rounds left free. Runs strictly after
                # them so residency and primaries are already final — which
                # is what makes secondaries cheaper to drop than sole copies.
                with tracer.span("policy.replicate", queries=len(plans)):
                    for cid in list(self.access_freq):
                        self.access_freq[cid] *= self.REPLICA_FREQ_DECAY
                        if self.access_freq[cid] < 1e-3:
                            del self.access_freq[cid]
                    for acc in accesses:
                        for cm in acc.queried:
                            self.access_freq[cm.chunk_id] = \
                                self.access_freq.get(cm.chunk_id, 0.0) + 1.0
                    shed = self.replicator.replicate(ReplicationContext(
                        state=self.cache, chunk_bytes=chunk_bytes,
                        freq=self.access_freq, home_of=self.chunks.home_node))
                self.stats["replicas_dropped"] += shed
                self.events.post("replicas_dropped", shed)
                for p in plans:
                    self.stats["replica_hits"] += p.join_plan.replica_hits
            t_evict_place = self.clock.now() - t0

            # Policy rounds reassign the resident set wholesale; reconcile any
            # device-backed buffer bindings (no-op without a device backend).
            self.cache.sync_devices()

            if self.reuse == "on":
                # Policy rounds reassign the resident set wholesale; reconcile
                # the coverage index so the next batch's rewrite sees it.
                self.cache.sync_coverage(self.chunks.meta_of)
                for p in plans:
                    self.stats["reuse_hits"] += p.reuse_hits
                    self.stats["reuse_bytes_served"] += p.reuse_bytes_served
                    self.stats["residual_bytes_scanned"] += \
                        sum(p.scan_bytes_by_node.values())
                    self.stats["reuse_scan_skips"] += p.reuse_scan_skips
                    if p.rewrite is not None and p.rewrite.fully_covered:
                        self.stats["reuse_fully_covered_queries"] += 1

            if self.auditor is not None:
                # Cross-check the listener-coupled tiers right after every
                # policy round's sync points (see repro.faults.audit).
                self.auditor.audit()
            # A completed admission round re-populates the cluster; the
            # double-fail guard resets so the next crash can target any node.
            self._last_failed = None

            if self.telemetry.enabled:
                self._record_cache_health(chunk_bytes)

            cached_bytes = self.cache.cached_bytes(chunk_bytes)
            cached_chunks = len(self.cache.cached)
            out: List[Optional[QueryReport]] = [
                hit_reports.get(i) for i in range(len(queries))]
            for i, p in enumerate(plans):
                last = i == len(plans) - 1
                out[plan_pos[i]] = (QueryReport(
                    query_index=p.query_index, policy=self.policy,
                    files_considered=p.files_considered,
                    files_pruned=p.files_pruned,
                    files_scanned=p.files_scanned,
                    scan_bytes_by_node=p.scan_bytes_by_node,
                    decode_cells_by_node=p.decode_cells_by_node,
                    queried_chunks=p.queried, queried_cells=p.queried_cells,
                    join_plan=p.join_plan,
                    placement=placement if last else None,
                    placement_extra_bytes=extra_bytes if last else 0,
                    cached_bytes_after=cached_bytes,
                    cached_chunks_after=cached_chunks,
                    evicted_items=p.online_evicted
                    + (deferred_evicted if last else 0),
                    opt_time_chunking_s=p.opt_time_chunking_s,
                    opt_time_evict_place_s=t_evict_place if last else 0.0,
                    refine_stats=p.refine_stats, batch_size=len(plans),
                    reuse_hits=p.reuse_hits,
                    reuse_bytes_served=p.reuse_bytes_served,
                    residual_bytes_scanned=(
                        sum(p.scan_bytes_by_node.values())
                        if self.reuse == "on" else 0),
                    reuse_scan_skips=p.reuse_scan_skips,
                    reuse_fully_covered=(p.rewrite is not None
                                         and p.rewrite.fully_covered),
                    degraded_boxes=tuple(p.degraded_boxes),
                    failed_ops=tuple(p.failed_ops)))
            return out
        finally:
            self._in_batch = False

    # -------------------------------------------- cache-health telemetry

    def _record_cache_health(self, chunk_bytes: Dict[int, int]) -> None:
        """Refresh the registry's cache-health instruments after a policy
        round (telemetry-on only): per-node budget utilization gauges,
        the replica-skew gauge (max/mean of cached bytes per node; 1.0 =
        perfectly balanced, 0 = empty cache), a residency-churn histogram
        (symmetric difference of the resident set vs the previous
        round), and ``coord.*`` gauge mirrors of :attr:`stats`."""
        reg = self.telemetry.registry
        used = self.cache.bytes_by_node(chunk_bytes)
        budget = max(self.cache.node_budget, 1)
        vals = [used.get(n, 0) for n in range(self.n_nodes)]
        for node, b in enumerate(vals):
            reg.gauge("cache.budget_utilization", node=node).set(b / budget)
        mean = sum(vals) / max(len(vals), 1)
        reg.gauge("cache.replica_skew").set(max(vals) / mean if mean > 0
                                            else 0.0)
        resident = set(self.cache.cached)
        reg.histogram("cache.residency_churn").observe(
            len(resident ^ self._prev_resident))
        self._prev_resident = resident
        for k, v in self.stats.items():
            reg.gauge(f"coord.{k}").set(v)

    # ------------------------------------------------ result-cache tier

    def _result_cache_report(self, query: SimilarityJoinQuery,
                             entry: ResultEntry,
                             batch_size: int) -> QueryReport:
        """The planning-free report of a result-cache hit: no files
        considered/scanned, no join plan, zero optimization time — the
        served observables (match count, queried cells, cache occupancy)
        come from the stored entry, which the version stamp guarantees
        was computed under the current residency."""
        return QueryReport(
            query_index=self.query_counter, policy=self.policy,
            files_considered=0, files_pruned=0, files_scanned=[],
            scan_bytes_by_node={}, decode_cells_by_node={},
            queried_chunks=[], queried_cells=entry.queried_cells,
            join_plan=None, placement=None, placement_extra_bytes=0,
            cached_bytes_after=entry.cached_bytes_after,
            cached_chunks_after=entry.cached_chunks_after,
            evicted_items=0, opt_time_chunking_s=0.0,
            opt_time_evict_place_s=0.0, refine_stats=RefineStats(),
            batch_size=batch_size, result_cache_hit=True,
            cached_matches=entry.matches)

    def record_result(self, query: SimilarityJoinQuery,
                      executed) -> None:
        """Write-back after execution: store a planned query's computed
        match count (plus the observables a future hit will serve) under
        the current residency version. No-op when the tier is off, the
        query was itself a hit, the backend computed no matches
        (``execute_joins=False``), or the query degraded — a partial
        match count must never be served to a future exact repeat."""
        if self.result_cache is None:
            return
        report = executed.report
        if report.result_cache_hit or executed.matches is None:
            return
        if getattr(executed, "degraded", None) is not None:
            return
        self.result_cache.store(
            ResultCache.key_of(query.box, query.eps),
            executed.matches, queried_cells=report.queried_cells,
            cached_bytes_after=report.cached_bytes_after,
            cached_chunks_after=report.cached_chunks_after)

    # ------------------------------------------ simulated failure handling

    def drain_exec_counters(self) -> Dict[str, float]:
        """Hand the pending replication/failover counters to the
        execution backend (drained once — the first ``ExecutedQuery``
        built after the event carries them; see
        ``repro.backend.base.ExecutedQuery``). Events posted after the
        last query stay in :attr:`events` until ``workload_summary``
        surfaces them."""
        return self.events.drain()

    def _fits_at(self, node: int, nbytes: int,
                 chunk_bytes: Dict[int, int]) -> bool:
        """Whether one more copy of ``nbytes`` fits at ``node`` under the
        budget scope (per-node hard limit or unified pool), charging
        every currently-held replica."""
        if nbytes <= 0:
            return True
        used = self.cache.bytes_by_node(chunk_bytes)
        if self.cache.budget_scope == "node":
            return used.get(node, 0) + nbytes <= self.cache.node_budget
        return sum(used.values()) + nbytes <= self.cache.total_budget

    def fail_node(self, node: int) -> Dict[str, float]:
        """Simulate a crash-restart of one node: every cached copy it
        held is lost (raw files are durable; the node rejoins empty) and
        the coordinator immediately re-admits what it can —

          * a chunk with surviving replicas shrinks to the survivors,
            then the lost copy is restored onto the restarted node from
            a survivor when budget allows (cheap — charged to
            ``recovery_bytes_from_replica``);
          * a sole-copy chunk is dropped through ``CacheState.drop`` (so
            the device-buffer, join-artifact, and result-cache listeners
            all forget it point-wise) and re-admitted from its raw file
            at its home node when budget allows (charged to
            ``recovery_bytes_from_raw``).

        The round ends with ``sync_coverage`` + ``sync_devices``, so
        every listener-driven tier reconciles against the post-failure
        residency (the result tier's snapshot diff bumps its version on
        any replica-set change — no stored result computed against a
        dead replica is ever served). Returns this event's counters;
        they also accumulate in :attr:`stats` and ride the next
        ``ExecutedQuery`` via :meth:`drain_exec_counters`.

        Guard rails: a non-integral or out-of-range ``node`` raises
        ``ValueError`` before any accounting is touched; so does failing
        the same node twice with no admission batch in between (the
        node is still empty — a second "crash" would double-count
        recovery). Calling this mid-``process_batch`` raises the typed
        :class:`~repro.faults.errors.BatchInFlightError`."""
        try:
            node = operator.index(node)
        except TypeError:
            raise ValueError(
                f"node must be an integer, got {node!r}") from None
        if not 0 <= node < self.n_nodes:
            raise ValueError(f"node {node} outside 0..{self.n_nodes - 1}")
        if self._in_batch:
            raise BatchInFlightError(
                f"fail_node({node}) called while an admission batch is in "
                f"flight; crash-restarts are only valid between batches")
        if node == self._last_failed:
            raise ValueError(
                f"node {node} already failed with no admission batch since; "
                f"it is still empty — failing it again would corrupt "
                f"recovery accounting")
        recover_span = self.telemetry.tracer.begin("recover", node=node)
        t0 = self.clock.now()
        chunk_bytes, _ = self.chunks.size_tables()
        readmits = 0
        from_replica = 0
        from_raw = 0
        for cid, reps in self.cache.location_items():
            if cid not in self.cache.cached or node not in reps:
                continue
            survivors = tuple(n for n in reps if n != node)
            nbytes = chunk_bytes.get(cid, 0)
            if survivors:
                self.cache.set_replicas(cid, survivors)
                if (self._fits_at(node, nbytes, chunk_bytes)
                        and self._readmit_ok(cid, node)):
                    self.cache.set_replicas(cid, survivors + (node,))
                    from_replica += nbytes
                    readmits += 1
            else:
                self.cache.drop(cid)
                home = (self.chunks.home_node(cid)
                        if self.chunks.meta_of(cid) is not None else None)
                if (home is not None
                        and self._fits_at(home, nbytes, chunk_bytes)
                        and self._readmit_ok(cid, home)):
                    self.cache.cached.add(cid)
                    self.cache.set_replicas(cid, (home,))
                    from_raw += nbytes
                    readmits += 1
                else:
                    # Not recoverable right now (no budget, or the
                    # readmit itself retried out): release any eviction-
                    # policy bookkeeping so the id cannot resurrect into
                    # residency without a fresh scan.
                    self.eviction.discard(cid)
        self.cache.sync_coverage(self.chunks.meta_of)
        self.cache.sync_devices()
        event = {
            "failover_readmits": float(readmits),
            "recovery_bytes_from_replica": float(from_replica),
            "recovery_bytes_from_raw": float(from_raw),
            "recovery_s": self.clock.now() - t0,
        }
        self.telemetry.tracer.end(recover_span)
        self.stats["node_failures"] += 1
        self._last_failed = node
        if self.auditor is not None:
            self.auditor.audit()
        for k, v in event.items():
            self.stats[k] += v
            self.events.post(k, v)
        return event

    def _readmit_ok(self, cid: int, node: int) -> bool:
        """One guarded ``recover.readmit`` crossing for re-admitting lost
        chunk ``cid`` onto ``node`` during crash recovery. True (always,
        when faults are off) means proceed; False means the readmit
        retried out and the chunk stays unrecovered this round."""
        if self.faults is None:
            return True
        try:
            self.retrier.call(
                "recover.readmit",
                lambda a: self.faults.fault_point(
                    "recover.readmit", chunk=cid, node=node, attempt=a))
            return True
        except RetryExhaustedError:
            return False

    # ---- per-query planning: chunk granularity (cost, chunk_lru, ...) ----

    def _plan_chunked_query(self, query: SimilarityJoinQuery, l: int,
                            batch_scanned: Set[int]) -> _QueryPlan:
        """Plan one chunk-granularity query: semantic-reuse rewrite (when
        enabled), Alg.-1 refinement, scan accounting, and join planning."""
        reuse_on = self.reuse == "on"
        tracer = self.telemetry.tracer
        # Semantic rewrite, BEFORE the scan plan is built: covered slices
        # (cached chunks overlapping the query, sliced to it) plus the
        # residual region left after subtracting their boxes.
        rewrite: Optional[QueryRewrite] = None
        if reuse_on:
            with tracer.span("query.rewrite", query=l):
                rewrite = self.cache.coverage.rewrite(query.box)
        candidates = self.catalog.files_overlapping(query.box)
        scans: List[int] = []
        scan_bytes: Dict[int, int] = {}
        decode_cells: Dict[int, Dict[str, int]] = {}
        queried: List[ChunkMeta] = []
        ship_bytes: Dict[int, int] = {}
        cells_in_q = 0
        pruned = 0
        reuse_hits = 0
        reuse_bytes = 0
        scan_skips = 0
        degraded: List[Box] = []
        failed_ops: List[str] = []
        t0 = self.clock.now()
        rstats = RefineStats()
        scan_span = tracer.begin("plan.scan", query=l,
                                 files=len(candidates))
        for meta in candidates:
            first_touch = meta.file_id not in self.chunks.trees
            try:
                tree = self._guarded_scan(
                    meta, query,
                    arm=lambda m=meta: m.file_id not in self.chunks.trees,
                    fn=lambda m=meta: self.chunks.tree(m))
            except RetryExhaustedError as e:
                self._degrade_file(meta, query, degraded, failed_ops, e.op)
                continue
            overlapping = tree.overlapping(query.box)
            if not overlapping:
                pruned += 1           # refined boxes prune the file entirely
                continue
            stale = [c for c in overlapping
                     if c.chunk_id not in self.cache.cached]
            needs_scan = first_touch or bool(stale)
            if reuse_on and stale and not first_touch:
                # Box overlap alone does not force a rescan: leaf boxes are
                # tight, so the file's queried cells are exactly those of
                # its leaves inside the query. If every stale (uncached)
                # leaf holds no queried cell, the query region of this file
                # is covered by cached chunks (plus provably-empty space)
                # and the scan is skipped — the cell-exact containment
                # test behind the CoverageIndex's box-level rewrite.
                needs_scan = any(
                    points_in_box(tree.coords[c.cell_idx], query.box).any()
                    for c in stale)
                if not needs_scan:
                    scan_skips += 1
            miss = needs_scan and meta.file_id not in batch_scanned
            if miss and not first_touch and self.faults is not None:
                # Stale chunks force a rescan of an already-built file:
                # a distinct scan.read crossing (the first-touch read was
                # armed inside _guarded_scan above).
                try:
                    self.retrier.call(
                        "scan.read",
                        lambda a, m=meta: self.faults.fault_point(
                            "scan.read", file=m.file_id, attempt=a))
                except RetryExhaustedError as e:
                    self._degrade_file(meta, query, degraded, failed_ops,
                                       e.op)
                    continue
            chunks = tree.refine(query.box, rstats)
            self.chunks.remap_after_splits(tree, self.cache, self.eviction)
            if miss:
                scans.append(meta.file_id)
                batch_scanned.add(meta.file_id)
                scan_bytes[meta.node] = (scan_bytes.get(meta.node, 0)
                                         + meta.file_bytes)
                decode_cells.setdefault(meta.node, {}).setdefault(meta.fmt, 0)
                decode_cells[meta.node][meta.fmt] += meta.n_cells
            if not chunks:
                # Overlap was empty space — carved off by the refinement.
                continue
            for c in chunks:
                cm = ChunkMeta.of(c)
                queried.append(cm)
                n_in_q = int(points_in_box(
                    tree.coords[c.cell_idx], query.box).sum())
                cells_in_q += n_in_q
                if reuse_on and cm.chunk_id in self.cache.coverage:
                    # Covering cached chunk (the CoverageIndex is the
                    # slice-serving source of truth; split remaps keep it
                    # live mid-query): its owner slices the queried extent
                    # in place and the join ships only the slice.
                    sliced = n_in_q * (cm.nbytes // max(cm.n_cells, 1))
                    ship_bytes[cm.chunk_id] = sliced
                    if sliced > 0:
                        reuse_hits += 1
                        reuse_bytes += sliced
        tracer.end(scan_span)
        t_chunking = self.clock.now() - t0

        # Locations at query start: the cached replica set (a one-tuple
        # in the single-copy default), else the home node (the scan just
        # materialized the chunk there).
        locations = {cm.chunk_id: (self.cache.replicas_of(cm.chunk_id)
                                   or self.catalog.by_id(cm.file_id).node)
                     for cm in queried}
        jplan = plan_join(queried, locations,
                          0 if query.eps <= 0 else query.eps, self.n_nodes,
                          ship_bytes=ship_bytes or None)
        self.join_history.append(JoinRecord(l, tuple(jplan.pairs)))
        if len(self.join_history) > self.history_window:
            self.join_history = self.join_history[-self.history_window:]

        return _QueryPlan(
            query=query, query_index=l, files_considered=len(candidates),
            files_pruned=pruned, files_scanned=scans,
            scan_bytes_by_node=scan_bytes, decode_cells_by_node=decode_cells,
            queried=queried, queried_cells=cells_in_q, join_plan=jplan,
            opt_time_chunking_s=t_chunking, refine_stats=rstats,
            rewrite=rewrite, reuse_hits=reuse_hits,
            reuse_bytes_served=reuse_bytes, reuse_scan_skips=scan_skips,
            degraded_boxes=degraded, failed_ops=failed_ops)

    # ---- per-query planning: file granularity (file_lru, file_lfu) ----

    def _plan_file_query(self, query: SimilarityJoinQuery,
                         l: int) -> _QueryPlan:
        """Whole files as single-chunk units, admitted online: the scan
        decision consults the live cache, so an admission earlier in the
        loop can evict (and force a rescan of) a later candidate — the
        paper's file-LRU baseline semantics.

        With ``reuse="on"``, resident file units covering part of the query
        are sliced in place for the join (shipping only the sliced extent);
        scans are never skipped here — whole-file units carry no finer
        extent metadata to run the containment test against."""
        reuse_on = self.reuse == "on"
        tracer = self.telemetry.tracer
        rewrite: Optional[QueryRewrite] = None
        if reuse_on:
            with tracer.span("query.rewrite", query=l):
                rewrite = self.cache.coverage.rewrite(query.box)
        candidates = self.catalog.files_overlapping(query.box)
        scans: List[int] = []
        scan_bytes: Dict[int, int] = {}
        decode_cells: Dict[int, Dict[str, int]] = {}
        queried: List[ChunkMeta] = []
        ship_bytes: Dict[int, int] = {}
        cells_in_q = 0
        evicted = 0
        reuse_hits = 0
        reuse_bytes = 0
        degraded: List[Box] = []
        failed_ops: List[str] = []
        scan_span = tracer.begin("plan.scan", query=l,
                                 files=len(candidates))
        for meta in candidates:
            unit = self.chunks.file_unit(meta)
            resident = self.eviction.is_resident(unit.chunk_id)
            try:
                coords, _ = self._guarded_scan(
                    meta, query,
                    arm=lambda r=resident: not r,
                    fn=lambda m=meta: self.reader.read(m.file_id))
            except RetryExhaustedError as e:
                self._degrade_file(meta, query, degraded, failed_ops, e.op)
                continue
            if not resident:
                scans.append(meta.file_id)
                scan_bytes[meta.node] = (scan_bytes.get(meta.node, 0)
                                         + meta.file_bytes)
                decode_cells.setdefault(meta.node, {}).setdefault(meta.fmt, 0)
                decode_cells[meta.node][meta.fmt] += meta.n_cells
            evicted += self.eviction.admit_online(unit, self.cache)
            queried.append(unit)
            n_in_q = int(points_in_box(coords, query.box).sum())
            cells_in_q += n_in_q
            if reuse_on and resident:
                sliced = n_in_q * meta.cell_bytes
                ship_bytes[unit.chunk_id] = sliced
                if sliced > 0:       # a 0-cell slice reuses nothing
                    reuse_hits += 1
                    reuse_bytes += sliced
        tracer.end(scan_span)
        locations = {cm.chunk_id: self.catalog.by_id(cm.file_id).node
                     for cm in queried}
        jplan = plan_join(queried, locations, query.eps, self.n_nodes,
                          ship_bytes=ship_bytes or None)
        return _QueryPlan(
            query=query, query_index=l, files_considered=len(candidates),
            files_pruned=0, files_scanned=scans,
            scan_bytes_by_node=scan_bytes, decode_cells_by_node=decode_cells,
            queried=queried, queried_cells=cells_in_q, join_plan=jplan,
            opt_time_chunking_s=0.0, refine_stats=RefineStats(),
            online_evicted=evicted, rewrite=rewrite, reuse_hits=reuse_hits,
            reuse_bytes_served=reuse_bytes,
            degraded_boxes=degraded, failed_ops=failed_ops)

    # ------------------------------------------- guarded scan plumbing

    def _guarded_scan(self, meta, query: SimilarityJoinQuery,
                      arm: Callable[[], bool], fn: Callable[[], object]):
        """Run one raw-file scan/decode operation under the ``scan.read``
        fault point and the shared retry policy.

        ``arm()`` decides whether this crossing performs a *real* read
        (first touch / non-resident unit) — only then is the fault point
        consulted. A typed :class:`ScanError` escaping ``fn`` is
        annotated with the queried box; with faults off it propagates to
        the caller (satellite: typed scan errors), with faults on it is
        transient and retried until the budget exhausts
        (:class:`RetryExhaustedError` — the caller degrades the file)."""
        def attempt(attempt_no: int = 0):
            if self.faults is not None and arm():
                self.faults.fault_point("scan.read", file=meta.file_id,
                                        attempt=attempt_no)
            try:
                return fn()
            except ScanError as e:
                if e.box is None:
                    e.box = query.box
                raise
        if self.faults is None:
            return attempt()
        return self.retrier.call("scan.read", attempt)

    def _degrade_file(self, meta, query: SimilarityJoinQuery,
                      degraded: List[Box], failed_ops: List[str],
                      op: str) -> None:
        """Record a file whose scan retried out: the file's overlap with
        the query box becomes a failed sub-box of the eventual
        :class:`~repro.faults.retry.DegradedResult`, and the file is
        skipped for this query (raw files are durable — a later query
        re-attempts with a fresh fault schedule)."""
        inter = meta.box.intersection(query.box)
        if inter is not None:
            degraded.append(inter)
        failed_ops.append(op)
