"""Cache coordinator: the Figure-2 planning pipeline, as thin layers.

For each similarity-join admission batch the coordinator runs:

  1. chunking refinement per query (Alg. 1) — ``ChunkManager``;
  2. join execution plan per query (chunk pair -> node, [63]-style);
  3. ONE cache eviction round over the batch (Alg. 2 / LRU / LFU) —
     ``EvictionPolicy`` from the registry;
  4. ONE cache placement round (Alg. 3 / static / origin) —
     ``PlacementPolicy`` from the registry, against ``CacheState``
     budgets (global pool or per-node hard limits via ``budget_scope``).

The coordinator sees only metadata (bounding boxes, counts, sizes, cache
content tables) — cell data stays on the nodes (the cluster layer).
``process_query`` is the single-query admission path (a batch of one);
``process_batch`` amortizes raw-file scans across the batch: a file
materialized for one query is not rescanned by a later query in the same
batch, and eviction/placement run once over the union touch set.

Policy combos (see ``repro.core.policies``): ``cost``, ``chunk_lru``,
``file_lru`` reproduce the paper's three configurations; ``cost_static``,
``chunk_lfu``, ``file_lfu`` are registry-provided extensions.
"""
from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Set

if TYPE_CHECKING:  # duck-typed at runtime to avoid a package cycle
    from repro.arrayio.catalog import Catalog, FileReader
from repro.core.cache_state import CacheState
from repro.core.chunk import ChunkMeta
from repro.core.chunk_manager import ChunkManager
from repro.core.geometry import Box, points_in_box
from repro.core.join_planner import JoinPlan, plan_join
from repro.core.placement import JoinRecord, PlacementResult
from repro.core.policies import (EvictionContext, PlacementContext, POLICIES,
                                 QueryAccess, build_eviction, build_placement,
                                 resolve_policy)
from repro.core.rtree import RefineStats

__all__ = ["POLICIES", "SimilarityJoinQuery", "QueryReport",
           "CacheCoordinator"]


@dataclasses.dataclass(frozen=True)
class SimilarityJoinQuery:
    box: Box
    eps: int = 1


@dataclasses.dataclass
class QueryReport:
    query_index: int
    policy: str
    files_considered: int
    files_pruned: int
    files_scanned: List[int]
    scan_bytes_by_node: Dict[int, int]
    decode_cells_by_node: Dict[int, Dict[str, int]]
    queried_chunks: List[ChunkMeta]
    queried_cells: int
    join_plan: Optional[JoinPlan]
    placement: Optional[PlacementResult]
    placement_extra_bytes: int
    cached_bytes_after: int
    cached_chunks_after: int
    evicted_items: int
    opt_time_chunking_s: float
    opt_time_evict_place_s: float
    refine_stats: RefineStats
    batch_size: int = 1


@dataclasses.dataclass
class _QueryPlan:
    """Per-query planning output, pending the batch eviction/placement."""

    query: SimilarityJoinQuery
    query_index: int
    files_considered: int
    files_pruned: int
    files_scanned: List[int]
    scan_bytes_by_node: Dict[int, int]
    decode_cells_by_node: Dict[int, Dict[str, int]]
    queried: List[ChunkMeta]
    queried_cells: int
    join_plan: JoinPlan
    opt_time_chunking_s: float
    refine_stats: RefineStats
    online_evicted: int = 0


class CacheCoordinator:
    def __init__(self, catalog: "Catalog", reader: "FileReader", n_nodes: int,
                 node_budget_bytes: int, policy: str = "cost",
                 placement_mode: str = "dynamic", min_cells: int = 256,
                 decay: float = 2.0, history_window: int = 64,
                 budget_scope: str = "global"):
        self.spec = resolve_policy(policy, placement_mode)
        self.catalog = catalog
        self.reader = reader
        self.n_nodes = n_nodes
        self.policy = policy
        self.placement_mode = placement_mode
        self.decay = decay
        self.history_window = history_window

        self.chunks = ChunkManager(catalog, reader, min_cells,
                                   node_budget_bytes)
        self.cache = CacheState(n_nodes, node_budget_bytes, budget_scope)
        self.eviction = build_eviction(self.spec, self.cache.total_budget,
                                       decay, history_window)
        self.placement = build_placement(self.spec)
        self.join_history: List[JoinRecord] = []   # Alg. 3 workload W
        self.query_counter = 0

    # ------------------------------------------------- legacy-shaped views

    @property
    def trees(self):
        return self.chunks.trees

    @property
    def chunk_file(self) -> Dict[int, int]:
        return self.chunks.chunk_file

    @property
    def cached(self) -> Set[int]:
        return self.cache.cached

    @property
    def locations(self) -> Dict[int, int]:
        return self.cache.locations

    @property
    def node_budget(self) -> int:
        return self.cache.node_budget

    @property
    def total_budget(self) -> int:
        return self.cache.total_budget

    @property
    def min_cells(self) -> int:
        return self.chunks.min_cells

    # ------------------------------------------------------------- queries

    def process_query(self, query: SimilarityJoinQuery) -> QueryReport:
        return self.process_batch([query])[0]

    def process_batch(self, queries: Sequence[SimilarityJoinQuery]
                      ) -> List[QueryReport]:
        """Admit a batch: per-query chunking + join planning with raw-file
        scans shared across the batch, then a single eviction/placement
        round over the union touch set."""
        if not queries:
            return []
        plans: List[_QueryPlan] = []
        batch_scanned: Set[int] = set()    # files materialized this batch
        for q in queries:
            self.query_counter += 1
            if self.spec.granularity == "file":
                plans.append(self._plan_file_query(q, self.query_counter))
            else:
                plans.append(self._plan_chunked_query(
                    q, self.query_counter, batch_scanned))

        t0 = time.perf_counter()
        chunk_bytes, file_bytes = self.chunks.size_tables()
        # An early query's chunk may have been split by a later query in
        # the same batch: remap every access onto the present leaf set
        # (identity for a batch of one) before the policy rounds.
        accesses: List[QueryAccess] = []
        for p in plans:
            queried_now: List[ChunkMeta] = []
            by_file_now: Dict[int, List[int]] = {}
            for cm in p.queried:
                for u in self.chunks.current_units(cm):
                    queried_now.append(u)
                    by_file_now.setdefault(u.file_id, []).append(u.chunk_id)
            accesses.append(QueryAccess(p.query_index, queried_now,
                                        by_file_now))
        deferred_evicted = 0
        if self.spec.granularity == "chunk":
            # File units admit online during the scan loop; chunk units
            # admit here, in one Alg.-2/LRU/LFU round over the batch.
            deferred_evicted = self.eviction.finalize_batch(EvictionContext(
                accesses=accesses, chunk_bytes=chunk_bytes,
                file_bytes=file_bytes, state=self.cache, chunks=self.chunks))

        replicas: Dict[int, Set[int]] = {}
        for p in plans:
            for cid, nodes in p.join_plan.replicas.items():
                replicas.setdefault(cid, set()).update(nodes)
        placement, extra_bytes = self.placement.place(PlacementContext(
            replicas=replicas,
            queried=[cm for acc in accesses for cm in acc.queried],
            join_history=self.join_history, chunk_bytes=chunk_bytes,
            node_budgets=self.cache.placement_budgets(), state=self.cache,
            home_of=self.chunks.home_node, decay=self.decay,
            history_window=self.history_window))
        if placement is not None:
            # Keep the eviction policy's residency view in sync with
            # placement drops (no-op for cost: triples re-enter as
            # uncached bytes next round, the seed behavior).
            for cid in placement.dropped:
                self.eviction.discard(cid)
        t_evict_place = time.perf_counter() - t0

        cached_bytes = self.cache.cached_bytes(chunk_bytes)
        cached_chunks = len(self.cache.cached)
        reports = []
        for i, p in enumerate(plans):
            last = i == len(plans) - 1
            reports.append(QueryReport(
                query_index=p.query_index, policy=self.policy,
                files_considered=p.files_considered,
                files_pruned=p.files_pruned,
                files_scanned=p.files_scanned,
                scan_bytes_by_node=p.scan_bytes_by_node,
                decode_cells_by_node=p.decode_cells_by_node,
                queried_chunks=p.queried, queried_cells=p.queried_cells,
                join_plan=p.join_plan,
                placement=placement if last else None,
                placement_extra_bytes=extra_bytes if last else 0,
                cached_bytes_after=cached_bytes,
                cached_chunks_after=cached_chunks,
                evicted_items=p.online_evicted
                + (deferred_evicted if last else 0),
                opt_time_chunking_s=p.opt_time_chunking_s,
                opt_time_evict_place_s=t_evict_place if last else 0.0,
                refine_stats=p.refine_stats, batch_size=len(plans)))
        return reports

    # ---- per-query planning: chunk granularity (cost, chunk_lru, ...) ----

    def _plan_chunked_query(self, query: SimilarityJoinQuery, l: int,
                            batch_scanned: Set[int]) -> _QueryPlan:
        candidates = self.catalog.files_overlapping(query.box)
        scans: List[int] = []
        scan_bytes: Dict[int, int] = {}
        decode_cells: Dict[int, Dict[str, int]] = {}
        queried: List[ChunkMeta] = []
        cells_in_q = 0
        pruned = 0
        t0 = time.perf_counter()
        rstats = RefineStats()
        for meta in candidates:
            first_touch = meta.file_id not in self.chunks.trees
            tree = self.chunks.tree(meta)
            overlapping = tree.overlapping(query.box)
            if not overlapping:
                pruned += 1           # refined boxes prune the file entirely
                continue
            miss = (first_touch
                    or any(c.chunk_id not in self.cache.cached
                           for c in overlapping)) \
                and meta.file_id not in batch_scanned
            chunks = tree.refine(query.box, rstats)
            self.chunks.remap_after_splits(tree, self.cache, self.eviction)
            if miss:
                scans.append(meta.file_id)
                batch_scanned.add(meta.file_id)
                scan_bytes[meta.node] = (scan_bytes.get(meta.node, 0)
                                         + meta.file_bytes)
                decode_cells.setdefault(meta.node, {}).setdefault(meta.fmt, 0)
                decode_cells[meta.node][meta.fmt] += meta.n_cells
            if not chunks:
                # Overlap was empty space — carved off by the refinement.
                continue
            for c in chunks:
                cm = ChunkMeta.of(c)
                queried.append(cm)
                cells_in_q += int(points_in_box(
                    tree.coords[c.cell_idx], query.box).sum())
        t_chunking = time.perf_counter() - t0

        # Locations at query start: cache location, else home node (the scan
        # just materialized the chunk there).
        locations = {cm.chunk_id: self.cache.locations.get(
            cm.chunk_id, self.catalog.by_id(cm.file_id).node)
            for cm in queried}
        jplan = plan_join(queried, locations,
                          0 if query.eps <= 0 else query.eps, self.n_nodes)
        self.join_history.append(JoinRecord(l, tuple(jplan.pairs)))
        if len(self.join_history) > self.history_window:
            self.join_history = self.join_history[-self.history_window:]

        return _QueryPlan(
            query=query, query_index=l, files_considered=len(candidates),
            files_pruned=pruned, files_scanned=scans,
            scan_bytes_by_node=scan_bytes, decode_cells_by_node=decode_cells,
            queried=queried, queried_cells=cells_in_q, join_plan=jplan,
            opt_time_chunking_s=t_chunking, refine_stats=rstats)

    # ---- per-query planning: file granularity (file_lru, file_lfu) ----

    def _plan_file_query(self, query: SimilarityJoinQuery,
                         l: int) -> _QueryPlan:
        """Whole files as single-chunk units, admitted online: the scan
        decision consults the live cache, so an admission earlier in the
        loop can evict (and force a rescan of) a later candidate — the
        paper's file-LRU baseline semantics."""
        candidates = self.catalog.files_overlapping(query.box)
        scans: List[int] = []
        scan_bytes: Dict[int, int] = {}
        decode_cells: Dict[int, Dict[str, int]] = {}
        queried: List[ChunkMeta] = []
        cells_in_q = 0
        evicted = 0
        for meta in candidates:
            unit = self.chunks.file_unit(meta)
            if not self.eviction.is_resident(unit.chunk_id):
                scans.append(meta.file_id)
                scan_bytes[meta.node] = (scan_bytes.get(meta.node, 0)
                                         + meta.file_bytes)
                decode_cells.setdefault(meta.node, {}).setdefault(meta.fmt, 0)
                decode_cells[meta.node][meta.fmt] += meta.n_cells
            evicted += self.eviction.admit_online(unit, self.cache)
            queried.append(unit)
            coords, _ = self.reader.read(meta.file_id)
            cells_in_q += int(points_in_box(coords, query.box).sum())
        locations = {cm.chunk_id: self.catalog.by_id(cm.file_id).node
                     for cm in queried}
        jplan = plan_join(queried, locations, query.eps, self.n_nodes)
        return _QueryPlan(
            query=query, query_index=l, files_considered=len(candidates),
            files_pruned=0, files_scanned=scans,
            scan_bytes_by_node=scan_bytes, decode_cells_by_node=decode_cells,
            queried=queried, queried_cells=cells_in_q, join_plan=jplan,
            opt_time_chunking_s=0.0, refine_stats=RefineStats(),
            online_evicted=evicted)
