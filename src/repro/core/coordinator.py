"""Cache coordinator: the per-query planning pipeline of Figure 2.

For each similarity-join query the coordinator produces four plans:
  1. chunking refinement (Alg. 1, evolving R-tree per file);
  2. join execution plan (chunk pair -> node, [63]-style);
  3. cache eviction plan (Alg. 2, or LRU baselines);
  4. cache placement plan (Alg. 3, or static baseline).

The coordinator sees only metadata (bounding boxes, counts, sizes, cache
content tables) — cell data stays on the nodes (the cluster layer). Policies:

  * ``cost``      — the paper's proposal: chunking + Alg. 2 + Alg. 3.
  * ``chunk_lru`` — chunking + distributed chunk-granularity LRU, chunks stay
                    at their origin node (no placement).
  * ``file_lru``  — no chunking: whole files are the cache/join units.
"""
from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

if TYPE_CHECKING:  # duck-typed at runtime to avoid a package cycle
    from repro.arrayio.catalog import Catalog, FileReader
from repro.core.chunk import ChunkMeta, FileMeta
from repro.core.eviction import LRUCache, Triple, cost_based_eviction
from repro.core.geometry import Box, points_in_box
from repro.core.join_planner import JoinPlan, plan_join
from repro.core.placement import (JoinRecord, PlacementResult,
                                  cost_based_placement, static_placement)
from repro.core.rtree import EvolvingRTree, RefineStats

POLICIES = ("cost", "chunk_lru", "file_lru")


@dataclasses.dataclass(frozen=True)
class SimilarityJoinQuery:
    box: Box
    eps: int = 1


@dataclasses.dataclass
class QueryReport:
    query_index: int
    policy: str
    files_considered: int
    files_pruned: int
    files_scanned: List[int]
    scan_bytes_by_node: Dict[int, int]
    decode_cells_by_node: Dict[int, Dict[str, int]]
    queried_chunks: List[ChunkMeta]
    queried_cells: int
    join_plan: Optional[JoinPlan]
    placement: Optional[PlacementResult]
    placement_extra_bytes: int
    cached_bytes_after: int
    cached_chunks_after: int
    evicted_items: int
    opt_time_chunking_s: float
    opt_time_evict_place_s: float
    refine_stats: RefineStats


class CacheCoordinator:
    def __init__(self, catalog: "Catalog", reader: "FileReader", n_nodes: int,
                 node_budget_bytes: int, policy: str = "cost",
                 placement_mode: str = "dynamic", min_cells: int = 256,
                 decay: float = 2.0, history_window: int = 64):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}")
        if placement_mode not in ("dynamic", "static"):
            raise ValueError(f"unknown placement mode {placement_mode!r}")
        self.catalog = catalog
        self.reader = reader
        self.n_nodes = n_nodes
        self.node_budget = node_budget_bytes
        self.total_budget = node_budget_bytes * n_nodes
        self.policy = policy
        self.placement_mode = placement_mode
        self.min_cells = min_cells
        self.decay = decay
        self.history_window = history_window

        self._chunk_counter = 0
        self.trees: Dict[int, EvolvingRTree] = {}
        self.chunk_file: Dict[int, int] = {}       # chunk_id -> file_id
        self.locations: Dict[int, int] = {}        # cached chunk -> node
        self.cached: Set[int] = set()              # cached chunk ids
        self.state: List[Triple] = []              # Alg. 2 state S
        self.join_history: List[JoinRecord] = []   # Alg. 3 workload W
        self.lru = LRUCache(self.total_budget)     # baselines
        self.query_counter = 0

    # ------------------------------------------------------------ plumbing

    def _next_chunk_id(self) -> int:
        self._chunk_counter += 1
        return self._chunk_counter

    def _tree(self, meta: FileMeta) -> EvolvingRTree:
        tree = self.trees.get(meta.file_id)
        if tree is None:
            coords, _ = self.reader.read(meta.file_id)
            # Cap chunk size at a quarter of one node's budget so placement
            # can always pack what eviction retains (rtree.py max_cells).
            max_cells = max(2 * self.min_cells,
                            self.node_budget // (4 * meta.cell_bytes))
            tree = EvolvingRTree(meta.file_id, coords, meta.cell_bytes,
                                 self.min_cells, self._next_chunk_id,
                                 max_cells=max_cells)
            self.trees[meta.file_id] = tree
            self.chunk_file[tree.leaves()[0].chunk_id] = meta.file_id
        return tree

    def _descendants(self, chunk_id: int) -> List[int]:
        fid = self.chunk_file.get(chunk_id)
        if fid is None:
            return []
        return self.trees[fid].descendants(chunk_id)

    def _remap_after_splits(self, tree: EvolvingRTree) -> None:
        """Propagate split chunk ids through cache bookkeeping: children
        inherit residency and location from the retired parent."""
        for cid, children in list(tree.split_children.items()):
            for ch in children:
                self.chunk_file.setdefault(ch, tree.file_id)
            if cid in self.cached:
                self.cached.discard(cid)
                loc = self.locations.pop(cid, None)
                for ch in tree.descendants(cid):
                    self.cached.add(ch)
                    if loc is not None:
                        self.locations[ch] = loc
            if self.policy == "chunk_lru" and cid in self.lru:
                loc = self.locations.get(cid)
                kids = [(ch, tree.get_chunk(ch).nbytes)
                        for ch in tree.descendants(cid)]
                self.lru.rename(cid, kids)

    # ------------------------------------------------------------- queries

    def process_query(self, query: SimilarityJoinQuery) -> QueryReport:
        self.query_counter += 1
        if self.policy == "file_lru":
            return self._process_file_lru(query)
        return self._process_chunked(query)

    # ---- chunked policies (cost, chunk_lru) ----

    def _process_chunked(self, query: SimilarityJoinQuery) -> QueryReport:
        l = self.query_counter
        candidates = self.catalog.files_overlapping(query.box)
        scans: List[int] = []
        scan_bytes: Dict[int, int] = {}
        decode_cells: Dict[int, Dict[str, int]] = {}
        queried: List[ChunkMeta] = []
        queried_by_file: Dict[int, List[int]] = {}
        cells_in_q = 0
        pruned = 0
        t0 = time.perf_counter()
        rstats = RefineStats()
        for meta in candidates:
            first_touch = meta.file_id not in self.trees
            tree = self._tree(meta)
            overlapping = tree.overlapping(query.box)
            if not overlapping:
                pruned += 1           # refined boxes prune the file entirely
                continue
            miss = first_touch or any(c.chunk_id not in self.cached
                                      for c in overlapping)
            chunks = tree.refine(query.box, rstats)
            self._remap_after_splits(tree)
            if not chunks:
                # Overlap was empty space — carved off by the refinement.
                if miss:
                    scans.append(meta.file_id)
                    scan_bytes[meta.node] = (scan_bytes.get(meta.node, 0)
                                             + meta.file_bytes)
                    decode_cells.setdefault(meta.node, {}).setdefault(meta.fmt, 0)
                    decode_cells[meta.node][meta.fmt] += meta.n_cells
                continue
            if miss:
                scans.append(meta.file_id)
                scan_bytes[meta.node] = (scan_bytes.get(meta.node, 0)
                                         + meta.file_bytes)
                decode_cells.setdefault(meta.node, {}).setdefault(meta.fmt, 0)
                decode_cells[meta.node][meta.fmt] += meta.n_cells
            for c in chunks:
                cm = ChunkMeta.of(c)
                queried.append(cm)
                queried_by_file.setdefault(meta.file_id, []).append(c.chunk_id)
                cells_in_q += int(points_in_box(
                    tree.coords[c.cell_idx], query.box).sum())
        t_chunking = time.perf_counter() - t0

        # Locations at query start: cache location, else home node (the scan
        # just materialized the chunk there).
        locations = {}
        for cm in queried:
            home = self.catalog.by_id(cm.file_id).node
            locations[cm.chunk_id] = self.locations.get(cm.chunk_id, home)

        jplan = plan_join(queried, locations, 0 if query.eps <= 0 else query.eps,
                          self.n_nodes)
        self.join_history.append(
            JoinRecord(l, tuple(jplan.pairs)))
        if len(self.join_history) > self.history_window:
            self.join_history = self.join_history[-self.history_window:]

        t1 = time.perf_counter()
        placement: Optional[PlacementResult] = None
        extra_bytes = 0
        evicted_count = 0
        if self.policy == "cost":
            chunk_bytes, file_bytes = self._size_tables()
            current = [Triple(l, fid, frozenset(cids))
                       for fid, cids in queried_by_file.items()]
            history = [t.remap(self._descendants) for t in self.state]
            history = [t for t in history if t.chunk_ids]
            res = cost_based_eviction(history, current, self.total_budget,
                                      chunk_bytes, file_bytes, self.decay)
            evicted_count = len(self.cached - res.cached_chunks)
            self.state = res.state
            if len(self.state) > 4 * self.history_window:
                self.state = sorted(self.state,
                                    key=lambda t: -t.query_index
                                    )[:4 * self.history_window]
            self.cached = res.cached_chunks
            # Replicas induced by the join, restricted to retained chunks.
            replicas = {cid: set(nodes)
                        for cid, nodes in jplan.replicas.items()
                        if cid in self.cached}
            for cid in self.cached:
                if cid not in replicas:
                    loc = self.locations.get(cid)
                    if loc is None:
                        loc = self.catalog.by_id(self.chunk_file[cid]).node
                    replicas[cid] = {loc}
            # Global budget semantics, matching the LRU baselines ("all the
            # memory across the cluster as unified distributed memory",
            # §4.2.1): eviction already enforced sum <= B, so placement
            # packs against the aggregate and optimizes location only —
            # pure piggyback, no forced drops/ships. Per-node hard limits
            # can be restored via node_budget_bytes in PlacementResult
            # consumers (the serving engine uses them).
            budgets = {n: self.total_budget for n in range(self.n_nodes)}
            if self.placement_mode == "dynamic":
                placement = cost_based_placement(
                    self.join_history, replicas, chunk_bytes, budgets,
                    self.decay, self.history_window)
            else:
                home = {cid: self.catalog.by_id(self.chunk_file[cid]).node
                        for cid in replicas}
                placement = static_placement(replicas, home, chunk_bytes,
                                             budgets)
            for cid in placement.dropped:
                self.cached.discard(cid)
            self.locations = dict(placement.locations)
            extra_bytes = sum(chunk_bytes[c]
                              for c, _ in placement.fallback_moves)
        else:  # chunk_lru
            sizes = self._size_tables()[0]
            for cm in queried:
                evicted = self.lru.admit(cm.chunk_id, cm.nbytes)
                evicted_count += len(evicted)
                for e in evicted:
                    self.locations.pop(e, None)
                self.lru.touch(cm.chunk_id)
            self.cached = self.lru.ids()
            for cm in queried:
                if cm.chunk_id in self.cached:
                    self.locations.setdefault(
                        cm.chunk_id, self.catalog.by_id(cm.file_id).node)
        t_evict_place = time.perf_counter() - t1

        cached_bytes = self._cached_bytes()
        return QueryReport(
            query_index=l, policy=self.policy,
            files_considered=len(candidates), files_pruned=pruned,
            files_scanned=scans, scan_bytes_by_node=scan_bytes,
            decode_cells_by_node=decode_cells, queried_chunks=queried,
            queried_cells=cells_in_q, join_plan=jplan, placement=placement,
            placement_extra_bytes=extra_bytes,
            cached_bytes_after=cached_bytes,
            cached_chunks_after=len(self.cached),
            evicted_items=evicted_count,
            opt_time_chunking_s=t_chunking,
            opt_time_evict_place_s=t_evict_place,
            refine_stats=rstats)

    # ---- file_lru baseline ----

    def _process_file_lru(self, query: SimilarityJoinQuery) -> QueryReport:
        l = self.query_counter
        candidates = self.catalog.files_overlapping(query.box)
        scans: List[int] = []
        scan_bytes: Dict[int, int] = {}
        decode_cells: Dict[int, Dict[str, int]] = {}
        queried: List[ChunkMeta] = []
        cells_in_q = 0
        evicted_count = 0
        for meta in candidates:
            if meta.file_id not in self.lru:
                scans.append(meta.file_id)
                scan_bytes[meta.node] = (scan_bytes.get(meta.node, 0)
                                         + meta.file_bytes)
                decode_cells.setdefault(meta.node, {}).setdefault(meta.fmt, 0)
                decode_cells[meta.node][meta.fmt] += meta.n_cells
            mem_bytes = meta.n_cells * meta.cell_bytes
            evicted_count += len(self.lru.admit(meta.file_id, mem_bytes))
            self.lru.touch(meta.file_id)
            # Whole file acts as one join unit (negative ids: file "chunks").
            queried.append(ChunkMeta(chunk_id=-(meta.file_id + 1),
                                     file_id=meta.file_id, box=meta.box,
                                     n_cells=meta.n_cells, nbytes=mem_bytes))
            coords, _ = self.reader.read(meta.file_id)
            cells_in_q += int(points_in_box(coords, query.box).sum())
        locations = {cm.chunk_id: self.catalog.by_id(cm.file_id).node
                     for cm in queried}
        jplan = plan_join(queried, locations, query.eps, self.n_nodes)
        return QueryReport(
            query_index=l, policy=self.policy,
            files_considered=len(candidates), files_pruned=0,
            files_scanned=scans, scan_bytes_by_node=scan_bytes,
            decode_cells_by_node=decode_cells, queried_chunks=queried,
            queried_cells=cells_in_q, join_plan=jplan, placement=None,
            placement_extra_bytes=0,
            cached_bytes_after=self.lru.used_bytes,
            cached_chunks_after=len(self.lru.ids()),
            evicted_items=evicted_count,
            opt_time_chunking_s=0.0, opt_time_evict_place_s=0.0,
            refine_stats=RefineStats())

    # ------------------------------------------------------------- helpers

    def _size_tables(self) -> Tuple[Dict[int, int], Dict[int, int]]:
        chunk_bytes: Dict[int, int] = {}
        for tree in self.trees.values():
            for c in tree.leaves():
                chunk_bytes[c.chunk_id] = c.nbytes
        file_bytes = {f.file_id: f.file_bytes for f in self.catalog.files}
        return chunk_bytes, file_bytes

    def _cached_bytes(self) -> int:
        if self.policy == "chunk_lru":
            return self.lru.used_bytes
        total = 0
        for cid in self.cached:
            fid = self.chunk_file.get(cid)
            if fid is None:
                continue
            tree = self.trees[fid]
            if cid in tree._leaves:
                total += tree.get_chunk(cid).nbytes
        return total
