"""Semantic cache reuse: a coverage index over resident chunk extents.

The paper routes every query through the catalog even when the requested
region is fully covered by chunks already resident in the cache. This
module adds the missing *semantic* layer (multi-query optimization a la
Michiardi et al., "Cache-based Multi-query Optimization", and the fast
containment tests over cached extents motivated by Krcal et al.'s
hierarchical bitmap indexing — both in PAPERS.md):

  * ``CoverageIndex`` — a two-level interval/R-tree structure over the
    bounding boxes of resident chunks (file-level bounding box on top,
    chunk boxes underneath), kept in sync by ``CacheState`` on
    admit/evict/split-remap.
  * ``QueryRewrite`` — a query region rewritten into (a) *covered slices*,
    sub-regions answerable from covering cached chunks that are sliced in
    place on their owning nodes, and (b) *residual* boxes that follow the
    existing catalog/scan path (``geometry.box_subtract`` decomposition).

Soundness note (why residuals compose per file): within one file the
evolving R-tree's leaf boxes are tight and pairwise disjoint, so a cached
chunk's box covers exactly that file's cells inside it — but cells of
*other* files may share the region. The coordinator therefore combines
box-level coverage from this index with a per-file cell-exact containment
test before it skips a raw-file scan (``CacheCoordinator``, reuse knob).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Set

from repro.core.chunk import ChunkMeta
from repro.core.geometry import Box, enclosing, residual_boxes

__all__ = ["CoveredSlice", "QueryRewrite", "CoverageIndex"]


@dataclasses.dataclass(frozen=True)
class CoveredSlice:
    """A sub-region of a query answerable from one covering cached chunk.

    ``box`` is the intersection of the chunk's bounding box with the query
    region — the extent the owning node slices in place; shipped bytes for
    the join are charged only for the cells inside this slice.
    """

    chunk_id: int
    file_id: int
    box: Box                      # chunk box ∩ query box


@dataclasses.dataclass
class QueryRewrite:
    """A query region rewritten against the cache's covered extents.

    ``covered`` lists the cached-chunk slices that serve sub-regions of the
    query; ``residual`` is the query region minus the union of covering
    chunk boxes, as disjoint boxes that follow the normal catalog/scan
    path. ``fully_covered`` (empty residual) is the box-level
    all-from-cache fast path — the coordinator still confirms it with a
    cell-exact test per file before skipping scans.
    """

    query: Box
    covered: List[CoveredSlice]
    residual: List[Box]

    @property
    def fully_covered(self) -> bool:
        """True when the covering cached boxes leave no residual region."""
        return not self.residual

    def covered_chunk_ids(self) -> Set[int]:
        """Chunk ids of every covering cached chunk in the rewrite."""
        return {s.chunk_id for s in self.covered}


class CoverageIndex:
    """Two-level box index over the extents of resident chunks.

    Level 1 prunes by per-file bounding boxes (recomputed lazily after
    removals), level 2 tests the chunk boxes themselves — the hierarchical
    containment-test structure the reuse rewrite consults before a query's
    scan plan is built. Mutations mirror cache residency: ``add`` on
    admission, ``remove`` on eviction/drop, ``remap_split`` when the
    evolving R-tree retires a cached chunk into children
    (``CacheState`` drives all three).
    """

    def __init__(self) -> None:
        self._entries: Dict[int, ChunkMeta] = {}      # chunk_id -> meta
        self._by_file: Dict[int, Set[int]] = {}       # file_id -> chunk ids
        self._file_bb: Dict[int, Optional[Box]] = {}  # lazy file-level bbox

    # ------------------------------------------------------------ mutation

    def add(self, meta: ChunkMeta) -> None:
        """Index a newly resident chunk's bounding box."""
        self._entries[meta.chunk_id] = meta
        ids = self._by_file.setdefault(meta.file_id, set())
        ids.add(meta.chunk_id)
        bb = self._file_bb.get(meta.file_id)
        if bb is not None:
            self._file_bb[meta.file_id] = bb.union_bb(meta.box)
        elif len(ids) == 1:
            self._file_bb[meta.file_id] = meta.box
        # else: entry is dirty (None after a removal) — the next
        # ``_file_box`` call recomputes the union including this box.

    def remove(self, chunk_id: int) -> None:
        """Drop an evicted chunk; no-op when the id is not indexed."""
        meta = self._entries.pop(chunk_id, None)
        if meta is None:
            return
        ids = self._by_file.get(meta.file_id)
        if ids is not None:
            ids.discard(chunk_id)
            if not ids:
                del self._by_file[meta.file_id]
                self._file_bb.pop(meta.file_id, None)
            else:
                # Shrinking a union is not incremental: recompute lazily.
                self._file_bb[meta.file_id] = None

    def remap_split(self, parent_id: int,
                    children: Iterable[ChunkMeta]) -> None:
        """A cached chunk split: children inherit the parent's coverage."""
        if parent_id not in self._entries:
            return
        self.remove(parent_id)
        for cm in children:
            self.add(cm)

    # ------------------------------------------------------------- queries

    def __contains__(self, chunk_id: int) -> bool:
        return chunk_id in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def ids(self) -> Set[int]:
        """The indexed (resident) chunk-id set."""
        return set(self._entries)

    def box_of(self, chunk_id: int) -> Optional[Box]:
        """The indexed extent for ``chunk_id`` (``None`` if absent) —
        the invariant auditor compares it against chunk metadata."""
        meta = self._entries.get(chunk_id)
        return meta.box if meta is not None else None

    def _file_box(self, file_id: int) -> Optional[Box]:
        bb = self._file_bb.get(file_id)
        if bb is None and self._by_file.get(file_id):
            bb = enclosing(self._entries[cid].box
                           for cid in self._by_file[file_id])
            self._file_bb[file_id] = bb
        return bb

    def overlapping(self, box: Box) -> List[ChunkMeta]:
        """Resident chunks whose bounding box overlaps ``box`` (file-level
        prune, then chunk-level test), in chunk-id order."""
        out: List[ChunkMeta] = []
        for file_id, ids in self._by_file.items():
            bb = self._file_box(file_id)
            if bb is None or not bb.overlaps(box):
                continue
            out.extend(self._entries[cid] for cid in ids
                       if self._entries[cid].box.overlaps(box))
        out.sort(key=lambda m: m.chunk_id)
        return out

    def residual(self, box: Box) -> List[Box]:
        """``box`` minus the union of all resident chunk boxes."""
        return residual_boxes(box, (m.box for m in self.overlapping(box)))

    def rewrite(self, box: Box) -> QueryRewrite:
        """Rewrite a query region into covered slices + residual boxes."""
        covering = self.overlapping(box)
        covered = []
        for m in covering:
            inter = m.box.intersection(box)
            if inter is not None:
                covered.append(CoveredSlice(m.chunk_id, m.file_id, inter))
        residual = residual_boxes(box, (s.box for s in covered))
        return QueryRewrite(query=box, covered=covered, residual=residual)
