"""Cache eviction: cost-based (Alg. 2) plus the paper's two LRU baselines.

The cache *state* is the set of triples ``(Q_l, f_i, {C_j})`` — the chunks of
file ``f_i`` accessed by query ``Q_l`` (§3.3). The cost of keeping a triple:

    cost(Q_l, f_i, {C_j}) = w(l) * size(f_i) / sum(size(uncached C_j))

with exponentially decayed query weights ``w(l) = decay**l``. A triple whose
chunks are all already retained costs nothing to keep (ratio = +inf). Costs
are evaluated in log2 space so 100-query workloads don't overflow.

Alg. 2 is a greedy *keep* loop: seed the new state with the current query's
triples, then repeatedly keep the highest-cost triple that fits the cumulated
budget. Keeping a triple raises the cost of every other triple sharing chunks
with it (their uncached denominator shrinks) — implemented with a max-heap
and versioned lazy re-insertion, O(N log N) as in the paper.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import OrderedDict
from typing import Dict, FrozenSet, Iterable, List, Sequence, Set, Tuple


@dataclasses.dataclass(frozen=True)
class Triple:
    """(Q_l, f_i, {C_j}) — chunks of file f_i accessed at query index l."""

    query_index: int
    file_id: int
    chunk_ids: FrozenSet[int]

    def remap(self, descendants) -> "Triple":
        """Remap split chunk ids onto their current leaves."""
        out: Set[int] = set()
        for cid in self.chunk_ids:
            out.update(descendants(cid))
        return Triple(self.query_index, self.file_id, frozenset(out))


@dataclasses.dataclass
class EvictionResult:
    """Output of one Alg.-2 round: the new state S' and retained chunks."""

    state: List[Triple]            # the retained triples S'
    cached_chunks: Set[int]        # union of chunk ids across S'
    kept_from_history: int
    dropped_from_history: int


def _log_cost(triple: Triple, cached: Set[int], chunk_bytes: Dict[int, int],
              file_bytes: Dict[int, int], log2_decay: float) -> float:
    uncached = sum(chunk_bytes[c] for c in triple.chunk_ids if c not in cached)
    if uncached == 0:
        return math.inf
    return (triple.query_index * log2_decay
            + math.log2(file_bytes[triple.file_id]) - math.log2(uncached))


def _uncached_bytes(triple: Triple, cached: Set[int],
                    chunk_bytes: Dict[int, int]) -> int:
    return sum(chunk_bytes[c] for c in triple.chunk_ids if c not in cached)


def cost_based_eviction(history: Sequence[Triple],
                        current: Sequence[Triple],
                        budget_bytes: int,
                        chunk_bytes: Dict[int, int],
                        file_bytes: Dict[int, int],
                        decay: float = 2.0) -> EvictionResult:
    """Alg. 2. ``current`` triples are always retained (they are resident for
    the running query; if they alone exceed the budget the loop simply keeps
    nothing else). Returns the updated state S' and the retained chunk set."""
    log2_decay = math.log2(decay)
    state: List[Triple] = list(current)
    cached: Set[int] = set()
    for t in current:
        cached.update(t.chunk_ids)
    used = sum(chunk_bytes[c] for c in cached)

    triples = list(history)
    # chunk -> indices of history triples containing it (for line 6 updates).
    by_chunk: Dict[int, List[int]] = {}
    for i, t in enumerate(triples):
        for c in t.chunk_ids:
            by_chunk.setdefault(c, []).append(i)

    version = [0] * len(triples)
    accepted = [False] * len(triples)
    heap: List[Tuple[float, int, int, int]] = []  # (-logcost, tiebreak, ver, idx)
    for i, t in enumerate(triples):
        lc = _log_cost(t, cached, chunk_bytes, file_bytes, log2_decay)
        heapq.heappush(heap, (-lc, -t.query_index, 0, i))

    deferred: List[int] = []
    kept = 0
    while heap:
        neg_lc, _, ver, i = heapq.heappop(heap)
        if accepted[i] or ver != version[i]:
            continue
        need = _uncached_bytes(triples[i], cached, chunk_bytes)
        if need > 0 and used + need > budget_bytes:
            deferred.append(i)
            continue
        # Keep it.
        accepted[i] = True
        kept += 1
        state.append(triples[i])
        used += need
        newly = [c for c in triples[i].chunk_ids if c not in cached]
        cached.update(newly)
        # Line 6: boost triples sharing the newly cached chunks.
        touched: Set[int] = set()
        for c in newly:
            touched.update(by_chunk.get(c, ()))
        for j in touched:
            if accepted[j]:
                continue
            version[j] += 1
            lc = _log_cost(triples[j], cached, chunk_bytes, file_bytes,
                           log2_decay)
            heapq.heappush(heap, (-lc, -triples[j].query_index, version[j], j))
        # Newly cached bytes may have made deferred triples fit (or free).
        if deferred:
            for j in deferred:
                if not accepted[j]:
                    version[j] += 1
                    lc = _log_cost(triples[j], cached, chunk_bytes, file_bytes,
                                   log2_decay)
                    heapq.heappush(heap, (-lc, -triples[j].query_index,
                                          version[j], j))
            deferred.clear()
    return EvictionResult(state=state, cached_chunks=cached,
                          kept_from_history=kept,
                          dropped_from_history=len(triples) - kept)


# --------------------------------------------------------------------------
# Baselines (§4.1): distributed LRU at file and chunk granularity.
# --------------------------------------------------------------------------

class LRUCache:
    """Distributed-unified-memory LRU over items with sizes (file or chunk
    granularity). ``touch`` marks use; ``admit`` inserts then evicts LRU
    items until the aggregate budget is respected."""

    def __init__(self, budget_bytes: int):
        self.budget = budget_bytes
        self._items: "OrderedDict[int, int]" = OrderedDict()  # id -> bytes

    def __contains__(self, item_id: int) -> bool:
        return item_id in self._items

    @property
    def used_bytes(self) -> int:
        """Total bytes of resident items."""
        return sum(self._items.values())

    def ids(self) -> Set[int]:
        """The resident item-id set."""
        return set(self._items.keys())

    def touch(self, item_id: int) -> None:
        """Mark an item most-recently-used (no-op when absent)."""
        if item_id in self._items:
            self._items.move_to_end(item_id)

    def admit(self, item_id: int, nbytes: int) -> List[int]:
        """Insert/refresh an item; returns ids evicted to make room. Items
        larger than the whole budget are not admitted (paper's LRU baselines
        never split items)."""
        evicted: List[int] = []
        if nbytes > self.budget:
            return evicted
        if item_id in self._items:
            self._items.move_to_end(item_id)
            return evicted
        self._items[item_id] = nbytes
        used = self.used_bytes
        while used > self.budget:
            old_id, old_bytes = self._items.popitem(last=False)
            if old_id == item_id:
                # Shouldn't happen (just admitted to MRU end) — guard anyway.
                self._items[item_id] = nbytes
                break
            evicted.append(old_id)
            used -= old_bytes
        return evicted

    def remove(self, item_id: int) -> None:
        """Forget an item without counting it as an eviction."""
        self._items.pop(item_id, None)

    def rename(self, old_id: int, new_ids: Iterable[Tuple[int, int]]) -> None:
        """Replace a split item by its children, preserving recency order as
        best as an LRU can (children inherit the parent's slot)."""
        if old_id not in self._items:
            return
        items = list(self._items.items())
        self._items.clear()
        for iid, nb in items:
            if iid == old_id:
                for cid, cb in new_ids:
                    self._items[cid] = cb
            else:
                self._items[iid] = nb


class LFUCache:
    """Distributed-unified-memory LFU over items with sizes. Victims are
    the least-frequently-used items, recency-LRU among equal frequencies
    (the classic LFU tie-break). Same admit/touch/rename surface as
    ``LRUCache`` so the policy layer can swap them freely."""

    def __init__(self, budget_bytes: int):
        self.budget = budget_bytes
        self._bytes: Dict[int, int] = {}
        self._freq: Dict[int, int] = {}
        self._clock: Dict[int, int] = {}
        self._tick = 0

    def __contains__(self, item_id: int) -> bool:
        return item_id in self._bytes

    @property
    def used_bytes(self) -> int:
        """Total bytes of resident items."""
        return sum(self._bytes.values())

    def ids(self) -> Set[int]:
        """The resident item-id set."""
        return set(self._bytes.keys())

    def touch(self, item_id: int) -> None:
        """Bump an item's frequency and recency clock (no-op when absent)."""
        if item_id in self._bytes:
            self._tick += 1
            self._freq[item_id] += 1
            self._clock[item_id] = self._tick

    def admit(self, item_id: int, nbytes: int) -> List[int]:
        """Insert/refresh an item; returns ids evicted to make room. Items
        larger than the whole budget are never admitted."""
        evicted: List[int] = []
        if nbytes > self.budget:
            return evicted
        self._tick += 1
        if item_id in self._bytes:
            self._freq[item_id] += 1
            self._clock[item_id] = self._tick
            return evicted
        self._bytes[item_id] = nbytes
        self._freq[item_id] = 1
        self._clock[item_id] = self._tick
        used = self.used_bytes
        while used > self.budget:
            victim = min((i for i in self._bytes if i != item_id),
                         key=lambda i: (self._freq[i], self._clock[i]),
                         default=None)
            if victim is None:
                break
            used -= self._bytes[victim]
            self.remove(victim)
            evicted.append(victim)
        return evicted

    def remove(self, item_id: int) -> None:
        """Forget an item without counting it as an eviction."""
        self._bytes.pop(item_id, None)
        self._freq.pop(item_id, None)
        self._clock.pop(item_id, None)

    def rename(self, old_id: int, new_ids: Iterable[Tuple[int, int]]) -> None:
        """Replace a split item by its children; children inherit the
        parent's frequency and clock."""
        if old_id not in self._bytes:
            return
        freq, clock = self._freq[old_id], self._clock[old_id]
        self.remove(old_id)
        for cid, cb in new_ids:
            self._bytes[cid] = cb
            self._freq[cid] = freq
            self._clock[cid] = clock
