"""Axis-aligned box geometry over integer array domains.

The paper's arrays have dimensions represented by continuous integer ranges
[1, N] (§2.1). A ``Box`` is a closed integer hyper-rectangle ``[lo_k, hi_k]``
per dimension. Bounding boxes of chunks are always derived from the cells
assigned to the chunk (§3.1 "How to split?"), never from the query geometry.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence, Tuple

import numpy as np

Coord = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Box:
    """Closed integer hyper-rectangle: lo[k] <= x[k] <= hi[k] for all k."""

    lo: Tuple[int, ...]
    hi: Tuple[int, ...]

    def __post_init__(self) -> None:
        if len(self.lo) != len(self.hi):
            raise ValueError(f"rank mismatch: {self.lo} vs {self.hi}")
        if any(l > h for l, h in zip(self.lo, self.hi)):
            raise ValueError(f"empty box: lo={self.lo} hi={self.hi}")

    @property
    def ndim(self) -> int:
        """Dimensionality of the array domain (§2.1: d dimensions)."""
        return len(self.lo)

    def volume(self) -> int:
        """Hyper-volume as number of integer cells covered."""
        v = 1
        for l, h in zip(self.lo, self.hi):
            v *= h - l + 1
        return v

    def side(self, k: int) -> int:
        """Extent (cell count) along dimension ``k``."""
        return self.hi[k] - self.lo[k] + 1

    def contains_point(self, p: Sequence[int]) -> bool:
        """Closed-interval membership test for one coordinate."""
        return all(l <= x <= h for l, x, h in zip(self.lo, p, self.hi))

    def contains_box(self, other: "Box") -> bool:
        """True when ``other`` lies entirely inside this box."""
        return all(sl <= ol and oh <= sh for sl, sh, ol, oh in
                   zip(self.lo, self.hi, other.lo, other.hi))

    def overlaps(self, other: "Box") -> bool:
        """True when the boxes share at least one integer cell (closed
        intervals: touching faces count as overlap)."""
        return all(sl <= oh and ol <= sh for sl, sh, ol, oh in
                   zip(self.lo, self.hi, other.lo, other.hi))

    def intersection(self, other: "Box") -> Optional["Box"]:
        """The shared sub-box, or ``None`` when the boxes are disjoint."""
        lo = tuple(max(a, b) for a, b in zip(self.lo, other.lo))
        hi = tuple(min(a, b) for a, b in zip(self.hi, other.hi))
        if any(l > h for l, h in zip(lo, hi)):
            return None
        return Box(lo, hi)

    def union_bb(self, other: "Box") -> "Box":
        """Smallest box enclosing both boxes (R-tree node union)."""
        return Box(tuple(min(a, b) for a, b in zip(self.lo, other.lo)),
                   tuple(max(a, b) for a, b in zip(self.hi, other.hi)))

    def as_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """(lo, hi) corners as int64 numpy vectors for bulk point tests."""
        return np.asarray(self.lo, dtype=np.int64), np.asarray(self.hi, dtype=np.int64)


def bounding_box(coords: np.ndarray) -> Optional[Box]:
    """Tightest Box around integer coordinates ``coords`` of shape (n, d).

    Returns None for an empty cell set — the paper derives chunk boxes only
    from assigned cells, so a cell-less side of a split simply vanishes.
    """
    if coords.size == 0:
        return None
    lo = coords.min(axis=0)
    hi = coords.max(axis=0)
    return Box(tuple(int(x) for x in lo), tuple(int(x) for x in hi))


def points_in_box(coords: np.ndarray, box: Box) -> np.ndarray:
    """Boolean mask (n,) of which coords lie inside ``box``."""
    if coords.size == 0:
        return np.zeros((0,), dtype=bool)
    lo, hi = box.as_arrays()
    return np.logical_and(coords >= lo, coords <= hi).all(axis=1)


def expand(box: Box, radius: int, domain: Optional[Box] = None) -> Box:
    """Minkowski-expand a box by ``radius`` in every dimension (for L^inf /
    L^1 similarity-join neighborhoods), clipped to ``domain`` if given."""
    lo = tuple(l - radius for l in box.lo)
    hi = tuple(h + radius for h in box.hi)
    if domain is not None:
        lo = tuple(max(a, b) for a, b in zip(lo, domain.lo))
        hi = tuple(min(a, b) for a, b in zip(hi, domain.hi))
    return Box(lo, hi)


def enclosing(boxes: Iterable[Box]) -> Optional[Box]:
    """Smallest box enclosing every box in ``boxes`` (``None`` if empty)."""
    out: Optional[Box] = None
    for b in boxes:
        out = b if out is None else out.union_bb(b)
    return out


def box_subtract(a: Box, b: Box) -> "list[Box]":
    """Decompose ``a \\ b`` into disjoint residual boxes (slab decomposition).

    Peels one axis-aligned slab per face of ``b`` that cuts through ``a``,
    producing at most ``2 * ndim`` pairwise-disjoint boxes whose union is
    exactly the cells of ``a`` outside ``b``. Returns ``[a]`` when the boxes
    do not overlap and ``[]`` when ``b`` fully covers ``a`` (exact fit
    included — boxes are closed, so touching-but-not-overlapping neighbors
    share no cells and subtraction leaves ``a`` intact). This is the
    residual-region primitive of the semantic cache-reuse rewrite
    (multi-query optimization a la Michiardi et al., PAPERS.md).
    """
    inter = a.intersection(b)
    if inter is None:
        return [a]
    out: list[Box] = []
    lo = list(a.lo)
    hi = list(a.hi)
    for k in range(a.ndim):
        if lo[k] < inter.lo[k]:
            slab_hi = list(hi)
            slab_hi[k] = inter.lo[k] - 1
            out.append(Box(tuple(lo), tuple(slab_hi)))
        if inter.hi[k] < hi[k]:
            slab_lo = list(lo)
            slab_lo[k] = inter.hi[k] + 1
            out.append(Box(tuple(slab_lo), tuple(hi)))
        # Shrink the working box to b's extent along k; remaining slabs are
        # carved from dimensions > k only, keeping the pieces disjoint.
        lo[k], hi[k] = inter.lo[k], inter.hi[k]
    return out


def residual_boxes(box: Box, covers: Iterable[Box]) -> "list[Box]":
    """The part of ``box`` not covered by any box in ``covers``, as a list
    of disjoint boxes.

    Iteratively subtracts each cover from the current residual set
    (worst-case output grows with cover count; cached-extent cover sets are
    small — a query overlaps few resident chunks). An empty result means
    ``covers`` fully covers ``box``: the fully-answerable-from-cache test
    of the semantic reuse layer."""
    residual = [box]
    for cover in covers:
        if not residual:
            return residual
        residual = [piece for r in residual for piece in box_subtract(r, cover)]
    return residual


def split_boundaries(query: Box, bb: Box) -> list:
    """Candidate split boundaries per Alg. 1: the faces of the query subarray
    that pass strictly through ``bb``.

    Each boundary is ``(dim, cut)`` meaning cells with ``coord[dim] <= cut``
    go to the low side. A query face q.lo[k] maps to cut = q.lo[k]-1 (cells
    strictly below the query go low); a face q.hi[k] maps to cut = q.hi[k].
    Only faces with bb.lo[k] <= cut < bb.hi[k] actually bisect the box.
    """
    out = []
    for k in range(query.ndim):
        for cut in (query.lo[k] - 1, query.hi[k]):
            if bb.lo[k] <= cut < bb.hi[k]:
                out.append((k, cut))
    return out
