"""Similarity-join planning over cached chunks (§2.2, derived from [63]).

Given the queried chunks (with current locations) and the join shape radius
``eps`` (L^1 / L^inf neighborhood), the planner:

  1. enumerates candidate chunk pairs — pairs whose bounding boxes, one side
     expanded by ``eps``, overlap (a superset of the true joining pairs);
  2. assigns every pair to a node minimizing shipped bytes, breaking ties by
     projected compute load (|C_i| * |C_j| cell-pair work), which yields the
     transfer/balance trade-off the optimizer in [63] targets;
  3. emits the per-node execution sub-plan and the transfer list. Every
     shipped chunk creates a *replica* — the input that cache placement
     (Alg. 3) later consolidates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

from repro.core.chunk import ChunkMeta
from repro.core.geometry import Box, expand

# A chunk's starting location as handed to the planner: a bare node id
# (single copy — the seed shape) or an ordered replica tuple, primary
# first (hot-chunk replication). Both normalize through one path, so the
# single-copy plan is bit-identical whichever form the caller passes.
PlanLocation = Union[int, Tuple[int, ...]]


@dataclasses.dataclass
class JoinPlan:
    """A query's join execution plan: pair assignments, transfers, and
    the per-node byte/compute loads the §4.1 cost model charges."""

    pairs: List[Tuple[int, int]]                 # candidate chunk-id pairs
    pair_node: Dict[Tuple[int, int], int]        # pair -> executing node
    transfers: List[Tuple[int, int]]             # (chunk_id, dest node)
    transfer_routes: List[Tuple[int, int, int]]  # (chunk_id, src, dest):
    # the same ship decisions as ``transfers`` with the source node
    # recorded, so a device-backed execution backend can replay each
    # decision as a real src -> dest transfer.
    bytes_in: Dict[int, int]                     # per-node received bytes
    bytes_out: Dict[int, int]                    # per-node sent bytes
    compute_load: Dict[int, int]                 # per-node cell-pair work
    replicas: Dict[int, Set[int]]                # chunk -> nodes holding it
    # Pair-sides served in place by a SECONDARY replica (a pre-existing
    # non-primary copy — not a copy this plan shipped): the observable
    # proving replication absorbed work the primary would otherwise
    # serialize. Always 0 with single-valued locations.
    replica_hits: int = 0


def candidate_pairs(chunks: Sequence[ChunkMeta], eps: int,
                    query: Optional[Box] = None) -> List[Tuple[int, int]]:
    """Self-join candidate pairs (i <= j), including the self pair, for
    chunks whose eps-expanded boxes overlap."""
    out: List[Tuple[int, int]] = []
    metas = sorted(chunks, key=lambda c: c.chunk_id)
    for a in range(len(metas)):
        ca = metas[a]
        grown = expand(ca.box, eps)
        for b in range(a, len(metas)):
            cb = metas[b]
            if a == b or grown.overlaps(cb.box):
                out.append((ca.chunk_id, cb.chunk_id))
    return out


def plan_join(chunks: Sequence[ChunkMeta],
              locations: Dict[int, PlanLocation],
              eps: int,
              n_nodes: int,
              ship_bytes: Optional[Dict[int, int]] = None) -> JoinPlan:
    """Assign candidate pairs to nodes. ``locations[c]`` is where chunk ``c``
    is resident when the query starts (cache location, or the home node right
    after a raw scan): a bare node id, or a primary-first replica tuple
    when hot-chunk replication holds several copies. Every holder seeds
    ``node_has``, so the greedy (ship bytes, balance penalty) cost
    naturally routes each pair to its least-loaded replica; transfers
    source from the original holder with the least outbound pressure.

    ``ship_bytes`` optionally overrides the per-chunk transfer cost: the
    semantic-reuse layer charges a covering cached chunk only for the
    extent sliced to the query region (cells inside the query box), not the
    whole chunk — the owning node slices in place and ships the slice."""
    meta = {c.chunk_id: c for c in chunks}
    wire = {c.chunk_id: c.nbytes for c in chunks}
    if ship_bytes:
        wire.update((cid, b) for cid, b in ship_bytes.items() if cid in wire)
    pairs = candidate_pairs(chunks, eps)
    # Order pairs by decreasing work so the balance heuristic sees the big
    # rocks first (classic LPT scheduling).
    pairs.sort(key=lambda p: -(meta[p[0]].n_cells * meta[p[1]].n_cells))

    # Normalize every location through ONE path (int -> one-tuple), so
    # the single-copy plan is identical whichever form the caller passed.
    holders: Dict[int, Tuple[int, ...]] = {
        cid: (loc if isinstance(loc, tuple) else (int(loc),))
        for cid, loc in locations.items()}
    primary: Dict[int, int] = {cid: reps[0] for cid, reps in holders.items()}
    node_has: Dict[int, Set[int]] = {n: set() for n in range(n_nodes)}
    for cid, reps in holders.items():
        for node in reps:
            node_has[node].add(cid)
    load: Dict[int, int] = {n: 0 for n in range(n_nodes)}
    bytes_in: Dict[int, int] = {n: 0 for n in range(n_nodes)}
    bytes_out: Dict[int, int] = {n: 0 for n in range(n_nodes)}
    pair_node: Dict[Tuple[int, int], int] = {}
    transfers: List[Tuple[int, int]] = []
    routes: List[Tuple[int, int, int]] = []

    mean_load_target = (sum(meta[a].n_cells * meta[b].n_cells
                            for a, b in pairs) / max(n_nodes, 1)) or 1.0

    replica_hits = 0
    for a, b in pairs:
        ca, cb = meta[a], meta[b]
        work = ca.n_cells * cb.n_cells
        best_node, best_cost = None, None
        for n in range(n_nodes):
            ship = 0
            if a not in node_has[n]:
                ship += wire[a]
            if b not in node_has[n] and a != b:
                ship += wire[b]
            # Cost: bytes shipped, with a balance penalty proportional to the
            # node's projected overload (keeps the plan from piling compute
            # on the chunk-rich node).
            cost = (ship, max(0.0, (load[n] + work) / mean_load_target - 1.0))
            if best_cost is None or cost < best_cost:
                best_node, best_cost = n, cost
        n = best_node
        assert n is not None
        pair_node[(a, b)] = n
        load[n] += work
        for cid in {a, b}:
            if cid not in node_has[n]:
                # Ship from the ORIGINAL holder with the least outbound
                # pressure (deterministic tie-break: tuple order, which
                # is primary-first) — the single-holder case reduces to
                # the seed's ``src = locations[cid]``.
                src = min(holders[cid],
                          key=lambda s: (bytes_out[s],
                                         holders[cid].index(s)))
                node_has[n].add(cid)
                transfers.append((cid, n))
                routes.append((cid, src, n))
                bytes_in[n] += wire[cid]
                bytes_out[src] += wire[cid]
            elif n in holders[cid] and n != primary[cid]:
                # Served in place by a pre-existing secondary copy.
                replica_hits += 1

    replicas: Dict[int, Set[int]] = {}
    for cid in meta:
        replicas[cid] = {n for n in range(n_nodes) if cid in node_has[n]}
    return JoinPlan(pairs=pairs, pair_node=pair_node, transfers=transfers,
                    transfer_routes=routes, bytes_in=bytes_in,
                    bytes_out=bytes_out, compute_load=load,
                    replicas=replicas, replica_hits=replica_hits)
