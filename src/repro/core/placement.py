"""Cache placement: co-locality-maximizing replica selection (§3.4, Alg. 3).

After a join executes, chunks have replicas at their home node and at every
node the join plan shipped them to. Placement keeps exactly one copy of each
cached chunk, chosen to maximize the decayed co-location benefit

    cost(C_i, n, P', W) = sum_{Q in W} w_Q * |{C_j in P'_n : (C_i,C_j) in Q}|

subject to per-node byte budgets, visiting chunks in increasing replica count
(chunks with many replicas keep more options as budgets tighten). Candidate
nodes are the replica holders — placement *piggybacks* on the transfers the
join already performed and never ships new bytes (§3.4): when no replica
node has budget left the chunk is dropped from cache rather than shipped
(``allow_fallback_ship=True`` restores the shipping variant, whose transfer
bytes are then charged as ``fallback_moves``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Set, Tuple


@dataclasses.dataclass(frozen=True)
class JoinRecord:
    """(Q_l, {(C_i, C_j)}) — chunk pairs joined at query l (input W)."""

    query_index: int
    pairs: Tuple[Tuple[int, int], ...]


@dataclasses.dataclass
class PlacementResult:
    """Output of one placement round: final locations, paid fallback
    transfers, and chunks dropped for lack of budget (Alg. 3)."""

    locations: Dict[int, int]          # chunk_id -> node
    fallback_moves: List[Tuple[int, int]]   # (chunk_id, node) paid transfers
    dropped: List[int]                 # chunks that fit nowhere
    colocated_pair_weight: float       # achieved objective value


def _pair_weights(workload: Sequence[JoinRecord], latest_index: int,
                  decay: float, window: int) -> Dict[int, Dict[int, float]]:
    """Aggregate w(C_i, C_j) = sum_Q w_Q [ (C_i,C_j) in Q ] as adjacency maps.

    Weights are normalized to w_Q = decay**(l - latest) in (0, 1] so long
    histories neither overflow nor matter beyond the effective window.
    """
    adj: Dict[int, Dict[int, float]] = {}
    for rec in workload:
        age = latest_index - rec.query_index
        if age >= window:
            continue
        w = decay ** (-age)
        for a, b in rec.pairs:
            if a == b:
                continue
            adj.setdefault(a, {})[b] = adj.setdefault(a, {}).get(b, 0.0) + w
            adj.setdefault(b, {})[a] = adj.setdefault(b, {}).get(a, 0.0) + w
    return adj


def cost_based_placement(workload: Sequence[JoinRecord],
                         replicas: Dict[int, Set[int]],
                         chunk_bytes: Dict[int, int],
                         node_budgets: Dict[int, int],
                         decay: float = 2.0,
                         window: int = 64,
                         allow_fallback_ship: bool = False
                         ) -> PlacementResult:
    """Alg. 3. ``replicas[c]`` is the set of nodes holding a copy of cached
    chunk ``c`` after query execution; ``node_budgets`` are per-node byte
    budgets B_k."""
    latest = max((r.query_index for r in workload), default=0)
    adj = _pair_weights(workload, latest, decay, window)
    free = dict(node_budgets)
    locations: Dict[int, int] = {}
    fallback: List[Tuple[int, int]] = []
    dropped: List[int] = []
    objective = 0.0

    def colocation_gain(cid: int, node: int) -> float:
        total = 0.0
        for partner, w in adj.get(cid, {}).items():
            if locations.get(partner) == node:
                total += w
        return total

    def try_place(cid: int, candidates: Iterable[int]) -> bool:
        nonlocal objective
        nb = chunk_bytes[cid]
        best_node, best_gain = None, -1.0
        for n in candidates:
            if free.get(n, 0) < nb:
                continue
            g = colocation_gain(cid, n)
            # Tie-break on free budget to balance load across nodes.
            if g > best_gain or (g == best_gain and best_node is not None
                                 and free[n] > free[best_node]):
                best_node, best_gain = n, g
        if best_node is None:
            return False
        locations[cid] = best_node
        free[best_node] -= nb
        objective += best_gain
        return True

    # Line 1: singleton-replica chunks are pinned where they are.
    singles = [c for c, nodes in replicas.items() if len(nodes) == 1]
    multi = [c for c, nodes in replicas.items() if len(nodes) > 1]
    for cid in singles:
        node = next(iter(replicas[cid]))
        nb = chunk_bytes[cid]
        if free.get(node, 0) >= nb:
            locations[cid] = node
            free[node] -= nb
            objective += colocation_gain(cid, node)
        elif allow_fallback_ship and try_place(
                cid, sorted(free, key=free.get, reverse=True)):
            fallback.append((cid, locations[cid]))
        else:
            dropped.append(cid)

    # Lines 2-5: multi-replica chunks in increasing replica count.
    for cid in sorted(multi, key=lambda c: (len(replicas[c]), c)):
        if try_place(cid, sorted(replicas[cid])):
            continue
        if allow_fallback_ship and try_place(
                cid, sorted(free, key=free.get, reverse=True)):
            fallback.append((cid, locations[cid]))
        else:
            dropped.append(cid)

    return PlacementResult(locations=locations, fallback_moves=fallback,
                           dropped=dropped, colocated_pair_weight=objective)


def static_placement(replicas: Dict[int, Set[int]],
                     home_node: Dict[int, int],
                     chunk_bytes: Dict[int, int],
                     node_budgets: Dict[int, int]) -> PlacementResult:
    """Baseline (§4.2.4 'static'): every chunk stays cached at its origin —
    the node where the raw file lives — regardless of the join workload."""
    free = dict(node_budgets)
    locations: Dict[int, int] = {}
    dropped: List[int] = []
    for cid in sorted(replicas):
        node = home_node[cid]
        nb = chunk_bytes[cid]
        if free.get(node, 0) >= nb:
            locations[cid] = node
            free[node] -= nb
        else:
            dropped.append(cid)
    return PlacementResult(locations=locations, fallback_moves=[],
                           dropped=dropped, colocated_pair_weight=0.0)
