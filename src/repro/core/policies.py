"""Layer 3 of the planning engine: pluggable eviction/placement policies.

The seed coordinator fused policy branching (``if self.policy == ...``)
into the query pipeline. This module turns both decisions into protocol
objects resolved from a string-keyed registry, the way distributed cache
tiers expose policy knobs:

  * ``EvictionPolicy`` — decides *what stays resident* under the byte
    budget. Implementations: cost-based (Alg. 2), LRU, LFU.
  * ``PlacementPolicy`` — decides *which node holds each resident chunk*.
    Implementations: cost-based co-location (Alg. 3), static (home node,
    per-node packing), origin (stay where materialized — the LRU
    baselines' behavior).

A *policy combo* (``PolicySpec``) names a (granularity, eviction,
placement) triple. The seed's three policies map onto combos — including
``file_lru``, which is now just ``lru`` eviction over single-chunk file
units instead of a separate negative-id code path — and new combos
(``chunk_lfu``, ``file_lfu``, ``cost_static``) prove the seam. Register
your own with :func:`register_policy`.

Admission timing differs by granularity, mirroring the paper's baselines:
file units admit *online* (the scan loop consults the live cache, so an
admission earlier in the query can evict a later candidate), while chunk
granularity defers admission to one batch-level round after join
planning (Figure 2's ordering).
"""
from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Protocol,
                    Sequence, Set, Tuple)

from repro.core.chunk import ChunkMeta
from repro.core.eviction import (LFUCache, LRUCache, Triple,
                                 cost_based_eviction)
from repro.core.placement import (JoinRecord, PlacementResult,
                                  cost_based_placement, static_placement)

if TYPE_CHECKING:
    from repro.core.cache_state import CacheState
    from repro.core.chunk_manager import ChunkManager


# ---------------------------------------------------------------------------
# Contexts handed to the policies — everything a policy may consult, so
# implementations never reach back into the coordinator.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QueryAccess:
    """One query's touch set, as seen by the eviction round."""

    query_index: int
    queried: List[ChunkMeta]                  # in access order
    queried_by_file: Dict[int, List[int]]     # file_id -> chunk ids


@dataclasses.dataclass
class EvictionContext:
    """Everything an eviction round may consult (Alg. 2 inputs)."""

    accesses: List[QueryAccess]               # the admission batch, in order
    chunk_bytes: Dict[int, int]
    file_bytes: Dict[int, int]
    state: "CacheState"
    chunks: "ChunkManager"


@dataclasses.dataclass
class PlacementContext:
    """Everything a placement round may consult (Alg. 3 inputs)."""

    replicas: Dict[int, Set[int]]             # cached chunk -> holder nodes
    queried: List[ChunkMeta]                  # batch accesses, in order
    join_history: List[JoinRecord]
    chunk_bytes: Dict[int, int]
    node_budgets: Dict[int, int]
    state: "CacheState"
    home_of: Callable[[int], int]
    decay: float
    history_window: int


# ---------------------------------------------------------------------------
# Protocols
# ---------------------------------------------------------------------------

class EvictionPolicy(Protocol):
    """Decides cache residency. ``finalize_batch`` is the deferred round
    (chunk granularity); ``admit_online``/``is_resident`` drive the online
    file-unit path. Both mutate residency and the replica-location map
    through the ``CacheState`` accessor surface."""

    name: str

    def finalize_batch(self, ctx: EvictionContext) -> int:
        """Run one eviction round over the batch; returns #items evicted."""
        ...

    def admit_online(self, unit: ChunkMeta, state: "CacheState") -> int:
        """Admit one unit during the scan loop; returns #items evicted."""
        ...

    def is_resident(self, chunk_id: int) -> bool:
        """Live residency (online path's scan decision)."""
        ...

    def tracks(self, chunk_id: int) -> bool:
        """Does the policy hold bookkeeping for this id (split remap)?"""
        ...

    def on_split(self, parent_id: int,
                 children: List[Tuple[int, int]]) -> None:
        """Rename a split parent to its (id, nbytes) children."""
        ...

    def discard(self, chunk_id: int) -> None:
        """Placement dropped this chunk from cache: release any
        bookkeeping so the policy's residency view stays in sync."""
        ...


class PlacementPolicy(Protocol):
    """Decides chunk locations for the resident set. Returns the
    ``PlacementResult`` (or ``None`` when locations are implicit) and the
    bytes of any paid fallback transfers."""

    name: str

    def place(self, ctx: PlacementContext
              ) -> Tuple[Optional[PlacementResult], int]:
        """Run one placement round over the resident set."""
        ...


# ---------------------------------------------------------------------------
# Eviction implementations
# ---------------------------------------------------------------------------

class CostEviction:
    """Alg. 2: greedy keep of (query, file, chunk-set) triples by decayed
    rescan-cost-per-uncached-byte. Under batch admission only the LAST
    query's triples are forcibly retained (the paper's 'resident for the
    running query' rule); earlier batch queries have already executed by
    eviction time, so their triples compete through the cost heap as
    maximally-recent history — keeping the budget invariant intact."""

    name = "cost"

    def __init__(self, total_budget: int, decay: float, history_window: int):
        self.total_budget = total_budget
        self.decay = decay
        self.history_window = history_window
        self.state: List[Triple] = []         # Alg. 2 state S

    def finalize_batch(self, ctx: EvictionContext) -> int:
        """One Alg.-2 greedy-keep round over the admission batch."""
        def triples(acc: QueryAccess) -> List[Triple]:
            return [Triple(acc.query_index, fid, frozenset(cids))
                    for fid, cids in acc.queried_by_file.items()]

        current = triples(ctx.accesses[-1])
        history = [t.remap(ctx.chunks.descendants) for t in self.state]
        history = [t for t in history if t.chunk_ids]
        for acc in ctx.accesses[:-1]:
            history.extend(triples(acc))
        res = cost_based_eviction(history, current, self.total_budget,
                                  ctx.chunk_bytes, ctx.file_bytes, self.decay)
        evicted = len(ctx.state.cached - res.cached_chunks)
        self.state = res.state
        if len(self.state) > 4 * self.history_window:
            self.state = sorted(self.state,
                                key=lambda t: -t.query_index
                                )[:4 * self.history_window]
        ctx.state.cached = res.cached_chunks
        return evicted

    def admit_online(self, unit: ChunkMeta, state: "CacheState") -> int:
        """Unsupported: cost eviction has no online file-unit path."""
        raise NotImplementedError(
            "cost-based eviction plans over chunk triples; it has no online "
            "file-unit admission path")

    def is_resident(self, chunk_id: int) -> bool:
        """Unsupported: residency lives in ``CacheState`` for this policy."""
        raise NotImplementedError

    def tracks(self, chunk_id: int) -> bool:
        """Always False: triples remap lazily in ``finalize_batch``."""
        return False                # triples remap lazily in finalize_batch

    def on_split(self, parent_id: int,
                 children: List[Tuple[int, int]]) -> None:
        """No-op — see :meth:`tracks`."""
        pass

    def discard(self, chunk_id: int) -> None:
        """No-op: triples keep the id; it re-enters as uncached bytes in
        the next round's cost computation (the seed behavior)."""
        pass


class _RecencyFrequencyEviction:
    """Shared plumbing for the LRU/LFU baselines: an aggregate-budget item
    cache admitted either online (file units) or deferred (chunk batch)."""

    def __init__(self, cache) -> None:
        self.cache = cache

    def _admit(self, unit: ChunkMeta, state: "CacheState") -> int:
        evicted = self.cache.admit(unit.chunk_id, unit.nbytes)
        for e in evicted:
            state.clear_location(e)
        self.cache.touch(unit.chunk_id)
        return len(evicted)

    def finalize_batch(self, ctx: EvictionContext) -> int:
        count = 0
        for acc in ctx.accesses:
            for cm in acc.queried:
                count += self._admit(cm, ctx.state)
        ctx.state.cached = self.cache.ids()
        return count

    def admit_online(self, unit: ChunkMeta, state: "CacheState") -> int:
        evicted = self._admit(unit, state)
        state.cached = self.cache.ids()
        return evicted

    def is_resident(self, chunk_id: int) -> bool:
        return chunk_id in self.cache

    def tracks(self, chunk_id: int) -> bool:
        return chunk_id in self.cache

    def on_split(self, parent_id: int,
                 children: List[Tuple[int, int]]) -> None:
        self.cache.rename(parent_id, children)

    def discard(self, chunk_id: int) -> None:
        self.cache.remove(chunk_id)


class LRUEviction(_RecencyFrequencyEviction):
    """The paper's §4.1 LRU baseline over file or chunk units."""

    name = "lru"

    def __init__(self, total_budget: int, decay: float, history_window: int):
        super().__init__(LRUCache(total_budget))


class LFUEviction(_RecencyFrequencyEviction):
    """Registry extension: LFU eviction with LRU tie-breaking."""

    name = "lfu"

    def __init__(self, total_budget: int, decay: float, history_window: int):
        super().__init__(LFUCache(total_budget))


# ---------------------------------------------------------------------------
# Placement implementations
# ---------------------------------------------------------------------------

def _default_replicas(ctx: PlacementContext) -> Dict[int, Set[int]]:
    """Join-induced replicas restricted to the retained set, with every
    other cached chunk pinned at its current (or home) node."""
    replicas = {cid: set(nodes) for cid, nodes in ctx.replicas.items()
                if cid in ctx.state.cached}
    for cid in ctx.state.cached:
        if cid not in replicas:
            loc = ctx.state.node_of(cid)
            replicas[cid] = {ctx.home_of(cid) if loc is None else loc}
    return replicas


class CostPlacement:
    """Alg. 3: consolidate replicas to one copy per chunk, maximizing the
    decayed co-location benefit over the join workload history."""

    name = "cost"

    def place(self, ctx: PlacementContext
              ) -> Tuple[Optional[PlacementResult], int]:
        """One Alg.-3 consolidation round; returns (result, paid bytes)."""
        replicas = _default_replicas(ctx)
        result = cost_based_placement(ctx.join_history, replicas,
                                      ctx.chunk_bytes, ctx.node_budgets,
                                      ctx.decay, ctx.history_window)
        for cid in result.dropped:
            ctx.state.cached.discard(cid)
        ctx.state.assign_locations(result.locations)
        extra = sum(ctx.chunk_bytes[c] for c, _ in result.fallback_moves)
        return result, extra


class StaticPlacement:
    """§4.2.4 baseline: every cached chunk lives at its home node."""

    name = "static"

    def place(self, ctx: PlacementContext
              ) -> Tuple[Optional[PlacementResult], int]:
        """Pack every resident chunk at its home node (§4.2.4)."""
        replicas = _default_replicas(ctx)
        home = {cid: ctx.home_of(cid) for cid in replicas}
        result = static_placement(replicas, home, ctx.chunk_bytes,
                                  ctx.node_budgets)
        for cid in result.dropped:
            ctx.state.cached.discard(cid)
        ctx.state.assign_locations(result.locations)
        return result, 0


class OriginPlacement:
    """The LRU baselines' implicit placement: chunks stay where the scan
    materialized them (their home node) and never move. Under
    ``budget_scope="node"`` the home nodes are packed against per-node
    budgets and overflow is dropped from cache (static-style packing);
    under the default global scope eviction already enforced the
    aggregate budget, so locations are recorded without drops."""

    name = "origin"

    def place(self, ctx: PlacementContext
              ) -> Tuple[Optional[PlacementResult], int]:
        """Record home-node locations; pack per node under node scope."""
        if ctx.state.budget_scope == "node":
            replicas = {cid: {ctx.home_of(cid)} for cid in ctx.state.cached}
            home = {cid: ctx.home_of(cid) for cid in replicas}
            result = static_placement(replicas, home, ctx.chunk_bytes,
                                      ctx.node_budgets)
            for cid in result.dropped:
                ctx.state.drop(cid)
            ctx.state.assign_locations(result.locations)
            return result, 0
        for cm in ctx.queried:
            if cm.chunk_id in ctx.state.cached:
                ctx.state.ensure_location(cm.chunk_id,
                                          ctx.home_of(cm.chunk_id))
        return None, 0


# ---------------------------------------------------------------------------
# Replication policies
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ReplicationContext:
    """Everything a replication round may consult: the cache state
    (post-eviction/placement, single-valued again), the size table, and
    the coordinator's decayed per-chunk access frequencies."""

    state: "CacheState"
    chunk_bytes: Dict[int, int]
    freq: Dict[int, float]                    # decayed access frequency
    home_of: Callable[[int], int]


class ReplicationPolicy(Protocol):
    """Decides which cached chunks hold extra copies. Runs AFTER the
    eviction and placement rounds (which own residency and primaries and
    plan under full budgets): it re-applies surviving secondaries and
    promotes hot chunks strictly into leftover budget — which is what
    makes secondaries structurally cheaper to drop than sole copies.
    Returns the number of secondaries shed this round."""

    name: str

    def replicate(self, ctx: ReplicationContext) -> int:
        """Run one replication round; returns #secondaries dropped."""
        ...


class NoReplication:
    """The default: single-copy caching, bit-for-bit the pre-replication
    pipeline (the round is a no-op and locations stay one-tuples)."""

    name = "off"

    def __init__(self, k: int = 1, threshold: float = 0.0):
        pass

    def replicate(self, ctx: ReplicationContext) -> int:
        """No-op round: nothing promoted, nothing dropped."""
        return 0


class HotChunkReplication:
    """Promote chunks whose decayed access frequency crosses
    ``threshold`` to ``k`` replicas on the least-loaded nodes, within
    whatever budget the eviction/placement rounds left free.

    Each round: (1) re-apply the previous round's secondaries that are
    still backed by a cached chunk and still fit — a budget squeeze or a
    hotter competitor sheds secondaries FIRST while residency (sole
    copies) is untouched, the replica-aware eviction ordering; (2)
    promote hot chunks (hottest first, deterministic id tie-break) to
    ``k`` copies, choosing for each new copy the node with the fewest
    cached bytes (tie: lowest node id). Secondaries are charged at their
    holder — per-node under ``budget_scope="node"``, against the unified
    pool under ``"global"`` — so a replica can never push a node or the
    cluster over budget."""

    name = "hot"

    def __init__(self, k: int = 2, threshold: float = 3.0):
        if k < 1:
            raise ValueError(f"replica count k must be >= 1, got {k}")
        self.k = k
        self.threshold = threshold
        # Secondaries decided in previous rounds, re-applied (budget
        # permitting) after each placement round wipes locations back to
        # single-valued.
        self._secondaries: Dict[int, Tuple[int, ...]] = {}

    def replicate(self, ctx: ReplicationContext) -> int:
        """One replication round; returns #secondaries shed."""
        state = ctx.state
        budgets = state.placement_budgets()
        used = state.bytes_by_node(ctx.chunk_bytes)
        free_total = state.total_budget - sum(used.values())
        dropped = 0

        def fits(node: int, nb: int) -> bool:
            if state.budget_scope == "node":
                return used.get(node, 0) + nb <= budgets.get(node, 0)
            return free_total >= nb

        def add(cid: int, node: int, nb: int) -> None:
            nonlocal free_total
            state.set_replicas(cid, state.replicas_of(cid) + (node,))
            used[node] = used.get(node, 0) + nb
            free_total -= nb

        # Phase 1: re-apply surviving secondaries under leftover budget.
        for cid in sorted(self._secondaries):
            if cid not in state.cached:
                continue          # chunk evicted: copies died with it
            reps = state.replicas_of(cid)
            if not reps:
                continue
            nb = ctx.chunk_bytes.get(cid, 0)
            for node in self._secondaries[cid]:
                if node in reps or node == reps[0]:
                    continue      # already applied / became the primary
                if nb > 0 and fits(node, nb):
                    add(cid, node, nb)
                    reps = state.replicas_of(cid)
                else:
                    dropped += 1
        # Phase 2: promote hot chunks, hottest first.
        hot = [cid for cid in state.cached
               if ctx.freq.get(cid, 0.0) >= self.threshold]
        hot.sort(key=lambda c: (-ctx.freq.get(c, 0.0), c))
        for cid in hot:
            nb = ctx.chunk_bytes.get(cid, 0)
            if nb <= 0 or not state.replicas_of(cid):
                continue          # unsized or not yet located
            while len(state.replicas_of(cid)) < self.k:
                reps = state.replicas_of(cid)
                cands = [n for n in range(state.n_nodes)
                         if n not in reps and fits(n, nb)]
                if not cands:
                    break
                add(cid, min(cands, key=lambda n: (used.get(n, 0), n)), nb)
        # Remember the end-state secondaries for the next round.
        self._secondaries = {
            cid: state.replicas_of(cid)[1:] for cid in state.cached
            if len(state.replicas_of(cid)) > 1}
        return dropped


REPLICATION_MODES = ("off", "hot")

REPLICATION_REGISTRY: Dict[str, Callable[..., ReplicationPolicy]] = {
    "off": NoReplication,
    "hot": HotChunkReplication,
}


def build_replication(name: str, k: int = 2,
                      threshold: float = 3.0) -> ReplicationPolicy:
    """Construct the replication policy named by ``name`` from the
    registry (``"off"`` = single-copy no-op, ``"hot"`` = hot-chunk
    promotion with ``k`` copies past ``threshold`` decayed accesses)."""
    factory = REPLICATION_REGISTRY.get(name)
    if factory is None:
        raise ValueError(f"unknown replication mode {name!r}; "
                         f"known: {sorted(REPLICATION_REGISTRY)}")
    return factory(k=k, threshold=threshold)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

GRANULARITIES = ("chunk", "file")

EVICTION_REGISTRY: Dict[str, Callable[[int, float, int], EvictionPolicy]] = {
    "cost": CostEviction,
    "lru": LRUEviction,
    "lfu": LFUEviction,
}

PLACEMENT_REGISTRY: Dict[str, Callable[[], PlacementPolicy]] = {
    "dynamic": CostPlacement,
    "static": StaticPlacement,
    "origin": OriginPlacement,
}

# Eviction policies able to admit file units online during the scan loop.
_ONLINE_EVICTION = ("lru", "lfu")


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """A named (granularity, eviction, placement) combination."""

    name: str
    granularity: str                 # "chunk" | "file"
    eviction: str                    # EVICTION_REGISTRY key
    placement: str                   # PLACEMENT_REGISTRY key

    def validate(self) -> None:
        """Reject unknown keys and invalid granularity/eviction pairings."""
        if self.granularity not in GRANULARITIES:
            raise ValueError(f"unknown granularity {self.granularity!r}")
        if self.eviction not in EVICTION_REGISTRY:
            raise ValueError(f"unknown eviction policy {self.eviction!r}; "
                             f"known: {sorted(EVICTION_REGISTRY)}")
        if self.placement not in PLACEMENT_REGISTRY:
            raise ValueError(f"unknown placement policy {self.placement!r}; "
                             f"known: {sorted(PLACEMENT_REGISTRY)}")
        if self.granularity == "file" and \
                self.eviction not in _ONLINE_EVICTION:
            raise ValueError(
                f"file granularity requires an online-capable eviction "
                f"policy ({_ONLINE_EVICTION}), got {self.eviction!r}")


POLICY_REGISTRY: Dict[str, PolicySpec] = {}


def register_policy(spec: PolicySpec) -> PolicySpec:
    """Validate and install a policy combo under ``spec.name``."""
    spec.validate()
    POLICY_REGISTRY[spec.name] = spec
    return spec


# The seed's three policies, now expressed as combos...
register_policy(PolicySpec("cost", "chunk", "cost", "dynamic"))
register_policy(PolicySpec("chunk_lru", "chunk", "lru", "origin"))
register_policy(PolicySpec("file_lru", "file", "lru", "origin"))
# ...plus new combinations the policy seam makes one-liners.
register_policy(PolicySpec("cost_static", "chunk", "cost", "static"))
register_policy(PolicySpec("chunk_lfu", "chunk", "lfu", "origin"))
register_policy(PolicySpec("file_lfu", "file", "lfu", "origin"))

POLICIES = tuple(POLICY_REGISTRY)


def resolve_policy(name: str, placement_mode: str = "dynamic") -> PolicySpec:
    """Look up a policy combo. ``placement_mode`` preserves the seed API:
    ``policy="cost", placement_mode="static"`` selects static placement."""
    if placement_mode not in ("dynamic", "static"):
        raise ValueError(f"unknown placement mode {placement_mode!r}")
    spec = POLICY_REGISTRY.get(name)
    if spec is None:
        raise ValueError(f"unknown policy {name!r}; "
                         f"known: {sorted(POLICY_REGISTRY)}")
    if spec.placement == "dynamic" and placement_mode == "static":
        spec = dataclasses.replace(spec, placement="static")
    return spec


def build_eviction(spec: PolicySpec, total_budget: int, decay: float,
                   history_window: int) -> EvictionPolicy:
    """Construct the eviction policy named by ``spec.eviction``."""
    return EVICTION_REGISTRY[spec.eviction](total_budget, decay,
                                            history_window)


def build_placement(spec: PolicySpec) -> PlacementPolicy:
    """Construct the placement policy named by ``spec.placement``."""
    return PLACEMENT_REGISTRY[spec.placement]()
