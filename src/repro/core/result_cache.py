"""Versioned result-cache tier: O(1) serving of exact repeat queries.

The coordinator's planning pipeline (chunking refinement, join planning,
eviction/placement) is run for every admitted query — but on the skewed
workloads the paper targets ("millions of users" traffic is Zipf-shaped)
most queries are *exact repeats* of a recent query, and a similarity
join's answer is a pure function of the raw data, the query box, and
``eps``. :class:`ResultCache` is a small read-through tier in front of
the planner (Szépkúti, *Caching in Multidimensional Databases*,
PAPERS.md): :meth:`repro.core.coordinator.CacheCoordinator.process_batch`
consults it *before* planning, so a hit skips chunking, join planning,
the policy round, and backend execution entirely.

Entries are **version-stamped**: the cache registers on
:attr:`repro.core.cache_state.CacheState.listeners` (the same hook
surface device buffers and join artifacts ride) and bumps its version on
every residency event — point-wise ``on_drop``/``on_split``, and a
``reconcile`` snapshot diff that catches the wholesale resident-set
reassignment of eviction/placement rounds (including admissions, which
never go through a point-wise hook). A lookup only serves an entry
stored at the *current* version, so no result computed against a
previous cache configuration is ever served after an
evict -> re-admit -> split sequence. Match counts would in fact survive
such churn (they depend only on the raw cells), but the served planning
observables (``queried_cells``, cache occupancy) would not — the stamp
keeps every served field honest and makes invalidation auditable
(``stale_drops``).

Capacity is LRU-bounded and entries optionally expire after ``ttl_s``
seconds (bounded staleness, the read-through pattern from the
scalability-patterns blueprint in SNIPPETS.md). The ``clock`` is
injectable for deterministic TTL tests.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import TYPE_CHECKING, Callable, List, Optional, Tuple, Union

from repro.obs.clock import Clock, as_clock

if TYPE_CHECKING:  # geometry/type-only imports; no runtime cycle
    from repro.core.cache_state import CacheState
    from repro.core.chunk import ChunkMeta
    from repro.core.geometry import Box

# Canonical lookup key of a query: (box.lo, box.hi, eps) as plain int
# tuples — Box is closed and normalized (lo <= hi), so two queries with
# equal keys denote the identical cell region and join radius.
ResultKey = Tuple[Tuple[int, ...], Tuple[int, ...], int]

RESULT_CACHE_MODES = ("off", "on")


@dataclasses.dataclass
class ResultEntry:
    """One cached query answer plus the planning observables served with
    it; ``version`` is the residency stamp it was computed under and
    ``stored_at`` the (injectable-clock) store time for TTL expiry."""

    matches: int
    queried_cells: int
    cached_bytes_after: int
    cached_chunks_after: int
    version: int
    stored_at: float


class ResultCache:
    """LRU+TTL bounded, residency-versioned map from canonical query
    keys to executed results.

    Counters: ``hits``/``misses`` (every lookup lands in exactly one),
    ``stale_drops`` (entry found but stamped with an older residency
    version), ``expired_drops`` (TTL), ``capacity_evictions`` (LRU), and
    ``invalidations`` (version bumps). A stale or expired entry counts
    as a miss and is dropped eagerly.
    """

    def __init__(self, capacity: int = 256, ttl_s: Optional[float] = None,
                 clock: Union[Clock, Callable[[], float], None] = None):
        if capacity <= 0:
            raise ValueError(f"result-cache capacity must be positive, "
                             f"got {capacity}")
        self.capacity = capacity
        self.ttl_s = ttl_s
        # Accepts a repro.obs Clock or a bare () -> float callable (the
        # seed-era signature); None falls back to the shared monotonic
        # clock. This removed the module's direct time.monotonic call.
        self._clock = as_clock(clock).now
        self._entries: "OrderedDict[ResultKey, ResultEntry]" = OrderedDict()
        # Residency version stamp + the snapshot reconcile diffs against.
        self.version = 0
        self._snapshot: Tuple[frozenset, frozenset] = (frozenset(),
                                                       frozenset())
        self.hits = 0
        self.misses = 0
        self.stale_drops = 0
        self.expired_drops = 0
        self.capacity_evictions = 0
        self.invalidations = 0

    # ------------------------------------------------------------ keying

    @staticmethod
    def key_of(box: "Box", eps: int) -> ResultKey:
        """The canonical lookup key of a query ``(box, eps)``."""
        return (tuple(int(x) for x in box.lo),
                tuple(int(x) for x in box.hi), int(eps))

    # ------------------------------------------------------ lookup/store

    def lookup(self, key: ResultKey) -> Optional[ResultEntry]:
        """Read-through probe: the entry for ``key`` if present, stamped
        with the current residency version, and within TTL — else
        ``None`` (stale/expired entries are dropped eagerly). A served
        entry is LRU-refreshed."""
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            return None
        if e.version != self.version:
            del self._entries[key]
            self.stale_drops += 1
            self.misses += 1
            return None
        if self.ttl_s is not None and self._clock() - e.stored_at > self.ttl_s:
            del self._entries[key]
            self.expired_drops += 1
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return e

    def store(self, key: ResultKey, matches: int, queried_cells: int = 0,
              cached_bytes_after: int = 0,
              cached_chunks_after: int = 0) -> None:
        """Write-back after a planned query executed: stamp the entry
        with the current residency version and evict LRU past capacity."""
        self._entries[key] = ResultEntry(
            matches=int(matches), queried_cells=int(queried_cells),
            cached_bytes_after=int(cached_bytes_after),
            cached_chunks_after=int(cached_chunks_after),
            version=self.version, stored_at=self._clock())
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.capacity_evictions += 1

    def __len__(self) -> int:
        """Stored entries (stale/expired ones linger until probed or
        evicted — the version stamp, not presence, is the validity
        source of truth)."""
        return len(self._entries)

    # ------------------------------------------------------ invalidation

    def bump(self) -> None:
        """Advance the residency version: every stored entry becomes
        stale at once (dropped lazily on probe — O(1) invalidation, the
        versioned-key pattern)."""
        self.version += 1
        self.invalidations += 1

    # ------------------------- residency listener (CacheState hooks) --

    def on_drop(self, chunk_id: int) -> None:
        """A chunk left the cache: results computed under the previous
        residency may serve observables that no longer hold — bump."""
        self.bump()

    def on_split(self, parent_id: int, leaves: List["ChunkMeta"]) -> None:
        """A cached chunk split (ids reminted): bump, same reasoning."""
        self.bump()

    def reconcile(self, state: "CacheState") -> None:
        """Post-round sync: diff the resident set + location map against
        the last seen snapshot and bump on any change. This is what
        catches *admissions* — policy rounds assign ``state.cached``
        wholesale, so no point-wise hook fires for a newly admitted
        chunk. A round that leaves residency untouched keeps the version
        (warm repeats stay servable)."""
        snap = (frozenset(state.cached), state.location_snapshot())
        if snap != self._snapshot:
            self._snapshot = snap
            self.bump()
