"""Evolving R-tree: query-driven chunking of a raw sparse array (§3.1, Alg. 1).

One tree per raw file. Leaves are the current chunks; internal nodes keep the
bounding boxes of retired (split) chunks and serve as the pruning index. The
tree only ever *refines*: a leaf splits into two leaves, chosen among the
query's faces that bisect the leaf's bounding box, minimizing the combined
hyper-volume of the two children's (cell-derived) bounding boxes.

Invariants (checked by ``validate()``):
  * the union of leaf ``cell_idx`` is exactly the file's cell set (cover);
  * leaf cell sets are pairwise disjoint (non-overlap);
  * every leaf box is the tight bounding box of its cells.

Split rule (Alg. 1 + §3.1 "When to split?"): a leaf overlapping query Q splits
iff  (n_cells >= min_cells)  OR  (no cell of the leaf lies inside Q).
A leaf whose box is contained in Q never splits (no query face bisects it, and
all of its cells are queried). Each split consumes one of the <= 2d bisecting
faces and children are never bisected by the same face again, so refinement
per query terminates after at most 2d levels.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.clock import Clock, MONOTONIC

from repro.core.chunk import Chunk
from repro.core.geometry import Box, bounding_box, points_in_box, split_boundaries


@dataclasses.dataclass
class _Node:
    box: Box
    chunk: Optional[Chunk]                 # leaf iff chunk is not None
    children: Optional[List["_Node"]] = None


@dataclasses.dataclass
class RefineStats:
    """Counters for one Alg.-1 refinement pass (optimization-time cost).

    ``split_candidates`` / ``split_eval_s`` isolate the split-choice
    step (``_best_split``): how many candidate faces were scored and the
    wall-clock spent scoring them — the planner-side hot spot that the
    vectorized evaluation targets (``bench_opt_time`` reports both)."""

    splits: int = 0
    leaves_visited: int = 0
    cells_partitioned: int = 0
    split_candidates: int = 0
    split_eval_s: float = 0.0


class EvolvingRTree:
    """Per-file evolving R-tree over the file's cell coordinates."""

    def __init__(self, file_id: int, coords: np.ndarray, cell_bytes: int,
                 min_cells: int, next_chunk_id: Callable[[], int],
                 max_cells: Optional[int] = None,
                 clock: Optional[Clock] = None):
        """``max_cells`` (extension, DESIGN.md §7): chunks larger than this
        split at the median of their longest box side even when no query
        face bisects them (a fully-inside chunk otherwise never splits and
        can exceed one node's cache budget, making it un-placeable).
        ``None`` keeps Alg. 1 verbatim. ``clock`` is the injectable time
        source behind ``RefineStats.split_eval_s`` (default: the shared
        monotonic clock)."""
        if coords.ndim != 2:
            raise ValueError(f"coords must be (n, d), got {coords.shape}")
        self.file_id = file_id
        self.coords = coords
        self.cell_bytes = cell_bytes
        self.min_cells = min_cells
        self.max_cells = max_cells
        self.clock = clock if clock is not None else MONOTONIC
        self._next_id = next_chunk_id
        box = bounding_box(coords)
        if box is None:
            raise ValueError("cannot index an empty file")
        root_chunk = Chunk(self._next_id(), file_id, box,
                           np.arange(coords.shape[0], dtype=np.int64), cell_bytes)
        self._root = _Node(box=box, chunk=root_chunk)
        self._leaves: Dict[int, _Node] = {root_chunk.chunk_id: self._root}
        # chunk_id -> ids of the two children it split into (for remapping
        # historical cache/workload state through splits, §3.3).
        self.split_children: Dict[int, Tuple[int, int]] = {}

    # ------------------------------------------------------------------ API

    @property
    def root_box(self) -> Box:
        """Bounding box of the whole file (the tree's root)."""
        return self._root.box

    def leaves(self) -> List[Chunk]:
        """The current chunks (live leaves) of the file."""
        return [n.chunk for n in self._leaves.values()]  # type: ignore[misc]

    def n_leaves(self) -> int:
        """Number of live leaves (current chunk count)."""
        return len(self._leaves)

    def get_chunk(self, chunk_id: int) -> Chunk:
        """The live leaf chunk with this id (KeyError when retired)."""
        return self._leaves[chunk_id].chunk  # type: ignore[return-value]

    def descendants(self, chunk_id: int) -> List[int]:
        """Current leaf ids holding the cells of a (possibly split) chunk."""
        if chunk_id in self._leaves:
            return [chunk_id]
        out: List[int] = []
        stack = list(self.split_children.get(chunk_id, ()))
        while stack:
            cid = stack.pop()
            if cid in self._leaves:
                out.append(cid)
            else:
                stack.extend(self.split_children.get(cid, ()))
        return out

    def overlapping(self, query: Box) -> List[Chunk]:
        """Leaves whose bounding box overlaps ``query`` (pruned descent)."""
        out: List[Chunk] = []
        stack = [self._root]
        while stack:
            node = stack.pop()
            if not node.box.overlaps(query):
                continue
            if node.chunk is not None:
                out.append(node.chunk)
            else:
                stack.extend(node.children or ())
        return out

    def refine(self, query: Box, stats: Optional[RefineStats] = None
               ) -> List[Chunk]:
        """Alg. 1 applied to every leaf overlapping ``query``; returns the
        post-refinement leaves that overlap the query."""
        st = stats if stats is not None else RefineStats()
        result: List[Chunk] = []
        stack = [self._root]
        while stack:
            node = stack.pop()
            if not node.box.overlaps(query):
                continue
            if node.chunk is None:
                stack.extend(node.children or ())
                continue
            st.leaves_visited += 1
            self._refine_leaf(node, query, result, st)
        return result

    # ------------------------------------------------------------ internals

    def _refine_leaf(self, node: _Node, query: Box, result: List[Chunk],
                     st: RefineStats) -> None:
        chunk = node.chunk
        assert chunk is not None
        pts = self.coords[chunk.cell_idx]
        in_q = points_in_box(pts, query)
        has_queried_cell = bool(in_q.any())
        # Alg. 1 line 1: small chunk with a relevant cell -> keep as is.
        if chunk.n_cells < self.min_cells and has_queried_cell:
            result.append(chunk)
            return
        best = self._best_split(chunk, pts, query, st)
        if best is None and self.max_cells is not None and \
                chunk.n_cells > self.max_cells:
            best = self._median_split(pts)
        if best is None:
            # Box contained in the query (no bisecting face): every cell is
            # queried; nothing to carve off.
            if has_queried_cell:
                result.append(chunk)
            return
        lo_idx, hi_idx, lo_box, hi_box = best
        st.splits += 1
        st.cells_partitioned += chunk.n_cells
        children: List[_Node] = []
        child_ids: List[int] = []
        for idx, box in ((lo_idx, lo_box), (hi_idx, hi_box)):
            if box is None:
                continue
            c = Chunk(self._next_id(), self.file_id, box,
                      chunk.cell_idx[idx], self.cell_bytes)
            children.append(_Node(box=box, chunk=c))
            child_ids.append(c.chunk_id)
        # Retire the parent leaf.
        del self._leaves[chunk.chunk_id]
        node.chunk = None
        node.children = children
        self.split_children[chunk.chunk_id] = tuple(child_ids)  # type: ignore[assignment]
        for ch in children:
            self._leaves[ch.chunk.chunk_id] = ch  # type: ignore[union-attr]
            if ch.box.overlaps(query):
                self._refine_leaf(ch, query, result, st)

    def _best_split(self, chunk: Chunk, pts: np.ndarray, query: Box,
                    st: Optional[RefineStats] = None):
        """Enumerate query faces bisecting the chunk box; minimize combined
        child hyper-volume (Alg. 1 lines 2-9). All candidate faces are
        scored in ONE vectorized masked min/max pass over the cells
        (child boxes and volumes for every face at once) instead of two
        ``bounding_box`` scans per face; only the winning face's masks
        and boxes are materialized. First strict minimum wins, matching
        the original candidate-order tie-breaking."""
        candidates = split_boundaries(query, chunk.box)
        if not candidates:
            return None
        t0 = self.clock.now()
        dims = np.fromiter((d for d, _ in candidates), dtype=np.int64)
        cuts = np.fromiter((c for _, c in candidates), dtype=np.int64)
        lo_masks = pts[:, dims] <= cuts                        # (n, K)
        m = lo_masks[:, :, None]                               # (n, K, 1)
        p3 = pts[:, None, :].astype(np.int64, copy=False)      # (n, 1, d)
        big = np.iinfo(np.int64).max
        small = np.iinfo(np.int64).min
        lo_min = np.where(m, p3, big).min(axis=0)              # (K, d)
        lo_max = np.where(m, p3, small).max(axis=0)
        hi_min = np.where(~m, p3, big).min(axis=0)
        hi_max = np.where(~m, p3, small).max(axis=0)
        n_lo = lo_masks.sum(axis=0)                            # (K,)
        n = pts.shape[0]
        best_k = 0
        best_vol = None
        for k in range(len(candidates)):
            # Volumes in python ints (unbounded), exactly as Box.volume();
            # an empty child contributes 0, as in the bounding_box path.
            vol = 0
            if n_lo[k] > 0:
                v = 1
                for s in lo_max[k] - lo_min[k] + 1:
                    v *= int(s)
                vol += v
            if n_lo[k] < n:
                v = 1
                for s in hi_max[k] - hi_min[k] + 1:
                    v *= int(s)
                vol += v
            if best_vol is None or vol < best_vol:
                best_vol = vol
                best_k = k
        lo_mask = lo_masks[:, best_k]
        lo_box = (Box(tuple(int(x) for x in lo_min[best_k]),
                      tuple(int(x) for x in lo_max[best_k]))
                  if n_lo[best_k] > 0 else None)
        hi_box = (Box(tuple(int(x) for x in hi_min[best_k]),
                      tuple(int(x) for x in hi_max[best_k]))
                  if n_lo[best_k] < n else None)
        if st is not None:
            st.split_candidates += len(candidates)
            st.split_eval_s += self.clock.now() - t0
        # A degenerate cut (all cells on one side -> one box None) still
        # makes progress: the surviving child's box is strictly tighter
        # (the cut bisected the parent box, carving off empty margin).
        return (np.nonzero(lo_mask)[0], np.nonzero(~lo_mask)[0],
                lo_box, hi_box)

    def _median_split(self, pts: np.ndarray):
        """Median cut along the longest box side with both sides non-empty
        (used only for over-budget chunks; see ``max_cells``)."""
        spans = pts.max(axis=0) - pts.min(axis=0)
        for dim in np.argsort(spans)[::-1]:
            vals = pts[:, dim]
            cut = int(np.median(vals))
            lo_mask = vals <= cut
            if lo_mask.all() or not lo_mask.any():
                cut = int(vals.min())
                lo_mask = vals <= cut
                if lo_mask.all():
                    continue
            lo_box = bounding_box(pts[lo_mask])
            hi_box = bounding_box(pts[~lo_mask])
            return (np.nonzero(lo_mask)[0], np.nonzero(~lo_mask)[0],
                    lo_box, hi_box)
        return None

    # ------------------------------------------------------------ validation

    def validate(self) -> None:
        """Check the cover / non-overlap / tight-box invariants."""
        seen = np.zeros(self.coords.shape[0], dtype=np.int64)
        for leaf in self._leaves.values():
            c = leaf.chunk
            assert c is not None
            seen[c.cell_idx] += 1
            bb = bounding_box(self.coords[c.cell_idx])
            assert bb is not None and bb == c.box, (
                f"leaf {c.chunk_id}: box {c.box} not tight (expected {bb})")
        assert (seen == 1).all(), "leaves do not partition the file's cells"
