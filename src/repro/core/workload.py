"""Query workload generators mirroring §4.1: PTF-1, PTF-2, GEO, and the
100-query stress workload.

  * PTF-1 — data-exploration joins through all detections on the time
    dimension: random compact (ra, dec) fields, full time range, with range
    re-use across the workload (shared ranges drive the 20x wins in Fig. 5).
  * PTF-2 — 4 range-shifted queries, enlarged 2x on ra and 2x on dec,
    alternating 1,2,3,4,1,2,3,4,1,2.
  * GEO  — fixed-size range shifted by a constant latitude step 1..5 then
    reversed: 1,2,3,4,5,5,4,3,2,1.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.coordinator import SimilarityJoinQuery
from repro.core.geometry import Box


def _clip_box(lo, hi, domain: Box) -> Box:
    lo = tuple(int(max(l, dl)) for l, dl in zip(lo, domain.lo))
    hi = tuple(int(min(h, dh)) for h, dh in zip(hi, domain.hi))
    hi = tuple(max(l, h) for l, h in zip(lo, hi))
    return Box(lo, hi)


def ptf1_workload(domain: Box, n_queries: int = 10, eps: int = 1,
                  field_frac: float = 0.08, seed: int = 3,
                  anchors: Optional[Sequence[Tuple[int, int]]] = None
                  ) -> List[SimilarityJoinQuery]:
    """Random sky fields over (ra, dec), joining through all of time. Every
    other query revisits a previous field (ranges shared across workload).
    ``anchors``: optional (ra, dec) points the exploration targets (e.g.
    observed detections) — without them fields are uniform over the domain.
    """
    rng = np.random.default_rng(seed)
    ra_n, dec_n = domain.side(0), domain.side(1)
    w = max(1, int(ra_n * field_frac))
    h = max(1, int(dec_n * field_frac))
    queries: List[SimilarityJoinQuery] = []
    fields = []
    for i in range(n_queries):
        if fields and i % 2 == 1:
            ra0, dec0 = fields[rng.integers(0, len(fields))]
            ra0 += int(rng.integers(-w // 4, w // 4 + 1))
            dec0 += int(rng.integers(-h // 4, h // 4 + 1))
        else:
            if anchors is not None:
                a_ra, a_dec = anchors[int(rng.integers(0, len(anchors)))]
                ra0 = int(a_ra) - w // 2
                dec0 = int(a_dec) - h // 2
            else:
                ra0 = int(rng.integers(domain.lo[0], domain.hi[0] - w + 1))
                dec0 = int(rng.integers(domain.lo[1], domain.hi[1] - h + 1))
            fields.append((ra0, dec0))
        box = _clip_box((ra0, dec0, domain.lo[2]),
                        (ra0 + w - 1, dec0 + h - 1, domain.hi[2]), domain)
        queries.append(SimilarityJoinQuery(box=box, eps=eps))
    return queries


def ptf2_workload(domain: Box, n_queries: int = 10, eps: int = 1,
                  field_frac: float = 0.08, seed: int = 5,
                  anchors: Optional[Sequence[Tuple[int, int]]] = None
                  ) -> List[SimilarityJoinQuery]:
    """4 shifted base ranges enlarged 2x on ra and 2x on dec, alternating."""
    rng = np.random.default_rng(seed)
    ra_n, dec_n = domain.side(0), domain.side(1)
    w = max(1, int(ra_n * field_frac * 2))
    h = max(1, int(dec_n * field_frac * 2))
    bases = []
    if anchors is not None:
        a_ra, a_dec = anchors[int(rng.integers(0, len(anchors)))]
        ra0, dec0 = int(a_ra) - w // 2, int(a_dec) - h // 2
    else:
        ra0 = int(rng.integers(domain.lo[0], max(domain.lo[0] + 1,
                                                 domain.hi[0] - 2 * w)))
        dec0 = int(rng.integers(domain.lo[1], max(domain.lo[1] + 1,
                                                  domain.hi[1] - 2 * h)))
    for k in range(4):
        bases.append((ra0 + k * w // 3, dec0 + k * h // 3))
    queries = []
    for i in range(n_queries):
        bra, bdec = bases[i % 4]
        box = _clip_box((bra, bdec, domain.lo[2]),
                        (bra + w - 1, bdec + h - 1, domain.hi[2]), domain)
        queries.append(SimilarityJoinQuery(box=box, eps=eps))
    return queries


def geo_workload(domain: Box, eps: int = 1, range_frac: float = 0.12,
                 step_frac: float = 0.06, seed: int = 9
                 ) -> List[SimilarityJoinQuery]:
    """Shifting-latitude workload 1,2,3,4,5 then 5,4,3,2,1 (§4.1)."""
    rng = np.random.default_rng(seed)
    lon_n, lat_n = domain.side(0), domain.side(1)
    w = max(1, int(lon_n * range_frac))
    h = max(1, int(lat_n * range_frac))
    step = max(1, int(lat_n * step_frac))
    lon0 = int(rng.integers(domain.lo[0], max(domain.lo[0] + 1,
                                              domain.hi[0] - w)))
    lat0 = int(rng.integers(domain.lo[1], max(domain.lo[1] + 1,
                                              domain.hi[1] - h - 5 * step)))
    forward = []
    for k in range(5):
        box = _clip_box((lon0, lat0 + k * step),
                        (lon0 + w - 1, lat0 + k * step + h - 1), domain)
        forward.append(SimilarityJoinQuery(box=box, eps=eps))
    return forward + forward[::-1]


def zipf_workload(domain: Box, n_queries: int = 200, n_templates: int = 30,
                  s: float = 1.1, eps: int = 1, field_frac: float = 0.08,
                  seed: int = 7,
                  anchors: Optional[Sequence[Tuple[int, int]]] = None
                  ) -> List[SimilarityJoinQuery]:
    """Zipf-skewed repeat workload: a pool of ``n_templates`` distinct
    query boxes sampled once, then ``n_queries`` draws with rank-``k``
    probability p_k ∝ 1/k^s — the "millions of users" traffic shape the
    result-cache/MQO tiers target (most queries are exact repeats of a
    few hot templates; the tail still exercises cold paths). Fully
    seeded: identical arguments yield an identical query list.
    ``anchors`` targets template fields at observed detections, as in
    :func:`ptf1_workload`. Fields span the first two dimensions; any
    further dimensions (e.g. PTF's time axis) are queried in full."""
    rng = np.random.default_rng(seed)
    ra_n, dec_n = domain.side(0), domain.side(1)
    w = max(1, int(ra_n * field_frac))
    h = max(1, int(dec_n * field_frac))
    rest_lo = tuple(domain.lo[2:])
    rest_hi = tuple(domain.hi[2:])
    templates: List[SimilarityJoinQuery] = []
    for _ in range(n_templates):
        if anchors is not None:
            a_ra, a_dec = anchors[int(rng.integers(0, len(anchors)))]
            ra0, dec0 = int(a_ra) - w // 2, int(a_dec) - h // 2
        else:
            ra0 = int(rng.integers(domain.lo[0], domain.hi[0] - w + 1))
            dec0 = int(rng.integers(domain.lo[1], domain.hi[1] - h + 1))
        box = _clip_box((ra0, dec0) + rest_lo,
                        (ra0 + w - 1, dec0 + h - 1) + rest_hi, domain)
        templates.append(SimilarityJoinQuery(box=box, eps=eps))
    ranks = np.arange(1, len(templates) + 1, dtype=np.float64)
    probs = ranks ** -float(s)
    probs /= probs.sum()
    draws = rng.choice(len(templates), size=n_queries, p=probs)
    return [templates[int(k)] for k in draws]


def ptf_stress_workload(domain: Box, n_queries: int = 100, eps: int = 1,
                        seed: int = 17,
                        anchors: Optional[Sequence[Tuple[int, int]]] = None
                        ) -> List[SimilarityJoinQuery]:
    """100 real-workload-style queries: a mix of exploration, revisits, and
    range shifts (§4.2.2)."""
    rng = np.random.default_rng(seed)
    out: List[SimilarityJoinQuery] = []
    p1 = ptf1_workload(domain, n_queries=max(4, n_queries // 2), eps=eps,
                       seed=seed, anchors=anchors)
    p2 = ptf2_workload(domain, n_queries=max(4, n_queries // 3), eps=eps,
                       seed=seed + 1, anchors=anchors)
    pool = p1 + p2
    while len(out) < n_queries:
        out.append(pool[int(rng.integers(0, len(pool)))])
    return out[:n_queries]
