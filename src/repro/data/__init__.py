"""Input pipeline over the distributed raw-array cache."""
