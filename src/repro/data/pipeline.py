"""Training input pipeline over the paper's distributed raw-array cache.

The corpus is a sparse 2-D array ``tokens[sample, position]`` stored in raw
(CSV/FITS-like/HDF5-like) files spread across pod hosts — unorganized, as in
the paper's setting. Every training step issues a subarray query
``[sample_lo..sample_hi] x [0..seq]``; the cache coordinator runs the full
stack on it (evolving R-tree chunking -> Alg. 2 eviction -> Alg. 3
placement), so repeated epochs hit the distributed cache instead of
re-scanning raw shards. The pipeline is deterministic given
``(epoch, step)`` — its state rides in the training checkpoint, giving
bit-exact resume after failures.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.arrayio.catalog import Catalog, FileReader, build_catalog
from repro.arrayio.generator import GeneratedFile
from repro.core.cluster import RawArrayCluster
from repro.core.coordinator import SimilarityJoinQuery
from repro.core.geometry import Box, points_in_box


def make_token_corpus(n_samples: int, max_len: int, vocab: int,
                      n_files: int, seed: int = 0,
                      min_len_frac: float = 0.3):
    """Variable-length documents as a sparse [sample, position] array;
    round-robin rows across files (files overlap in sample ranges the same
    way PTF nights overlap the sky)."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(max(2, int(max_len * min_len_frac)), max_len + 1,
                        size=n_samples)
    per_file = [[] for _ in range(n_files)]
    for s in range(n_samples):
        toks = rng.integers(1, vocab, size=lens[s])
        pos = np.arange(lens[s])
        rows = np.stack([np.full(lens[s], s + 1), pos + 1], axis=1)
        per_file[s % n_files].append((rows, toks))
    files = []
    for chunks in per_file:
        coords = np.concatenate([c for c, _ in chunks]).astype(np.int64)
        attrs = np.concatenate([t for _, t in chunks]
                               ).astype(np.float32)[:, None]
        lo, hi = coords.min(0), coords.max(0)
        files.append(GeneratedFile(coords, attrs,
                                   Box(tuple(map(int, lo)),
                                       tuple(map(int, hi)))))
    return files, lens


@dataclasses.dataclass
class PipelineStats:
    steps: int = 0
    bytes_scanned: int = 0
    files_scanned: int = 0
    cache_hit_steps: int = 0


class RawArrayTokenPipeline:
    """Batch iterator over a raw-array corpus through the caching stack."""

    def __init__(self, catalog: Catalog, reader: FileReader, *,
                 n_hosts: int, host_budget_bytes: int, batch: int,
                 seq: int, policy: str = "cost", min_cells: int = 2048,
                 pad_id: int = 0):
        self.cluster = RawArrayCluster(
            catalog, reader, n_hosts, host_budget_bytes, policy=policy,
            min_cells=min_cells, execute_joins=False)
        self.reader = reader
        self.batch = batch
        self.seq = seq
        self.pad_id = pad_id
        self.n_samples = catalog.domain.hi[0]
        self.epoch = 0
        self.step_in_epoch = 0
        self.steps_per_epoch = max(1, self.n_samples // batch)
        self.stats = PipelineStats()

    # ------------------------------------------------------------- state --

    def state(self) -> Dict[str, int]:
        return {"epoch": self.epoch, "step_in_epoch": self.step_in_epoch}

    def set_state(self, state: Dict[str, int]) -> None:
        self.epoch = int(state["epoch"])
        self.step_in_epoch = int(state["step_in_epoch"])

    # ------------------------------------------------------------ batches --

    def _sample_range(self) -> Tuple[int, int]:
        # Deterministic epoch-strided order (shift per epoch so chunk reuse
        # across epochs is partial, like PTF-2's shifted ranges).
        start = (self.step_in_epoch * self.batch +
                 (self.epoch * self.batch) // 2) % self.n_samples
        return start + 1, min(start + self.batch, self.n_samples) + 1

    def next_batch(self) -> Dict[str, np.ndarray]:
        s_lo, s_hi = self._sample_range()
        qbox = Box((s_lo, 1), (s_hi - 1, self.seq + 1))
        ex = self.cluster.run_query(SimilarityJoinQuery(qbox, eps=1))
        rep = ex.report
        self.stats.steps += 1
        scanned = sum(rep.scan_bytes_by_node.values())
        self.stats.bytes_scanned += scanned
        self.stats.files_scanned += len(rep.files_scanned)
        if scanned == 0:
            self.stats.cache_hit_steps += 1

        out = np.full((self.batch, self.seq + 1), self.pad_id, np.int64)
        valid = np.zeros((self.batch, self.seq + 1), bool)
        coord = self.cluster.coordinator
        for cm in rep.queried_chunks:
            all_coords, attrs = self.reader.read(cm.file_id)
            idx = coord.chunks.cell_indices(cm.chunk_id, cm.file_id)
            if idx is None:            # file-granularity unit (file_lru)
                coords = all_coords
                chunk_attrs = attrs
            else:
                coords = all_coords[idx]
                chunk_attrs = attrs[idx]
            mask = points_in_box(coords, qbox)
            cc = coords[mask]
            toks = chunk_attrs[mask][:, 0].astype(np.int64)
            rows = cc[:, 0] - s_lo
            cols = cc[:, 1] - 1
            out[rows, cols] = toks
            valid[rows, cols] = True

        tokens = out[:, :-1]
        labels = np.where(valid[:, 1:], out[:, 1:], -1)
        self.step_in_epoch += 1
        if self.step_in_epoch >= self.steps_per_epoch:
            self.step_in_epoch = 0
            self.epoch += 1
        return {"tokens": tokens.astype(np.int32),
                "labels": labels.astype(np.int32)}


def build_pipeline(tmpdir: str, *, n_samples: int = 256, seq: int = 64,
                   vocab: int = 512, n_files: int = 8, n_hosts: int = 4,
                   batch: int = 16, host_budget_bytes: int = 1 << 20,
                   fmt: str = "hdf5", policy: str = "cost",
                   seed: int = 0) -> RawArrayTokenPipeline:
    files, _ = make_token_corpus(n_samples, seq, vocab, n_files, seed)
    catalog, data = build_catalog(files, tmpdir, fmt, n_hosts)
    reader = FileReader(catalog, data)
    return RawArrayTokenPipeline(
        catalog, reader, n_hosts=n_hosts,
        host_budget_bytes=host_budget_bytes, batch=batch, seq=seq,
        policy=policy)
