"""Transient-fault pipeline: seeded injection, retry/degrade, auditing.

Public surface of the fault subsystem (PR 10):

* :class:`FaultInjector` / :class:`FaultSpec` / :func:`make_faults` —
  deterministic, seeded fault schedules armed at named fault points
  (``scan.read``, ``ship.transfer``, ``prep.build``, ``dispatch.kernel``,
  ``recover.readmit``) via the single ``fault_point(name, ...)`` seam.
* :class:`ChecksumRegistry` / :func:`payload_checksum` — per-chunk CRCs
  that catch bit-flip corruption faults on shipped payloads.
* :class:`RetryPolicy` / :class:`Retrier` / :func:`make_retry` —
  bounded retries with exponential backoff under an injectable clock
  and a per-operation timeout budget.
* :class:`DegradedResult` / :func:`make_degraded` — typed partial
  results naming exactly which sub-boxes were served after an exhausted
  retry budget.
* :class:`InvariantAuditor` / :class:`AuditViolation` — cross-layer
  consistency checks over the listener-coupled cache tiers.
* The typed error hierarchy in :mod:`repro.faults.errors`.

Everything defaults off (``faults="off"``): the seam is never consulted
and the pipeline is bit-for-bit the fault-free seed.
"""
from repro.faults.audit import AuditViolation, InvariantAuditor
from repro.faults.errors import (BatchInFlightError, ChecksumError,
                                 InjectedFaultError, RetryExhaustedError,
                                 ScanError, TransientFaultError)
from repro.faults.injector import (FAULT_KINDS, FAULT_POINTS, ChecksumRegistry,
                                   FaultInjector, FaultSpec, make_faults,
                                   payload_checksum)
from repro.faults.retry import (DegradedResult, Retrier, RetryPolicy,
                                make_degraded, make_retry)

__all__ = [
    "AuditViolation", "BatchInFlightError", "ChecksumError",
    "ChecksumRegistry", "DegradedResult", "FAULT_KINDS", "FAULT_POINTS",
    "FaultInjector", "FaultSpec", "InjectedFaultError", "InvariantAuditor",
    "Retrier", "RetryExhaustedError", "RetryPolicy", "ScanError",
    "TransientFaultError", "make_degraded", "make_faults", "make_retry",
    "payload_checksum",
]
