"""Cross-layer invariant auditor over the listener-coupled cache tiers.

Residency (``CacheState``) drives four derived tiers through listener
hooks: device buffers (``JaxMeshBackend``), join artifacts
(``JoinArtifactCache``), the coverage index, and result-cache version
stamps. Under fault storms a missed hook or a partially-applied
recovery would silently diverge them; the :class:`InvariantAuditor`
cross-checks after every policy round and recovery:

* **containment** — device buffers ⊆ resident chunks (and each buffer's
  holder set ⊆ the chunk's replica set + home), pinned batches ⊆
  resident, artifacts ⊆ resident;
* **coverage** — coverage-index entries ⊆ resident, and (when the reuse
  layer keeps it synced) extents match chunk metadata exactly;
* **replica accounting** — every location tuple well-formed (non-empty,
  duplicate-free, nodes in range, chunk resident) and per-node byte
  totals summing to the global ``cached_bytes``;
* **result-cache monotonicity** — the residency version stamp never
  decreases.

The auditor registers as a ``CacheState`` listener only to observe
lifecycle events; the checks themselves run via :meth:`audit`, which the
coordinator calls explicitly after ``sync_devices`` (so listener
ordering can never make the auditor see a half-reconciled tier), and
standalone via ``tools/audit_state.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, List, Optional

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.cache_state import CacheState
    from repro.core.coordinator import CacheCoordinator


@dataclass(frozen=True)
class AuditViolation:
    """One failed invariant: which check, and a human-readable detail."""

    invariant: str
    detail: str

    def __str__(self) -> str:
        """``invariant: detail`` — the line tools print per violation."""
        return f"{self.invariant}: {self.detail}"


class InvariantAuditor:
    """Audits the coordinator's coupled cache tiers; see module docstring.

    Violations accumulate in ``violations`` (``violations_total`` is the
    cumulative count backends snapshot/delta per query); ``audits_run``
    counts full passes. A bound backend (set via :meth:`attach`) enables
    the device-buffer checks; without one those checks are skipped.
    """

    def __init__(self, coordinator: "CacheCoordinator") -> None:
        """Bind to ``coordinator``; the backend attaches itself later."""
        self.coordinator = coordinator
        self.backend: Any = None
        self.violations: List[AuditViolation] = []
        self.violations_total = 0
        self.audits_run = 0
        self.reconciles = 0
        self._last_result_version: Optional[int] = None

    def attach(self, backend: Any) -> None:
        """Give the auditor a backend to cross-check device state against."""
        self.backend = backend

    # ---------------------------------------------- CacheState listener

    def on_drop(self, chunk_id: int) -> None:
        """Listener hook: observation only (checks run in :meth:`audit`)."""

    def on_split(self, parent_id: int, leaves) -> None:
        """Listener hook: observation only (checks run in :meth:`audit`)."""

    def reconcile(self, state: "CacheState") -> None:
        """Listener hook: count the sync; heavy checks stay in
        :meth:`audit` so ordering against other listeners is moot."""
        self.reconciles += 1

    # ------------------------------------------------------ audit passes

    def audit(self) -> List[AuditViolation]:
        """Run every invariant check once; returns (and accumulates) the
        violations found in this pass."""
        coord = self.coordinator
        found: List[AuditViolation] = []
        found.extend(self._check_buffers(coord))
        found.extend(self._check_artifacts(coord))
        found.extend(self._check_coverage(coord))
        found.extend(self._check_replicas(coord))
        found.extend(self._check_result_versions(coord))
        self.audits_run += 1
        self.violations.extend(found)
        self.violations_total += len(found)
        return found

    # -------------------------------------------------------- invariants

    def _check_buffers(self, coord: "CacheCoordinator"
                       ) -> List[AuditViolation]:
        """Device buffers (and pinned batches) must track residency."""
        out: List[AuditViolation] = []
        backend = self.backend
        buffers = getattr(backend, "_buffers", None)
        if buffers is None:
            return out
        cached = coord.cache.cached
        for cid, holders in buffers.items():
            if cid not in cached:
                out.append(AuditViolation(
                    "buffers⊆residency",
                    f"device buffer for non-resident chunk {cid} "
                    f"on nodes {sorted(holders)}"))
                continue
            reps = coord.cache.replicas_of(cid)
            expected = set(reps) if reps else {coord.chunks.home_node(cid)}
            extra = set(holders) - expected
            if extra:
                out.append(AuditViolation(
                    "buffers⊆replicas",
                    f"chunk {cid} buffered on {sorted(extra)} outside "
                    f"replica set {sorted(expected)}"))
        pinned = getattr(backend, "_pinned_by_chunk", None) or {}
        for cid in pinned:
            if cid not in cached:
                out.append(AuditViolation(
                    "pinned⊆residency",
                    f"pinned dispatch batch references evicted chunk {cid}"))
        return out

    def _check_artifacts(self, coord: "CacheCoordinator"
                         ) -> List[AuditViolation]:
        """Join artifacts must only exist for resident chunks."""
        out: List[AuditViolation] = []
        artifacts = getattr(self.backend, "artifacts", None)
        if artifacts is None:
            return out
        cached = coord.cache.cached
        for cid in artifacts.chunk_ids():
            if cid not in cached:
                out.append(AuditViolation(
                    "artifacts⊆residency",
                    f"join artifacts live for evicted chunk {cid}"))
        audit_fn = getattr(artifacts, "audit", None)
        if callable(audit_fn):
            out.extend(AuditViolation("artifact-index", detail)
                       for detail in audit_fn())
        return out

    def _check_coverage(self, coord: "CacheCoordinator"
                        ) -> List[AuditViolation]:
        """Coverage-index entries must be resident with exact extents."""
        out: List[AuditViolation] = []
        coverage = coord.cache.coverage
        if not len(coverage):
            return out
        cached = coord.cache.cached
        for cid in coverage.ids():
            if cid not in cached:
                out.append(AuditViolation(
                    "coverage⊆residency",
                    f"coverage index advertises evicted chunk {cid}"))
                continue
            meta = coord.chunks.meta_of(cid)
            extent = coverage.box_of(cid)
            if meta is not None and extent is not None \
                    and extent != meta.box:
                out.append(AuditViolation(
                    "coverage-extents",
                    f"chunk {cid} coverage extent {extent} != "
                    f"metadata extent {meta.box}"))
        return out

    def _check_replicas(self, coord: "CacheCoordinator"
                        ) -> List[AuditViolation]:
        """Location tuples well-formed + byte accounting consistent."""
        out = [AuditViolation("replica-locations", detail)
               for detail in coord.cache.audit_locations(coord.n_nodes)]
        chunk_bytes = coord.chunks.size_tables()[0]
        per_node = coord.cache.bytes_by_node(chunk_bytes)
        total = coord.cache.cached_bytes(chunk_bytes)
        if sum(per_node.values()) != total:
            out.append(AuditViolation(
                "replica-bytes",
                f"per-node byte totals {sum(per_node.values())} != "
                f"global replica-charged total {total}"))
        return out

    def _check_result_versions(self, coord: "CacheCoordinator"
                               ) -> List[AuditViolation]:
        """Result-cache residency version must be monotonic."""
        out: List[AuditViolation] = []
        rc = getattr(coord, "result_cache", None)
        if rc is None:
            return out
        version = rc.version
        if (self._last_result_version is not None
                and version < self._last_result_version):
            out.append(AuditViolation(
                "result-version-monotonic",
                f"result-cache version went backwards: "
                f"{self._last_result_version} -> {version}"))
        self._last_result_version = version
        return out

    # -------------------------------------------------------- reporting

    def report(self) -> str:
        """Multi-line human-readable summary of cumulative audit state."""
        lines = [f"audits_run={self.audits_run} "
                 f"violations={self.violations_total} "
                 f"reconciles_seen={self.reconciles}"]
        lines.extend(f"  VIOLATION {v}" for v in self.violations)
        return "\n".join(lines)
