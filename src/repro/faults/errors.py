"""Typed errors for the transient-fault pipeline.

The hierarchy draws one load-bearing line: everything under
:class:`TransientFaultError` is *retryable* — the :class:`~repro.faults.retry.Retrier`
catches it, backs off, and re-attempts the operation — while
:class:`RetryExhaustedError` and :class:`BatchInFlightError` are terminal
control-flow signals that callers handle explicitly (degrade the query,
reject the call).
"""
from __future__ import annotations

from typing import Any, Optional


class TransientFaultError(RuntimeError):
    """Base class for failures that are worth retrying.

    Raising a subclass inside an operation wrapped by
    :meth:`repro.faults.retry.Retrier.call` triggers backoff + retry
    rather than propagating to the caller.
    """


class InjectedFaultError(TransientFaultError):
    """A seeded fault fired at a named fault point (``kind="error"``)."""

    def __init__(self, point: str, **context: Any) -> None:
        """``point`` is the fault-point name (e.g. ``"ship.transfer"``);
        ``context`` carries site-specific detail (chunk id, node, ...)."""
        self.point = point
        self.context = dict(context)
        detail = ", ".join(f"{k}={v}" for k, v in sorted(context.items()))
        super().__init__(f"injected fault at {point}"
                         + (f" ({detail})" if detail else ""))


class ChecksumError(TransientFaultError):
    """A shipped chunk payload failed its per-chunk checksum.

    Raised by :meth:`repro.faults.injector.ChecksumRegistry.verify` when
    the CRC of a received payload differs from the recorded one — the
    transfer is treated as transient (corruption on the wire) and
    retried from a clean source.
    """

    def __init__(self, chunk_id: int, expected: int, got: int) -> None:
        """Record the mismatching CRCs for ``chunk_id``."""
        self.chunk_id = chunk_id
        self.expected = expected
        self.got = got
        super().__init__(f"checksum mismatch on chunk {chunk_id}: "
                         f"expected {expected:#010x}, got {got:#010x}")


class ScanError(TransientFaultError):
    """A raw-file scan failed (missing/truncated file, decode error).

    Names the file (id + path) and — once the planner annotates it — the
    queried box, so a failure deep in the scan path surfaces as a typed,
    attributable error instead of a bare ``OSError``/numpy exception.
    """

    def __init__(self, file_id: int, path: str,
                 box: Optional[Any] = None,
                 cause: Optional[BaseException] = None) -> None:
        """``box`` is the queried :class:`~repro.core.geometry.Box` when
        known (the planner fills it in); ``cause`` the original error."""
        self.file_id = file_id
        self.path = path
        self.box = box
        self.cause = cause
        msg = f"scan of file {file_id} ({path}) failed"
        if box is not None:
            msg += f" while serving query box {box}"
        if cause is not None:
            msg += f": {cause!r}"
        super().__init__(msg)


class RetryExhaustedError(RuntimeError):
    """An operation kept failing after every attempt the policy allows.

    Terminal (NOT a :class:`TransientFaultError`): callers catch it to
    degrade gracefully — drop the affected sub-boxes into a
    :class:`~repro.faults.retry.DegradedResult` instead of crashing the
    batch.
    """

    def __init__(self, op: str, attempts: int,
                 last_error: Optional[BaseException] = None,
                 timed_out: bool = False) -> None:
        """``op`` is the operation label (fault-point name), ``attempts``
        how many times it ran, ``last_error`` the final failure, and
        ``timed_out`` whether the per-operation budget (rather than the
        attempt cap) ended the retry loop."""
        self.op = op
        self.attempts = attempts
        self.last_error = last_error
        self.timed_out = timed_out
        why = "timeout budget exhausted" if timed_out else "attempts exhausted"
        super().__init__(f"retry budget for {op} exhausted after "
                         f"{attempts} attempt(s) ({why}); "
                         f"last error: {last_error!r}")


class BatchInFlightError(RuntimeError):
    """``fail_node`` was called while a planning batch is in flight.

    Mid-batch crash-restarts would mutate residency under the planner's
    feet and corrupt accounting; the coordinator rejects them with this
    typed error so callers can retry between batches.
    """
