"""Seeded, schedule-driven fault injection behind one ``fault_point`` seam.

The coordinator and both backends arm *named fault points* — the five
operation sites of the pipeline::

    scan.read        raw-file scan / decode (planner, raw fallback)
    ship.transfer    replica-to-node chunk transfer (backends)
    prep.build       host-side join prep (artifact build)
    dispatch.kernel  per-node kernel dispatch
    recover.readmit  post-crash re-admission of a lost chunk

by calling :meth:`FaultInjector.fault_point` wherever the real operation
happens. With no injector configured (``faults="off"``, the default) the
seam is never consulted and behavior is bit-for-bit the fault-free seed.

Determinism: each site draws from its **own** RNG stream, derived from
``(seed, crc32(site name))``, and consumes exactly one uniform draw per
crossing (plus per-fire draws for kind/byte choices). A site's schedule
therefore depends only on its own crossing count — re-running the same
seeded workload reproduces the identical injection schedule, and adding
a new fault point never perturbs the others.

Three fault kinds:

* ``"error"``   — raise :class:`~repro.faults.errors.InjectedFaultError`
  (a transient failure the :class:`~repro.faults.retry.Retrier` retries).
* ``"latency"`` — a straggler: delay the crossing by ``delay_s`` (via
  ``clock.advance`` when the injected clock supports it, else a real
  sleep) and let the operation succeed.
* ``"corrupt"`` — return a bit-flipped **copy** of the crossing's
  payload; the caller verifies it against the
  :class:`ChecksumRegistry` and the resulting
  :class:`~repro.faults.errors.ChecksumError` is retried like any other
  transient fault. Crossings without a payload fall back to ``"error"``.
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple, Union

import numpy as np

from repro.faults.errors import ChecksumError, InjectedFaultError
from repro.obs.clock import Clock, as_clock

FAULT_POINTS: Tuple[str, ...] = ("scan.read", "ship.transfer", "prep.build",
                                 "dispatch.kernel", "recover.readmit")
FAULT_KINDS: Tuple[str, ...] = ("error", "latency", "corrupt")

#: Cap on how long a latency fault may really sleep (wall-clock clocks
#: only); manual clocks advance by the full ``delay_s`` virtually.
_REAL_SLEEP_CAP_S = 0.005


@dataclass(frozen=True)
class FaultSpec:
    """Schedule for one fault point: fire with probability ``rate`` per
    crossing, choosing uniformly among ``kinds``; ``delay_s`` sizes
    latency faults and ``max_fires`` (optional) caps total fires."""

    point: str
    rate: float
    kinds: Tuple[str, ...] = ("error",)
    delay_s: float = 0.002
    max_fires: Optional[int] = None

    def __post_init__(self) -> None:
        """Validate the point name, rate range, and kind names."""
        if self.point not in FAULT_POINTS:
            raise ValueError(f"unknown fault point {self.point!r}; "
                             f"expected one of {FAULT_POINTS}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"fault rate must be in [0, 1], got {self.rate}")
        for k in self.kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}; "
                                 f"expected one of {FAULT_KINDS}")
        if not self.kinds:
            raise ValueError("FaultSpec.kinds must not be empty")


def payload_checksum(payload: Any) -> int:
    """CRC32 of a chunk payload's raw bytes (host copy, contiguous)."""
    arr = np.ascontiguousarray(np.asarray(payload))
    return zlib.crc32(arr.tobytes())


class ChecksumRegistry:
    """Per-chunk payload checksums for end-to-end transfer integrity.

    ``record`` memoizes the CRC of a chunk's clean host payload the
    first time it ships; ``verify`` recomputes the CRC of the received
    payload and raises :class:`~repro.faults.errors.ChecksumError` on a
    mismatch (counted in ``mismatches``).
    """

    def __init__(self) -> None:
        """Start with no recorded checksums and a zero mismatch count."""
        self._crc: Dict[int, int] = {}
        self.mismatches = 0

    def record(self, chunk_id: int, payload: Any) -> int:
        """Record (once) and return the clean CRC for ``chunk_id``."""
        if chunk_id not in self._crc:
            self._crc[chunk_id] = payload_checksum(payload)
        return self._crc[chunk_id]

    def verify(self, chunk_id: int, payload: Any) -> None:
        """Raise :class:`ChecksumError` if ``payload`` does not match the
        recorded CRC for ``chunk_id`` (unknown chunks are recorded)."""
        got = payload_checksum(payload)
        expected = self._crc.setdefault(chunk_id, got)
        if got != expected:
            self.mismatches += 1
            raise ChecksumError(chunk_id, expected, got)

    def forget(self, chunk_id: int) -> None:
        """Drop the recorded CRC for a retired chunk id (split/evict)."""
        self._crc.pop(chunk_id, None)

    def __len__(self) -> int:
        """Number of chunks with a recorded checksum."""
        return len(self._crc)

    # ------------------------- CacheState listener (lifecycle hygiene)

    def on_drop(self, chunk_id: int) -> None:
        """Listener hook: a dropped chunk's CRC must not survive — a
        later chunk reusing the id would trip a false mismatch."""
        self.forget(chunk_id)

    def on_split(self, parent_id: int, *args: Any) -> None:
        """Listener hook: the split parent's payload is retired with it;
        children record fresh CRCs on their first ship."""
        self.forget(parent_id)

    def reconcile(self, state: Any) -> None:
        """Listener hook: drop CRCs of chunks no longer resident."""
        for cid in [c for c in self._crc if c not in state.cached]:
            self.forget(cid)


class FaultInjector:
    """Deterministic, seeded transient-fault injector.

    Constructed from per-point :class:`FaultSpec` schedules (or a plain
    ``{point: rate}`` mapping via :func:`make_faults` /
    :meth:`FaultInjector.storm`) and threaded through the stack like the
    injectable ``Clock``. Counters (total fires, per point × kind, delay
    seconds) are cumulative; backends snapshot/delta them to attribute
    injections to individual queries. ``schedule_log`` records every
    fire as ``(point, crossing_index, kind)`` so two same-seed runs can
    be asserted identical.
    """

    def __init__(self, specs: Iterable[FaultSpec], seed: int = 0,
                 clock: Optional[Clock] = None) -> None:
        """``specs`` give at most one schedule per point; ``seed`` roots
        every per-site RNG stream; ``clock`` (optional) makes latency
        faults virtual when it supports ``advance``."""
        self.specs: Dict[str, FaultSpec] = {}
        for spec in specs:
            if spec.point in self.specs:
                raise ValueError(f"duplicate FaultSpec for {spec.point!r}")
            self.specs[spec.point] = spec
        self.seed = int(seed)
        self.clock = as_clock(clock) if clock is not None else None
        self._rng: Dict[str, np.random.Generator] = {
            name: np.random.default_rng([self.seed, zlib.crc32(name.encode())])
            for name in FAULT_POINTS}
        self.crossings: Dict[str, int] = {n: 0 for n in FAULT_POINTS}
        self.injected = 0
        self.by_point: Dict[str, Dict[str, int]] = {}
        self.latency_s = 0.0
        self.schedule_log: List[Tuple[str, int, str]] = []

    # ------------------------------------------------------------ seam

    def fault_point(self, name: str, payload: Any = None,
                    **context: Any) -> Any:
        """One crossing of the named fault point.

        Returns ``payload`` unchanged when no fault fires (or a
        bit-flipped copy for a ``"corrupt"`` fire); raises
        :class:`InjectedFaultError` for an ``"error"`` fire; sleeps and
        returns for a ``"latency"`` fire. ``context`` decorates the
        raised error only — it never influences the schedule.
        """
        if name not in self._rng:
            raise ValueError(f"unknown fault point {name!r}; "
                             f"expected one of {FAULT_POINTS}")
        crossing = self.crossings[name]
        self.crossings[name] = crossing + 1
        spec = self.specs.get(name)
        if spec is None or spec.rate <= 0.0:
            return payload
        fired = sum(self.by_point.get(name, {}).values())
        if spec.max_fires is not None and fired >= spec.max_fires:
            return payload
        rng = self._rng[name]
        if rng.random() >= spec.rate:
            return payload
        kind = spec.kinds[0] if len(spec.kinds) == 1 else \
            spec.kinds[int(rng.integers(len(spec.kinds)))]
        if kind == "corrupt" and payload is None:
            kind = "error"
        self.injected += 1
        self.by_point.setdefault(name, {}).setdefault(kind, 0)
        self.by_point[name][kind] += 1
        self.schedule_log.append((name, crossing, kind))
        if kind == "error":
            raise InjectedFaultError(name, crossing=crossing, **context)
        if kind == "latency":
            self._delay(spec.delay_s)
            return payload
        return self._corrupt(payload, rng)

    # ------------------------------------------------------- internals

    def _delay(self, delay_s: float) -> None:
        """Apply a straggler delay: virtually via ``clock.advance`` when
        available, else a (capped) real sleep."""
        self.latency_s += delay_s
        advance = getattr(self.clock, "advance", None)
        if advance is not None:
            advance(delay_s)
        else:
            time.sleep(min(delay_s, _REAL_SLEEP_CAP_S))

    @staticmethod
    def _corrupt(payload: Any, rng: np.random.Generator) -> Any:
        """Return a copy of ``payload`` with one byte bit-flipped."""
        arr = np.array(np.asarray(payload), copy=True)
        flat = arr.reshape(-1).view(np.uint8)
        if flat.size == 0:
            return arr
        flat[int(rng.integers(flat.size))] ^= 0xFF
        return arr

    # ------------------------------------------------------- reporting

    def counters(self) -> Dict[str, float]:
        """Cumulative counters: total fires, per ``point.kind`` fires,
        and total injected latency seconds."""
        out: Dict[str, float] = {"injected": self.injected,
                                 "latency_s": self.latency_s}
        for point, kinds in sorted(self.by_point.items()):
            for kind, n in sorted(kinds.items()):
                out[f"{point}.{kind}"] = n
        return out

    # ----------------------------------------------------- constructors

    @classmethod
    def storm(cls, rate: float, seed: int = 0,
              kinds: Tuple[str, ...] = FAULT_KINDS,
              points: Tuple[str, ...] = FAULT_POINTS,
              delay_s: float = 0.002,
              clock: Optional[Clock] = None) -> "FaultInjector":
        """Uniform fault storm: every point in ``points`` fires each of
        ``kinds`` (uniformly chosen) at per-crossing probability
        ``rate`` — the acceptance-criteria configuration."""
        return cls([FaultSpec(p, rate, kinds=kinds, delay_s=delay_s)
                    for p in points], seed=seed, clock=clock)


def make_faults(spec: Union[str, None, FaultInjector, Mapping[str, float]],
                seed: int = 0,
                clock: Optional[Clock] = None) -> Optional[FaultInjector]:
    """Normalize a ``faults=`` knob (mirrors ``as_clock``/``make_telemetry``).

    ``None``/``"off"`` → ``None`` (seam disabled, seed-exact);
    a :class:`FaultInjector` passes through; a ``{point: rate}`` mapping
    builds an error-only injector with the given ``seed``/``clock``.
    """
    if spec is None or spec == "off":
        return None
    if isinstance(spec, FaultInjector):
        return spec
    if isinstance(spec, Mapping):
        return FaultInjector([FaultSpec(p, r) for p, r in spec.items()],
                             seed=seed, clock=clock)
    raise ValueError(f"faults must be 'off', None, a FaultInjector, or a "
                     f"{{point: rate}} mapping, got {spec!r}")
