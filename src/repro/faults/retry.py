"""Retry/backoff/timeout wrapper and typed degraded-mode results.

:class:`RetryPolicy` is the knob surface (max attempts, exponential
backoff, per-operation timeout budget); :class:`Retrier` executes an
operation under that policy with an **injectable clock** — a
``ManualClock`` advances virtually during backoff so tests and the
simulated backend never really sleep, while wall clocks sleep for real.

When the budget is exhausted the :class:`Retrier` raises
:class:`~repro.faults.errors.RetryExhaustedError`; callers catch it and
*degrade* instead of crashing: the affected sub-boxes are subtracted
from the query box and the query returns a :class:`DegradedResult`
naming exactly which regions were served and which operations failed.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Mapping, Optional, Tuple, Union

from repro.core.geometry import Box, residual_boxes
from repro.faults.errors import RetryExhaustedError, TransientFaultError
from repro.obs.clock import Clock, MONOTONIC, as_clock


@dataclass(frozen=True)
class RetryPolicy:
    """Retry knobs for one class of transient operations.

    ``max_attempts`` bounds total tries (first try included);
    ``backoff_base_s * backoff_multiplier**attempt`` spaces retries; and
    ``timeout_s`` (optional) caps the whole operation — elapsed time
    plus the next backoff must fit the budget or the retry loop gives
    up early (``timed_out=True`` on the raised error).
    """

    max_attempts: int = 3
    backoff_base_s: float = 0.001
    backoff_multiplier: float = 2.0
    timeout_s: Optional[float] = None

    def __post_init__(self) -> None:
        """Validate ranges (at least one attempt, non-negative times)."""
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backoff_base_s < 0 or self.backoff_multiplier < 1.0:
            raise ValueError("backoff_base_s must be >= 0 and "
                             "backoff_multiplier >= 1.0")
        if self.timeout_s is not None and self.timeout_s <= 0:
            raise ValueError("timeout_s must be positive when set")

    def backoff_s(self, attempt: int) -> float:
        """Backoff to sleep after failed attempt ``attempt`` (0-based)."""
        return self.backoff_base_s * (self.backoff_multiplier ** attempt)


def make_retry(spec: Union[str, None, RetryPolicy, Mapping[str, Any]]
               ) -> RetryPolicy:
    """Normalize a ``retry=`` knob: ``None``/``"default"`` → the default
    :class:`RetryPolicy`; an instance passes through; a mapping becomes
    ``RetryPolicy(**mapping)``."""
    if spec is None or spec == "default":
        return RetryPolicy()
    if isinstance(spec, RetryPolicy):
        return spec
    if isinstance(spec, Mapping):
        return RetryPolicy(**spec)
    raise ValueError(f"retry must be None, 'default', a RetryPolicy, or a "
                     f"kwargs mapping, got {spec!r}")


class Retrier:
    """Runs operations under a :class:`RetryPolicy` with cumulative stats.

    ``call(op, fn)`` invokes ``fn(attempt)`` until it returns, a
    non-transient error escapes, or the budget (attempts or timeout) is
    exhausted — then raises :class:`RetryExhaustedError`. Passing the
    0-based ``attempt`` lets callers re-route each retry (e.g. pick a
    different surviving replica as the transfer source).

    Stats (``retries``, ``giveups``, ``timeouts``, ``backoff_s``) are
    cumulative; backends snapshot/delta them per query.
    """

    def __init__(self, policy: RetryPolicy,
                 clock: Optional[Clock] = None,
                 tracer: Any = None) -> None:
        """``clock`` drives the timeout budget and (when it supports
        ``advance``) virtual backoff sleeps; ``tracer`` (optional) wraps
        each re-attempt in a ``retry`` span."""
        self.policy = policy
        self.clock = as_clock(clock) if clock is not None else MONOTONIC
        self.tracer = tracer
        self.retries = 0
        self.giveups = 0
        self.timeouts = 0
        self.backoff_s = 0.0

    def call(self, op: str, fn: Callable[[int], Any]) -> Any:
        """Execute ``fn`` under the policy; see class docstring."""
        policy = self.policy
        started = self.clock.now()
        last: Optional[BaseException] = None
        for attempt in range(policy.max_attempts):
            try:
                if attempt == 0 or self.tracer is None:
                    return fn(attempt)
                with self.tracer.span("retry", cat="faults", op=op,
                                      attempt=attempt):
                    return fn(attempt)
            except TransientFaultError as e:
                last = e
                if attempt + 1 >= policy.max_attempts:
                    break
                backoff = policy.backoff_s(attempt)
                if (policy.timeout_s is not None and
                        self.clock.now() - started + backoff
                        > policy.timeout_s):
                    self.timeouts += 1
                    self.giveups += 1
                    raise RetryExhaustedError(op, attempt + 1, last,
                                              timed_out=True) from e
                self._sleep(backoff)
                self.retries += 1
        self.giveups += 1
        raise RetryExhaustedError(op, policy.max_attempts, last) from last

    def _sleep(self, backoff: float) -> None:
        """Back off — virtually when the clock supports ``advance``."""
        self.backoff_s += backoff
        advance = getattr(self.clock, "advance", None)
        if advance is not None:
            advance(backoff)
        elif backoff > 0:
            time.sleep(backoff)


@dataclass(frozen=True)
class DegradedResult:
    """What a query actually served after exhausted retry budgets.

    ``failed_boxes`` are the sub-boxes whose operations retried out
    (chunk/file extents clipped to the query box); ``served_boxes`` is
    the exact residual partition of the query box minus the failures;
    ``failed_ops`` names the operations that gave up; and
    ``matches_lower_bound`` is the match count over the served region
    only (a lower bound on the true answer).
    """

    query_box: Box
    served_boxes: Tuple[Box, ...]
    failed_boxes: Tuple[Box, ...]
    failed_ops: Tuple[str, ...]
    matches_lower_bound: int = 0

    @property
    def fully_failed(self) -> bool:
        """True when nothing of the query box could be served."""
        return not self.served_boxes


def make_degraded(query_box: Box, failed_boxes: Tuple[Box, ...],
                  failed_ops: Tuple[str, ...],
                  matches: int = 0) -> DegradedResult:
    """Build a :class:`DegradedResult`, computing ``served_boxes`` as
    the exact residual of ``query_box`` minus ``failed_boxes``."""
    served = tuple(residual_boxes(query_box, list(failed_boxes)))
    return DegradedResult(query_box=query_box, served_boxes=served,
                          failed_boxes=tuple(failed_boxes),
                          failed_ops=tuple(failed_ops),
                          matches_lower_bound=int(matches))
