"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

OPTIONAL layer. Add ``<name>.py`` (or ``.cu``) + ``ops.py`` + ``ref.py``
ONLY for compute hot-spots the paper itself optimizes with a custom
kernel; each kernel package ships a jit'd ops wrapper and a pure-jnp
oracle. ``simjoin`` carries both the dense grid and the block-sparse
(eps-pruned, scalar-prefetched) variant the join executors dispatch.
"""
