"""Flash attention forward kernel (pl.pallas_call + BlockSpec VMEM tiling).

Grid (B, H, Sq/bq). Each program holds one (bq, D) query tile in VMEM plus
the full (S, D) K/V stripe of its KV head (GQA maps q-head h to kv-head
h // rep via the BlockSpec index_map — no materialized KV expansion), and
runs the online-softmax recurrence over (bk, D) chunks with fp32
accumulators. Causal masking uses global indices so any (bq, bk) pairing is
correct, including rectangular Sq != Sk (decode-append prefill).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, causal: bool,
                  sm_scale: float, q_offset: int):
    bq, d = q_ref.shape[-2:]
    sk = k_ref.shape[-2]
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # (bq, d)
    iq = pl.program_id(2)

    def body(j, carry):
        acc, m, l = carry
        k = k_ref[0, 0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        s = q @ k.T                                      # (bq, bk)
        if causal:
            qi = iq * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0) + q_offset
            kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kj <= qi, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        acc = acc * corr[:, None] + p @ v
        return acc, m_new, l_new

    nk = sk // bk
    if causal:
        # Skip fully-masked KV blocks: block j is live iff
        # j*bk <= (iq+1)*bq - 1 + q_offset.
        nk_live = jnp.minimum(
            nk, ((iq + 1) * bq + q_offset + bk - 1) // bk)
    else:
        nk_live = nk
    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk_live, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, bq: int = 128, bk: int = 128,
                        q_offset: int = 0,
                        interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Hk, Sk, D) with H % Hk == 0.
    Sq % bq == 0 and Sk % bk == 0 (ops.py pads)."""
    b, h, sq, d = q.shape
    _, hk, sk, _ = k.shape
    assert h % hk == 0 and sq % bq == 0 and sk % bk == 0
    rep = h // hk
    sm_scale = 1.0 / math.sqrt(d)
    kernel = functools.partial(_flash_kernel, bk=bk, causal=causal,
                               sm_scale=sm_scale, q_offset=q_offset)
    grid = (b, h, sq // bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, sk, d),
                         lambda ib, ih, iq: (ib, ih // rep, 0, 0)),
            pl.BlockSpec((1, 1, sk, d),
                         lambda ib, ih, iq: (ib, ih // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda ib, ih, iq: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
