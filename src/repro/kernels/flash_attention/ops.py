"""jit'd wrapper: padding to block multiples, layout adaptation from the
model's (B, S, H, D) to the kernel's (B, H, S, D), interpret-mode fallback
on CPU hosts."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


def _pad_seq(x: jax.Array, block: int) -> jax.Array:
    s = x.shape[2]
    pad = (-s) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return x


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, Hk, D) — model layout in, model
    layout out."""
    sq = q.shape[1]
    qt = _pad_seq(q.transpose(0, 2, 1, 3), bq)
    kt = _pad_seq(k.transpose(0, 2, 1, 3), bk)
    vt = _pad_seq(v.transpose(0, 2, 1, 3), bk)
    # Padded KV columns must never win the softmax: they are masked by the
    # causal test for kj >= Sk only when causal; for non-causal, rely on
    # explicit masking via a huge negative bias injected by zero-padded K
    # producing s=0 — so instead mask by slicing the output back and
    # padding K with nothing (non-causal callers must pass Sk % bk == 0).
    if not causal:
        assert k.shape[1] % bk == 0, "non-causal requires Sk % bk == 0"
    out = flash_attention_fwd(qt, kt, vt, causal=causal, bq=bq, bk=bk,
                              interpret=interpret)
    return out[:, :, :sq].transpose(0, 2, 1, 3)
