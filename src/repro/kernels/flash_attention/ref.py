"""Pure-jnp oracle for flash attention (fp32 softmax, GQA broadcast)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, q_offset: int = 0) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Hk, Sk, D)."""
    b, h, sq, d = q.shape
    _, hk, sk, _ = k.shape
    rep = h // hk
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        kj = jnp.arange(sk)[None, :]
        s = jnp.where(kj <= qi, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
