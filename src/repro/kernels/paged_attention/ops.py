"""jit'd wrapper for paged decode attention with interpret fallback."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.paged_attention import paged_attention


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, page_table, seq_lens,
                           interpret: bool = True):
    """jit'd entry for the paged decode-attention kernel (see
    ``paged_attention.paged_attention`` for shapes and semantics)."""
    return paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                           interpret=interpret)
