"""Paged decode attention (pl.pallas_call + PrefetchScalarGridSpec).

Single-token decode over a *paged* KV cache: pages are the chunk unit the
cost-based cache manager (repro.serve.kvcache) places in HBM; the page table
is scalar-prefetched so the BlockSpec index_map can fetch each request's
pages from arbitrary HBM slots — the TPU analogue of the paper's
"coordinator tells every node which chunk replica to use".

Grid (B, MAX_PAGES). Online-softmax accumulators live in VMEM scratch and
are carried across the page axis; out is written on the last page visit.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(page_table_ref, seq_lens_ref,      # scalar prefetch
                  q_ref, k_ref, v_ref,               # VMEM blocks
                  o_ref,                             # output
                  acc_ref, m_ref, l_ref,             # VMEM scratch
                  *, page_size: int, rep: int, sm_scale: float,
                  max_pages: int):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * sm_scale       # (H, D)
    k = k_ref[0].astype(jnp.float32)                  # (PS, Hk, D)
    v = v_ref[0].astype(jnp.float32)
    h, d = q.shape
    hk = k.shape[1]
    qg = q.reshape(hk, rep, d)
    s = jnp.einsum("krd,pkd->krp", qg, k)             # (Hk, rep, PS)
    s = s.reshape(h, page_size)
    pos = p * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (h, page_size), 1)
    live = pos < seq_lens_ref[b]
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    pexp = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + pexp.sum(axis=1)
    pv = jnp.einsum("krp,pkd->krd", pexp.reshape(hk, rep, page_size), v)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv.reshape(h, d)
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(p == max_pages - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, seq_lens: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, D); k_pages/v_pages: (NP, PS, Hk, D);
    page_table: (B, MAXP) int32 page ids (entries past the live length may
    point anywhere valid — they are masked by seq_lens); seq_lens: (B,)."""
    b, h, d = q.shape
    np_, ps, hk, _ = k_pages.shape
    maxp = page_table.shape[1]
    rep = h // hk
    kernel = functools.partial(_paged_kernel, page_size=ps, rep=rep,
                               sm_scale=1.0 / math.sqrt(d), max_pages=maxp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, maxp),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda ib, ip, pt, sl: (ib, 0, 0)),
            pl.BlockSpec((1, ps, hk, d),
                         lambda ib, ip, pt, sl: (pt[ib, ip], 0, 0, 0)),
            pl.BlockSpec((1, ps, hk, d),
                         lambda ib, ip, pt, sl: (pt[ib, ip], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda ib, ip, pt, sl: (ib, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, d), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        # jax renamed TPUCompilerParams -> CompilerParams; accept both.
        compiler_params=getattr(pltpu, "CompilerParams",
                                getattr(pltpu, "TPUCompilerParams", None))(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(page_table, seq_lens, q, k_pages, v_pages)
