"""Pure-jnp oracle for paged decode attention: gather pages into a dense KV
cache, run masked softmax attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, page_table: jax.Array,
                        seq_lens: jax.Array) -> jax.Array:
    """Reference decode attention over a paged KV cache: gather each
    request's pages dense, mask past ``seq_lens``, softmax-attend."""
    b, h, d = q.shape
    np_, ps, hk, _ = k_pages.shape
    maxp = page_table.shape[1]
    rep = h // hk
    # Gather: (B, MAXP, PS, Hk, D) -> (B, S, Hk, D)
    k = k_pages[page_table].reshape(b, maxp * ps, hk, d)
    v = v_pages[page_table].reshape(b, maxp * ps, hk, d)
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    live = jnp.arange(maxp * ps)[None, None, :] < seq_lens[:, None, None]
    s = jnp.where(live, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
