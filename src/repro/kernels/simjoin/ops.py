"""jit'd wrapper around the simjoin Pallas kernel: padding, sentinel
injection, block-count reduction, and a numpy-friendly entry point usable as
``RawArrayCluster.join_fn``."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.simjoin.simjoin import BLOCK, SENTINEL, simjoin_block_counts


def _pad_cm(x: jax.Array, sentinel: int) -> jax.Array:
    """(N, d) -> coordinate-major (d, N_padded) with sentinel fill."""
    n, d = x.shape
    npad = (-n) % BLOCK
    xt = jnp.transpose(x.astype(jnp.int32))
    if npad or n == 0:
        pad_n = npad if n else BLOCK
        xt = jnp.pad(xt, ((0, 0), (0, pad_n)), constant_values=sentinel)
    return xt


@functools.partial(jax.jit, static_argnames=("eps", "same", "interpret"))
def count_similar_pairs(a: jax.Array, b: jax.Array, eps: int, same: bool,
                        interpret: bool = True) -> jax.Array:
    """Unordered L1-neighbor pair count between coordinate sets (see
    ref.count_pairs_ref)."""
    at = _pad_cm(a, SENTINEL)
    bt = _pad_cm(b, -SENTINEL)
    counts = simjoin_block_counts(at, bt, eps, same, interpret=interpret)
    return counts.sum().astype(jnp.int32)


def count_similar_pairs_np(a: np.ndarray, b: np.ndarray, eps: int,
                           same: bool) -> int:
    """Drop-in ``join_fn`` for repro.core.cluster.RawArrayCluster."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return 0
    return int(count_similar_pairs(jnp.asarray(a, jnp.int32),
                                   jnp.asarray(b, jnp.int32), int(eps),
                                   bool(same)))


def pad_cm_np(x: np.ndarray, sentinel: int) -> np.ndarray:
    """Host-side version of ``_pad_cm``: (N, d) int coords -> coordinate-
    major (d, N_padded) int32 with sentinel fill, N_padded a positive
    multiple of BLOCK. Used to stack shape-bucketed pair batches before a
    single device transfer."""
    n, d = x.shape
    pad_n = (-n) % BLOCK if n else BLOCK
    xt = np.ascontiguousarray(x.astype(np.int32, copy=False).T)
    if pad_n:
        xt = np.pad(xt, ((0, 0), (0, pad_n)), constant_values=sentinel)
    return xt


@functools.partial(jax.jit, static_argnames=("eps", "same", "interpret"))
def count_similar_pairs_batch(a_stack: jax.Array, b_stack: jax.Array,
                              eps: int, same: bool,
                              interpret: bool = True) -> jax.Array:
    """Batched pair counting: ``a_stack``/``b_stack`` are (k, d, Na) /
    (k, d, Nb) coordinate-major stacks (pre-padded to BLOCK multiples with
    sentinels, e.g. via :func:`pad_cm_np`). Returns (k,) int32 match
    counts — one kernel dispatch chain per shape bucket instead of one
    per chunk pair. ``lax.map`` keeps the per-element grid (and thus the
    self-join ``program_id`` masking) identical to the unbatched call."""
    def one(ab):
        a, b = ab
        return simjoin_block_counts(a, b, eps, same,
                                    interpret=interpret).sum()
    return jax.lax.map(one, (a_stack, b_stack)).astype(jnp.int32)
