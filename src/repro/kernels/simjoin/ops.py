"""jit'd wrappers around the simjoin Pallas kernels: padding, sentinel
injection, block-count reduction, numpy-friendly entry points usable as
``RawArrayCluster.join_fn``, and the pruned (block-sparse) variants fed
by the host-side ``repro.kernels.simjoin.prune`` preprocessing.

``TRACE_COUNTS`` tallies how often each jitted entry point is *traced*
(the counter bumps run at trace time only): repeated same-shape
dispatches must not grow it — the no-recompile guarantee
``tests/test_simjoin_pruning.py`` asserts and ``BENCH_kernels.json``
records."""
from __future__ import annotations

import collections
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.simjoin.simjoin import (BLOCK, SENTINEL,
                                           simjoin_block_counts,
                                           simjoin_pruned_block_counts)

# Entry-point name -> times jax traced it (bumped at trace time only).
TRACE_COUNTS: "collections.Counter[str]" = collections.Counter()


def _pad_cm(x: jax.Array, sentinel: int) -> jax.Array:
    """(N, d) -> coordinate-major (d, N_padded) with sentinel fill."""
    n, d = x.shape
    npad = (-n) % BLOCK
    xt = jnp.transpose(x.astype(jnp.int32))
    if npad or n == 0:
        pad_n = npad if n else BLOCK
        xt = jnp.pad(xt, ((0, 0), (0, pad_n)), constant_values=sentinel)
    return xt


@functools.partial(jax.jit, static_argnames=("eps", "same", "interpret"))
def count_similar_pairs(a: jax.Array, b: jax.Array, eps: int, same: bool,
                        interpret: bool = True) -> jax.Array:
    """Unordered L1-neighbor pair count between coordinate sets (see
    ref.count_pairs_ref)."""
    TRACE_COUNTS["count_similar_pairs"] += 1
    at = _pad_cm(a, SENTINEL)
    bt = _pad_cm(b, -SENTINEL)
    counts = simjoin_block_counts(at, bt, eps, same, interpret=interpret)
    return counts.sum().astype(jnp.int32)


def count_similar_pairs_np(a: np.ndarray, b: np.ndarray, eps: int,
                           same: bool) -> int:
    """Drop-in ``join_fn`` for repro.core.cluster.RawArrayCluster."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return 0
    return int(count_similar_pairs(jnp.asarray(a, jnp.int32),
                                   jnp.asarray(b, jnp.int32), int(eps),
                                   bool(same)))


def pad_cm_np(x: np.ndarray, sentinel: int) -> np.ndarray:
    """Host-side version of ``_pad_cm``: (N, d) int coords -> coordinate-
    major (d, N_padded) int32 with sentinel fill, N_padded a positive
    multiple of BLOCK. Used to stack shape-bucketed pair batches before a
    single device transfer."""
    n, d = x.shape
    pad_n = (-n) % BLOCK if n else BLOCK
    xt = np.ascontiguousarray(x.astype(np.int32, copy=False).T)
    if pad_n:
        xt = np.pad(xt, ((0, 0), (0, pad_n)), constant_values=sentinel)
    return xt


@functools.partial(jax.jit, static_argnames=("eps", "same", "interpret"))
def count_similar_pairs_batch(a_stack: jax.Array, b_stack: jax.Array,
                              eps: int, same: bool,
                              interpret: bool = True) -> jax.Array:
    """Batched pair counting: ``a_stack``/``b_stack`` are (k, d, Na) /
    (k, d, Nb) coordinate-major stacks (pre-padded to BLOCK multiples with
    sentinels, e.g. via :func:`pad_cm_np`). Returns (k,) int32 match
    counts — one kernel dispatch chain per shape bucket instead of one
    per chunk pair. ``lax.map`` keeps the per-element grid (and thus the
    self-join ``program_id`` masking) identical to the unbatched call."""
    TRACE_COUNTS["batch"] += 1

    def one(ab):
        a, b = ab
        return simjoin_block_counts(a, b, eps, same,
                                    interpret=interpret).sum()
    return jax.lax.map(one, (a_stack, b_stack)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("eps", "same", "interpret"))
def count_similar_pairs_pruned(a_cm: jax.Array, b_cm: jax.Array,
                               pairs: jax.Array, eps: int, same: bool,
                               interpret: bool = True) -> jax.Array:
    """Block-sparse pair counting for ONE coordinate-set pair:
    ``a_cm``/``b_cm`` are (d, N) coordinate-major sets already spatially
    sorted and sentinel-padded on host (``prune.spatial_sort`` +
    :func:`pad_cm_np`), ``pairs`` the (P, 3) live block-pair list from
    ``prune.build_block_pairs``. Returns the scalar int32 match count."""
    TRACE_COUNTS["pruned"] += 1
    return simjoin_pruned_block_counts(
        a_cm, b_cm, pairs, eps, same,
        interpret=interpret).sum().astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("eps", "same", "interpret"))
def count_similar_pairs_pruned_batch(a_stack: jax.Array, b_stack: jax.Array,
                                     pairs_stack: jax.Array, eps: int,
                                     same: bool,
                                     interpret: bool = True) -> jax.Array:
    """Batched block-sparse pair counting: (k, d, Na) / (k, d, Nb)
    coordinate-major stacks plus a (k, P, 3) pair-list stack (every
    element's live pairs padded to the bucket's P with ``valid == 0``
    rows, see ``prune.pad_pairs``). Returns (k,) int32 match counts."""
    TRACE_COUNTS["pruned_batch"] += 1

    def one(abp):
        a, b, pr = abp
        return simjoin_pruned_block_counts(a, b, pr, eps, same,
                                           interpret=interpret).sum()
    return jax.lax.map(one, (a_stack, b_stack, pairs_stack)).astype(jnp.int32)


def count_similar_pairs_pruned_np(a: np.ndarray, b: np.ndarray, eps: int,
                                  same: bool, interpret: bool = True
                                  ) -> Tuple[int, int, int]:
    """Full host pipeline for one pair — sort, prune, pad, dispatch —
    returning ``(match_count, block_pairs_total, block_pairs_evaluated)``
    where *total* is the dense kernel's grid size and *evaluated* the
    live pairs actually dispatched. Used by benchmarks and parity tests;
    the batched executor path lives in ``repro.backend.executors``."""
    from repro.kernels.simjoin import prune
    if a.shape[0] == 0 or b.shape[0] == 0:
        return 0, 0, 0
    a_s = prune.spatial_sort(np.asarray(a))
    b_s = a_s if same else prune.spatial_sort(np.asarray(b))
    pairs, total = prune.build_block_pairs(a_s, b_s, BLOCK, int(eps),
                                           bool(same))
    if pairs.shape[0] == 0:
        return 0, total, 0
    at = pad_cm_np(a_s, SENTINEL)
    bt = pad_cm_np(b_s, -SENTINEL)
    got = count_similar_pairs_pruned(jnp.asarray(at), jnp.asarray(bt),
                                     jnp.asarray(pairs), int(eps),
                                     bool(same), interpret=interpret)
    return int(got), total, int(pairs.shape[0])
