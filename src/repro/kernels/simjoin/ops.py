"""jit'd wrapper around the simjoin Pallas kernel: padding, sentinel
injection, block-count reduction, and a numpy-friendly entry point usable as
``RawArrayCluster.join_fn``."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.simjoin.simjoin import BLOCK, SENTINEL, simjoin_block_counts


def _pad_cm(x: jax.Array, sentinel: int) -> jax.Array:
    """(N, d) -> coordinate-major (d, N_padded) with sentinel fill."""
    n, d = x.shape
    npad = (-n) % BLOCK
    xt = jnp.transpose(x.astype(jnp.int32))
    if npad or n == 0:
        pad_n = npad if n else BLOCK
        xt = jnp.pad(xt, ((0, 0), (0, pad_n)), constant_values=sentinel)
    return xt


@functools.partial(jax.jit, static_argnames=("eps", "same", "interpret"))
def count_similar_pairs(a: jax.Array, b: jax.Array, eps: int, same: bool,
                        interpret: bool = True) -> jax.Array:
    """Unordered L1-neighbor pair count between coordinate sets (see
    ref.count_pairs_ref)."""
    at = _pad_cm(a, SENTINEL)
    bt = _pad_cm(b, -SENTINEL)
    counts = simjoin_block_counts(at, bt, eps, same, interpret=interpret)
    return counts.sum().astype(jnp.int32)


def count_similar_pairs_np(a: np.ndarray, b: np.ndarray, eps: int,
                           same: bool) -> int:
    """Drop-in ``join_fn`` for repro.core.cluster.RawArrayCluster."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return 0
    return int(count_similar_pairs(jnp.asarray(a, jnp.int32),
                                   jnp.asarray(b, jnp.int32), int(eps),
                                   bool(same)))
