"""Host-side block pruning for the block-sparse simjoin kernel.

Pure-numpy preprocessing that turns a coordinate set into the inputs the
``simjoin_pruned_block_counts`` kernel consumes:

  1. ``spatial_sort`` orders cells along the longest dimension of their
     bounding box (lexicographic tie-break over the remaining
     dimensions) so consecutive 128-wide kernel blocks are spatially
     coherent (tight per-block boxes);
  2. ``block_bounds`` computes those per-block bounding boxes (real
     cells only — sentinel padding never enters a box);
  3. ``build_block_pairs`` keeps only the block pairs whose minimal L1
     box distance is ``<= eps`` — a sound prune because the minimal box
     distance lower-bounds the distance of every cell pair inside the
     two blocks (property-tested in ``test_hypothesis_properties``);
  4. ``pad_pairs``/``padded_pair_len`` pad surviving pair lists to a
     power-of-two bucket length so shape-bucketed batch dispatch does
     not retrace per distinct pair count.

A second, *cell-exact* prune stage refines the bbox-surviving pairs
(ISSUE 9, after Krčál et al.'s hierarchical bitmap indexing for
range/membership queries on multidimensional arrays):

  5. ``build_bitmaps`` derives a small hierarchical occupancy bitmap
     sidecar per block — the set of eps-quantized grid cells its real
     cells occupy (fine level, step ``bitmap_scale(eps)``) plus a
     coarse summary level (``BITMAP_COARSE``× wider cells);
  6. ``refine_block_pairs`` intersects each surviving bbox pair's
     dilated occupancy sets: a pair stays live only if some occupied
     fine cell of one block lies within the eps-dilation of an occupied
     fine cell of the other (coarse level first — most far pairs die on
     the cheap summary). Killing a pair is sound because every real
     cell lies inside its quantized grid cell, whose minimal box
     distance lower-bounds every contained cell pair's distance — the
     same argument as the bbox prune, applied per occupied cell instead
     of per whole block, so non-convex/stringy blocks whose boxes
     overlap empty space stop keeping pairs alive.

The count is invariant under the reordering: the join is a sum over
unordered cell pairs, and self-join dedup compares *positions in the
sorted order*, which still counts each unordered pair exactly once.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

#: Coarse summary factor of the hierarchical bitmap: one coarse cell
#: covers ``BITMAP_COARSE`` fine cells per dimension, so the summary
#: level holds far fewer occupied cells and kills most far pairs before
#: the fine-level intersection runs.
BITMAP_COARSE = 8

#: Fine-level quantization: the grid step is ``~eps / BITMAP_REFINE``.
#: A block holds at most 128 cells, so the occupied-cell set is bounded
#: regardless of the step — a fine step costs nothing extra here and
#: buys prune precision (on the GEO bench, eps/64 recovers 38 of the 46
#: pairs an exact min-distance test would kill vs 18 at eps/8).
BITMAP_REFINE = 64


def spatial_sort(coords: np.ndarray) -> np.ndarray:
    """Order (n, d) integer cell coordinates along the longest dimension
    of their bounding box, breaking ties lexicographically over the
    remaining dimensions (in ascending dimension order; stable), so
    equal-key runs stay spatially compact and per-block boxes come out
    tighter. A 0/1-cell set is returned unchanged; the pair count is
    invariant under any reordering (see the module docstring)."""
    if coords.shape[0] <= 1:
        return coords
    spans = coords.max(axis=0) - coords.min(axis=0)
    dim = int(np.argmax(spans))
    rest = [k for k in range(coords.shape[1]) if k != dim]
    # np.lexsort sorts by its LAST key first: primary = the longest
    # dimension, then the remaining dimensions most-significant first.
    keys = tuple(coords[:, k] for k in reversed(rest)) + (coords[:, dim],)
    return coords[np.lexsort(keys)]


def block_bounds(coords: np.ndarray, block: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Tight per-block bounding boxes of (n, d) coords split into
    ``block``-sized runs (last run possibly partial): (lo, hi) int64
    arrays of shape (ceil(n/block), d). Boxes come from real cells only,
    so downstream sentinel padding cannot loosen them."""
    if coords.shape[0] == 0:
        return (np.zeros((0, coords.shape[1]), np.int64),
                np.zeros((0, coords.shape[1]), np.int64))
    idx = np.arange(0, coords.shape[0], block)
    c = coords.astype(np.int64, copy=False)
    return (np.minimum.reduceat(c, idx, axis=0),
            np.maximum.reduceat(c, idx, axis=0))


def min_l1_box_dist(lo_a: np.ndarray, hi_a: np.ndarray,
                    lo_b: np.ndarray, hi_b: np.ndarray) -> np.ndarray:
    """(A, B) matrix of minimal L1 distances between two box sets given
    as (A, d)/(B, d) lo/hi corners: per dimension the gap between the
    closed intervals (zero when they overlap), summed over dimensions.
    Lower-bounds the L1 distance of any cell pair drawn from the two
    boxes — the soundness condition of the block prune."""
    gap = (np.maximum(lo_a[:, None, :] - hi_b[None, :, :], 0)
           + np.maximum(lo_b[None, :, :] - hi_a[:, None, :], 0))
    return gap.sum(axis=-1)


def build_block_pairs(a_sorted: np.ndarray, b_sorted: np.ndarray,
                      block: int, eps: int, same: bool
                      ) -> Tuple[np.ndarray, int]:
    """The live block-pair list for two spatially sorted coordinate
    sets: rows ``(block_i, block_j, 1)`` (int32) for every block pair
    whose minimal L1 box distance is ``<= eps``. Self-join mode keeps
    only ``i <= j`` pairs — every cell pair of an ``i > j`` block pair
    is eliminated by the kernel's ``i < j`` dedup mask anyway.

    Returns ``(pairs, dense_total)`` where ``dense_total`` is the number
    of block pairs the dense kernel would evaluate (the denominator of
    the ``block_pairs_evaluated / block_pairs_total`` counters)."""
    lo_a, hi_a = block_bounds(a_sorted, block)
    lo_b, hi_b = block_bounds(b_sorted, block)
    keep = min_l1_box_dist(lo_a, hi_a, lo_b, hi_b) <= eps
    if same:
        bi = np.arange(keep.shape[0])
        keep &= bi[:, None] <= bi[None, :]
    pi, pj = np.nonzero(keep)
    pairs = np.stack([pi, pj, np.ones_like(pi)], axis=1).astype(np.int32)
    return pairs, int(keep.size)


def padded_pair_len(n_pairs: int) -> int:
    """Bucket granularity for pair lists: the next power of two (at
    least 8), so batched dispatch sees a handful of pair-list shapes
    instead of one per distinct live-pair count."""
    n = max(int(n_pairs), 1)
    return max(8, 1 << (n - 1).bit_length())


def bitmap_scale(eps: int) -> int:
    """The fine-level quantization step of the occupancy bitmaps for an
    eps threshold: ``~eps / BITMAP_REFINE`` (at least 1). At small eps
    (``< BITMAP_REFINE``, including the ``eps = 0`` edge) the step is 1
    and the fine level holds the exact cell coordinates — the dilation
    test degenerates to an exact point membership test."""
    return max(1, -(-int(eps) // BITMAP_REFINE))


def build_bitmaps(coords: np.ndarray, block: int, scale: int
                  ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Hierarchical occupancy bitmap sidecars of (n, d) sorted coords
    split into ``block``-sized runs: per block, the deduplicated set of
    quantized grid cells its real cells occupy, as a
    ``(fine, coarse)`` pair of (m, d)/(mc, d) int64 arrays — fine cells
    on a ``scale``-step grid, coarse cells ``BITMAP_COARSE``× wider
    (``fine // BITMAP_COARSE``; floor division keeps negative
    coordinates on the same grid). Stored sparse — the occupied-cell
    set IS the bitmap, just run-length-free — because a kernel block
    holds at most 128 cells, so the set is tiny regardless of the grid's
    nominal extent."""
    c = coords.astype(np.int64, copy=False)
    fine_all = np.floor_divide(c, scale)
    out: List[Tuple[np.ndarray, np.ndarray]] = []
    for i0 in range(0, c.shape[0], block):
        fine = np.unique(fine_all[i0:i0 + block], axis=0)
        coarse = np.unique(np.floor_divide(fine, BITMAP_COARSE), axis=0)
        out.append((fine, coarse))
    return out


def min_l1_cell_dist(cells_a: np.ndarray, cells_b: np.ndarray,
                     step: int) -> int:
    """Minimal L1 distance provable between any two real cells drawn
    from two occupied quantized-cell sets on a ``step``-wide grid.
    Quantized cell ``k`` covers the closed coordinate interval
    ``[k*step, (k+1)*step - 1]`` per dimension, so two distinct cells
    ``|dc|`` apart contribute a gap of ``(|dc| - 1)*step + 1`` (zero
    when equal) — summed over dimensions and minimized over all cell
    pairs. Lower-bounds the true distance of every real cell pair
    (exact at ``step = 1``); the soundness condition of the bitmap
    prune, property-tested in ``test_hypothesis_properties``."""
    d = np.abs(cells_a[:, None, :] - cells_b[None, :, :])
    gap = np.where(d > 0, (d - 1) * int(step) + 1, 0).sum(axis=-1)
    return int(gap.min())


def refine_block_pairs(pairs: np.ndarray,
                       bm_a: List[Tuple[np.ndarray, np.ndarray]],
                       bm_b: List[Tuple[np.ndarray, np.ndarray]],
                       eps: int, scale: int
                       ) -> Tuple[np.ndarray, int]:
    """Cell-exact refinement of a bbox-surviving (P, 3) block-pair list
    against the two sides' hierarchical bitmaps: a pair is killed when
    its blocks' occupied cells are provably more than eps apart —
    coarse level first (few cells, ``BITMAP_COARSE * scale``-wide, so
    most far pairs die on the cheap summary), fine level only for
    coarse survivors. The sparse min-distance test is equivalent to
    dilating one side's bitmap by eps and intersecting with the other
    (a cell pair within eps exists iff the dilated sets intersect), but
    runs directly on the occupied-cell sets — at most 128×128
    comparisons per pair. Returns ``(refined_pairs, killed)``; sound by
    :func:`min_l1_cell_dist`, so refined lists preserve exact match
    counts."""
    if pairs.shape[0] == 0:
        return pairs, 0
    coarse_step = int(scale) * BITMAP_COARSE
    keep = np.ones(pairs.shape[0], dtype=bool)
    for r in range(pairs.shape[0]):
        fa, ca = bm_a[int(pairs[r, 0])]
        fb, cb = bm_b[int(pairs[r, 1])]
        if min_l1_cell_dist(ca, cb, coarse_step) > eps:
            keep[r] = False
        elif min_l1_cell_dist(fa, fb, int(scale)) > eps:
            keep[r] = False
    refined = pairs[keep]
    return refined, int(pairs.shape[0] - refined.shape[0])


def pad_pairs(pairs: np.ndarray, to_len: int) -> np.ndarray:
    """Pad a (P, 3) pair list to ``to_len`` rows with invalid
    ``(0, 0, 0)`` entries — the kernel multiplies their counts away.
    An oversize pair list raises ``ValueError`` (a real error, not an
    ``assert``: silent truncation here would drop matches, and asserts
    vanish under ``python -O``)."""
    if pairs.shape[0] == to_len:
        return pairs
    if pairs.shape[0] > to_len:
        raise ValueError(
            f"pair list of shape {pairs.shape} does not fit the padded "
            f"length {to_len}; pad_pairs only grows pair lists")
    out = np.zeros((to_len, 3), np.int32)
    out[:pairs.shape[0]] = pairs
    return out
