"""Host-side block pruning for the block-sparse simjoin kernel.

Pure-numpy preprocessing that turns a coordinate set into the inputs the
``simjoin_pruned_block_counts`` kernel consumes:

  1. ``spatial_sort`` orders cells along the longest dimension of their
     bounding box (lexicographic tie-break over the remaining
     dimensions) so consecutive 128-wide kernel blocks are spatially
     coherent (tight per-block boxes);
  2. ``block_bounds`` computes those per-block bounding boxes (real
     cells only — sentinel padding never enters a box);
  3. ``build_block_pairs`` keeps only the block pairs whose minimal L1
     box distance is ``<= eps`` — a sound prune because the minimal box
     distance lower-bounds the distance of every cell pair inside the
     two blocks (property-tested in ``test_hypothesis_properties``);
  4. ``pad_pairs``/``padded_pair_len`` pad surviving pair lists to a
     power-of-two bucket length so shape-bucketed batch dispatch does
     not retrace per distinct pair count.

The count is invariant under the reordering: the join is a sum over
unordered cell pairs, and self-join dedup compares *positions in the
sorted order*, which still counts each unordered pair exactly once.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def spatial_sort(coords: np.ndarray) -> np.ndarray:
    """Order (n, d) integer cell coordinates along the longest dimension
    of their bounding box, breaking ties lexicographically over the
    remaining dimensions (in ascending dimension order; stable), so
    equal-key runs stay spatially compact and per-block boxes come out
    tighter. A 0/1-cell set is returned unchanged; the pair count is
    invariant under any reordering (see the module docstring)."""
    if coords.shape[0] <= 1:
        return coords
    spans = coords.max(axis=0) - coords.min(axis=0)
    dim = int(np.argmax(spans))
    rest = [k for k in range(coords.shape[1]) if k != dim]
    # np.lexsort sorts by its LAST key first: primary = the longest
    # dimension, then the remaining dimensions most-significant first.
    keys = tuple(coords[:, k] for k in reversed(rest)) + (coords[:, dim],)
    return coords[np.lexsort(keys)]


def block_bounds(coords: np.ndarray, block: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Tight per-block bounding boxes of (n, d) coords split into
    ``block``-sized runs (last run possibly partial): (lo, hi) int64
    arrays of shape (ceil(n/block), d). Boxes come from real cells only,
    so downstream sentinel padding cannot loosen them."""
    if coords.shape[0] == 0:
        return (np.zeros((0, coords.shape[1]), np.int64),
                np.zeros((0, coords.shape[1]), np.int64))
    idx = np.arange(0, coords.shape[0], block)
    c = coords.astype(np.int64, copy=False)
    return (np.minimum.reduceat(c, idx, axis=0),
            np.maximum.reduceat(c, idx, axis=0))


def min_l1_box_dist(lo_a: np.ndarray, hi_a: np.ndarray,
                    lo_b: np.ndarray, hi_b: np.ndarray) -> np.ndarray:
    """(A, B) matrix of minimal L1 distances between two box sets given
    as (A, d)/(B, d) lo/hi corners: per dimension the gap between the
    closed intervals (zero when they overlap), summed over dimensions.
    Lower-bounds the L1 distance of any cell pair drawn from the two
    boxes — the soundness condition of the block prune."""
    gap = (np.maximum(lo_a[:, None, :] - hi_b[None, :, :], 0)
           + np.maximum(lo_b[None, :, :] - hi_a[:, None, :], 0))
    return gap.sum(axis=-1)


def build_block_pairs(a_sorted: np.ndarray, b_sorted: np.ndarray,
                      block: int, eps: int, same: bool
                      ) -> Tuple[np.ndarray, int]:
    """The live block-pair list for two spatially sorted coordinate
    sets: rows ``(block_i, block_j, 1)`` (int32) for every block pair
    whose minimal L1 box distance is ``<= eps``. Self-join mode keeps
    only ``i <= j`` pairs — every cell pair of an ``i > j`` block pair
    is eliminated by the kernel's ``i < j`` dedup mask anyway.

    Returns ``(pairs, dense_total)`` where ``dense_total`` is the number
    of block pairs the dense kernel would evaluate (the denominator of
    the ``block_pairs_evaluated / block_pairs_total`` counters)."""
    lo_a, hi_a = block_bounds(a_sorted, block)
    lo_b, hi_b = block_bounds(b_sorted, block)
    keep = min_l1_box_dist(lo_a, hi_a, lo_b, hi_b) <= eps
    if same:
        bi = np.arange(keep.shape[0])
        keep &= bi[:, None] <= bi[None, :]
    pi, pj = np.nonzero(keep)
    pairs = np.stack([pi, pj, np.ones_like(pi)], axis=1).astype(np.int32)
    return pairs, int(keep.size)


def padded_pair_len(n_pairs: int) -> int:
    """Bucket granularity for pair lists: the next power of two (at
    least 8), so batched dispatch sees a handful of pair-list shapes
    instead of one per distinct live-pair count."""
    n = max(int(n_pairs), 1)
    return max(8, 1 << (n - 1).bit_length())


def pad_pairs(pairs: np.ndarray, to_len: int) -> np.ndarray:
    """Pad a (P, 3) pair list to ``to_len`` rows with invalid
    ``(0, 0, 0)`` entries — the kernel multiplies their counts away.
    An oversize pair list raises ``ValueError`` (a real error, not an
    ``assert``: silent truncation here would drop matches, and asserts
    vanish under ``python -O``)."""
    if pairs.shape[0] == to_len:
        return pairs
    if pairs.shape[0] > to_len:
        raise ValueError(
            f"pair list of shape {pairs.shape} does not fit the padded "
            f"length {to_len}; pad_pairs only grows pair lists")
    out = np.zeros((to_len, 3), np.int32)
    out[:pairs.shape[0]] = pairs
    return out
