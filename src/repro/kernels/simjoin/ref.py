"""Pure-jnp oracle for the simjoin kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def count_pairs_ref(a: jax.Array, b: jax.Array, eps: int,
                    same: bool) -> jax.Array:
    """a: (Na, d), b: (Nb, d) integer coords. Number of (x, y) pairs with
    L1(x, y) <= eps; in self-join mode each unordered pair counts once and
    identical indices are excluded."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return jnp.zeros((), jnp.int32)
    dist = jnp.abs(a[:, None, :].astype(jnp.int64)
                   - b[None, :, :].astype(jnp.int64)).sum(-1)
    hit = dist <= eps
    if same:
        i = jnp.arange(a.shape[0])[:, None]
        j = jnp.arange(b.shape[0])[None, :]
        hit = jnp.logical_and(hit, i < j)
    return hit.sum().astype(jnp.int32)
