"""Blocked L1 similarity-join kernel (pl.pallas_call + BlockSpec).

The paper's workload joins sparse-array cells by an L1(eps) predicate
(§2.2). On CPU that is pointer-chasing over cell lists; the TPU-native
formulation tiles the two coordinate sets into 128-aligned VMEM blocks laid
out coordinate-major ((d, N) so the lane dimension is the 128-wide cell
block) and evaluates the |a_i - b_j| <= eps predicate as dense (128, 128)
VPU blocks, emitting per-block-pair match counts.

Self-join mode masks the upper triangle (i < j) using global indices so each
unordered pair counts once. Padded cells use +/- sentinel coordinates whose
distance always exceeds eps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128
SENTINEL = 1 << 20


def _simjoin_kernel(a_ref, b_ref, out_ref, *, eps: int, same: bool,
                    ndim: int):
    """a_ref: (d, BLOCK) int32; b_ref: (d, BLOCK) int32; out: (1, 1) int32."""
    dist = jnp.zeros((BLOCK, BLOCK), jnp.int32)
    for k in range(ndim):
        ak = a_ref[k, :]                       # (BLOCK,)
        bk = b_ref[k, :]
        dist = dist + jnp.abs(ak[:, None] - bk[None, :])
    hit = dist <= eps
    if same:
        i = pl.program_id(0) * BLOCK + jax.lax.broadcasted_iota(
            jnp.int32, (BLOCK, BLOCK), 0)
        j = pl.program_id(1) * BLOCK + jax.lax.broadcasted_iota(
            jnp.int32, (BLOCK, BLOCK), 1)
        hit = jnp.logical_and(hit, i < j)
    out_ref[0, 0] = jnp.sum(hit.astype(jnp.int32))


def simjoin_block_counts(a: jax.Array, b: jax.Array, eps: int, same: bool,
                         interpret: bool = True) -> jax.Array:
    """a: (d, Na), b: (d, Nb) int32, Na/Nb multiples of BLOCK (padded with
    sentinels by ops.py). Returns (Na/BLOCK, Nb/BLOCK) int32 match counts."""
    d, na = a.shape
    _, nb = b.shape
    assert na % BLOCK == 0 and nb % BLOCK == 0, (na, nb)
    grid = (na // BLOCK, nb // BLOCK)
    kernel = functools.partial(_simjoin_kernel, eps=eps, same=same, ndim=d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, BLOCK), lambda i, j: (0, i)),
            pl.BlockSpec((d, BLOCK), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(grid, jnp.int32),
        interpret=interpret,
    )(a, b)
