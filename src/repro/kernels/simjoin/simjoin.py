"""Blocked L1 similarity-join kernel (pl.pallas_call + BlockSpec).

The paper's workload joins sparse-array cells by an L1(eps) predicate
(§2.2). On CPU that is pointer-chasing over cell lists; the TPU-native
formulation tiles the two coordinate sets into 128-aligned VMEM blocks laid
out coordinate-major ((d, N) so the lane dimension is the 128-wide cell
block) and evaluates the |a_i - b_j| <= eps predicate as dense (128, 128)
VPU blocks, emitting per-block-pair match counts.

Self-join mode masks the upper triangle (i < j) using global indices so each
unordered pair counts once. Padded cells use +/- sentinel coordinates whose
distance always exceeds eps.

Two kernel variants share the block-pair body:

  * ``simjoin_block_counts`` — the dense grid: every ``(Na/128, Nb/128)``
    block pair is evaluated (kept for parity testing and as the fallback
    when coordinates are not spatially coherent);
  * ``simjoin_pruned_block_counts`` — the block-sparse grid: the host
    sorts each coordinate set spatially, computes per-block bounding
    boxes, keeps only block pairs whose minimal L1 box distance is
    ``<= eps`` (``repro.kernels.simjoin.prune``), and scalar-prefetches
    the surviving ``(i, j)`` pair list (the in-repo ``paged_attention``
    ``PrefetchScalarGridSpec`` pattern) so the grid iterates ONLY live
    pairs — O(live pairs) instead of O(all block pairs) work. The
    cell-exact bitmap stage (``prune.refine_block_pairs``) rides this
    same scalar-prefetch path: it only shrinks the host-built pair
    list further, so the kernel is untouched and iterates strictly
    fewer live pairs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 128
SENTINEL = 1 << 20


def _block_pair_count(a_ref, b_ref, i_block, j_block, *, eps: int,
                      same: bool, ndim: int):
    """Shared block-pair body: L1 matches between one (d, BLOCK) pair,
    with self-join dedup (global ``i < j``) reconstructed from the
    pair's block indices — ``program_id`` on the dense grid, the
    scalar-prefetched pair list on the block-sparse grid."""
    dist = jnp.zeros((BLOCK, BLOCK), jnp.int32)
    for k in range(ndim):
        ak = a_ref[k, :]                       # (BLOCK,)
        bk = b_ref[k, :]
        dist = dist + jnp.abs(ak[:, None] - bk[None, :])
    hit = dist <= eps
    if same:
        i = i_block * BLOCK + jax.lax.broadcasted_iota(
            jnp.int32, (BLOCK, BLOCK), 0)
        j = j_block * BLOCK + jax.lax.broadcasted_iota(
            jnp.int32, (BLOCK, BLOCK), 1)
        hit = jnp.logical_and(hit, i < j)
    return jnp.sum(hit.astype(jnp.int32))


def _simjoin_kernel(a_ref, b_ref, out_ref, *, eps: int, same: bool,
                    ndim: int):
    """a_ref: (d, BLOCK) int32; b_ref: (d, BLOCK) int32; out: (1, 1) int32."""
    out_ref[0, 0] = _block_pair_count(
        a_ref, b_ref, pl.program_id(0), pl.program_id(1), eps=eps,
        same=same, ndim=ndim)


def simjoin_block_counts(a: jax.Array, b: jax.Array, eps: int, same: bool,
                         interpret: bool = True) -> jax.Array:
    """a: (d, Na), b: (d, Nb) int32, Na/Nb multiples of BLOCK (padded with
    sentinels by ops.py). Returns (Na/BLOCK, Nb/BLOCK) int32 match counts."""
    d, na = a.shape
    _, nb = b.shape
    assert na % BLOCK == 0 and nb % BLOCK == 0, (na, nb)
    grid = (na // BLOCK, nb // BLOCK)
    kernel = functools.partial(_simjoin_kernel, eps=eps, same=same, ndim=d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, BLOCK), lambda i, j: (0, i)),
            pl.BlockSpec((d, BLOCK), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(grid, jnp.int32),
        interpret=interpret,
    )(a, b)


def _simjoin_pruned_kernel(pairs_ref, a_ref, b_ref, out_ref, *, eps: int,
                           same: bool, ndim: int):
    """pairs_ref: (P, 3) int32 scalar-prefetch rows ``(block_i, block_j,
    valid)``; a_ref/b_ref: the (d, BLOCK) blocks the pair list selected;
    out: (1, 1) int32. Rows padded onto a bucket's pair list carry
    ``valid == 0`` and contribute nothing (their loaded blocks are
    arbitrary but the count is multiplied away)."""
    p = pl.program_id(0)
    out_ref[0, 0] = _block_pair_count(
        a_ref, b_ref, pairs_ref[p, 0], pairs_ref[p, 1], eps=eps,
        same=same, ndim=ndim) * pairs_ref[p, 2]


def simjoin_pruned_block_counts(a: jax.Array, b: jax.Array,
                                pairs: jax.Array, eps: int, same: bool,
                                interpret: bool = True) -> jax.Array:
    """Block-sparse simjoin: evaluate ONLY the scalar-prefetched block
    pairs. ``a``: (d, Na), ``b``: (d, Nb) int32 coordinate-major sets,
    Na/Nb multiples of BLOCK, spatially sorted and sentinel-padded on
    host (``prune.spatial_sort`` + ``ops.pad_cm_np``); ``pairs``: (P, 3)
    int32 ``(block_i, block_j, valid)`` rows from
    ``prune.build_block_pairs``. Returns (P, 1) int32 per-pair match
    counts (zero for ``valid == 0`` padding rows)."""
    d, na = a.shape
    _, nb = b.shape
    assert na % BLOCK == 0 and nb % BLOCK == 0, (na, nb)
    n_pairs = pairs.shape[0]
    assert n_pairs > 0, "empty pair list: skip the kernel call entirely"
    kernel = functools.partial(_simjoin_pruned_kernel, eps=eps, same=same,
                               ndim=d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((d, BLOCK), lambda p, pr: (0, pr[p, 0])),
            pl.BlockSpec((d, BLOCK), lambda p, pr: (0, pr[p, 1])),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda p, pr: (p, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pairs, 1), jnp.int32),
        interpret=interpret,
    )(pairs, a, b)
