"""Launchers: mesh, multi-pod dry-run, HLO/roofline analysis, train/serve drivers."""
