import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything below may import jax.

import argparse            # noqa: E402
import json                # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402
from typing import Optional                           # noqa: E402

import jax                 # noqa: E402
import jax.numpy as jnp    # noqa: E402

from repro.configs import (SHAPES, SHAPES_BY_NAME, get, list_archs,
                           shape_applicable)          # noqa: E402
from repro.launch import input_specs as ispec         # noqa: E402
from repro.launch.hlo_analysis import HloAnalyzer     # noqa: E402
from repro.launch.mesh import make_production_mesh    # noqa: E402
from repro.launch.roofline import build_report, format_row  # noqa: E402
from repro.serve.serve_step import (make_prefill_step,
                                    make_serve_step)  # noqa: E402
from repro.sharding.partition import make_policy      # noqa: E402
from repro.train.optimizer import OptimizerConfig     # noqa: E402
from repro.train.train_step import make_train_step    # noqa: E402

"""Multi-pod dry-run driver (deliverable e).

For every runnable (arch x shape) cell and each production mesh, lower +
compile the step function against ShapeDtypeStruct stand-ins (no device
allocation), print ``memory_analysis()`` / ``cost_analysis()``, and derive
the three roofline terms from the loop-aware HLO analyzer. Failures here are
bugs in the sharding config — the run exits nonzero if any cell fails.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results.jsonl
"""


def opt_config_for(cfg) -> OptimizerConfig:
    state_dtype = jnp.bfloat16 if cfg.param_count() > 5e10 else None
    return OptimizerConfig(state_dtype=state_dtype)


def attention_impl_for(seq_len: int) -> str:
    return "naive" if seq_len <= 1024 else "blockwise"


def lower_cell(cfg, shape, mesh, *, seq_axes=None, n_microbatches: int = 1,
               fsdp_threshold: float = 5e9):
    """Build (jitted_fn, args) for one cell and lower under ``mesh``."""
    policy = make_policy(cfg, mesh, fsdp_threshold)
    if shape.kind == "train":
        step = make_train_step(cfg, opt_config_for(cfg),
                               n_microbatches=n_microbatches,
                               attention_impl=attention_impl_for(shape.seq_len),
                               remat=True)
        params = ispec.abstract_params(cfg, mesh, policy)
        opt = ispec.abstract_opt_state(cfg, mesh, policy, opt_config_for(cfg))
        batch = ispec.abstract_batch(cfg, shape, mesh, policy)
        with jax.set_mesh(mesh):
            return jax.jit(step).lower(params, opt, batch)
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, attention_impl_for(shape.seq_len))
        params = ispec.abstract_params(cfg, mesh, policy)
        batch = ispec.abstract_batch(cfg, shape, mesh, policy)
        with jax.set_mesh(mesh):
            return jax.jit(step).lower(
                params, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"))
    if shape.kind == "decode":
        step = make_serve_step(cfg)
        params = ispec.abstract_params(cfg, mesh, policy)
        dec = ispec.abstract_decode_inputs(cfg, shape, mesh, policy,
                                           seq_axes=seq_axes)
        with jax.set_mesh(mesh):
            return jax.jit(step).lower(params, dec["tokens"], dec["state"],
                                       dec["pos"])
    raise ValueError(shape.kind)


# Sequence parallelism winners, measured per arch on train_4k (§Perf D):
# dense attention stacks gain 1.22-4.10x; MoE archs lose ~2x (the dispatch
# re-gathers the full sequence per layer) and nemotron's 18k-wide
# activations make the per-layer gathers dominate. Measurement-driven, not
# a heuristic.
SP_WINNERS = frozenset({"qwen1.5-0.5b", "olmo-1b", "llama3.2-3b",
                        "hubert-xlarge", "internvl2-2b"})


def apply_variant(cfg, variant: str):
    """'baseline' reverts the §Perf hillclimb changes (paper-faithful
    framework defaults pre-optimization); 'optimized' keeps them."""
    import dataclasses
    if variant == "baseline":
        return dataclasses.replace(cfg, mlstm_impl="sequential",
                                   moe_dispatch="einsum",
                                   kv_update="onehot")
    if cfg.name in SP_WINNERS:
        cfg = dataclasses.replace(cfg, seq_parallel=True)
    return cfg


def run_cell(arch: str, shape_name: str, mesh_name: str,
             dump_hlo: Optional[str] = None, verbose: bool = True,
             variant: str = "optimized") -> dict:
    cfg = apply_variant(get(arch), variant)
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": reason}
    multi = mesh_name == "multipod"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.size
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    if dump_hlo:
        os.makedirs(dump_hlo, exist_ok=True)
        fn = os.path.join(dump_hlo, f"{arch}_{shape_name}_{mesh_name}.hlo")
        with open(fn, "w") as f:
            f.write(hlo_text)
    cost = HloAnalyzer(hlo_text).module_cost()
    hbm = None
    try:
        hbm = float(ma.argument_size_in_bytes + ma.temp_size_in_bytes +
                    ma.output_size_in_bytes)
    except AttributeError:
        pass
    report = build_report(arch, shape, mesh_name, chips, cost, cfg,
                          hbm_per_chip=hbm)
    row = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips, "variant": variant,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                ma, "generated_code_size_in_bytes", None),
        },
        "xla_cost_analysis": {"flops": ca.get("flops"),
                              "bytes": ca.get("bytes accessed")},
        "hlo_flops_per_chip": report.flops_per_chip,
        "hlo_bytes_per_chip": report.bytes_per_chip,
        "coll_bytes_per_chip": report.coll_bytes_per_chip,
        "coll_by_kind": report.coll_by_kind,
        "compute_s": report.compute_s,
        "memory_s": report.memory_s,
        "collective_s": report.collective_s,
        "serial_s": report.serial_s,
        "seq_iters": report.seq_iters,
        "bottleneck": report.bottleneck,
        "model_flops": report.model_flops,
        "useful_ratio": report.useful_ratio,
        "roofline_fraction": report.roofline_fraction,
        "hbm_per_chip": hbm,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print("  memory_analysis:", row["memory_analysis"])
        print("  cost_analysis:  ", row["xla_cost_analysis"])
        print("  " + format_row(report))
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs(), default=None)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES], default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--out", default=None, help="JSONL output path")
    ap.add_argument("--dump-hlo", default=None,
                    help="directory to dump optimized HLO per cell")
    ap.add_argument("--variant", choices=["baseline", "optimized"],
                    default="optimized")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    rows = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                try:
                    row = run_cell(arch, shape, mesh_name,
                                   dump_hlo=args.dump_hlo,
                                   variant=args.variant)
                except Exception as e:   # a cell failure is a sharding bug
                    traceback.print_exc()
                    row = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "fail", "error": repr(e)}
                    failures += 1
                rows.append(row)
                if row["status"] == "skip":
                    print(f"[{arch} x {shape} x {mesh_name}] SKIP: "
                          f"{row['reason']}")
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(row) + "\n")
    ok = sum(1 for r in rows if r["status"] == "ok")
    sk = sum(1 for r in rows if r["status"] == "skip")
    print(f"\ndry-run: {ok} ok, {sk} skipped, {failures} failed")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
