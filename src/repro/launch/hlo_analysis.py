"""HLO cost analysis that is *loop-aware* and *collective-aware*.

``compiled.cost_analysis()`` counts each ``while`` body exactly once, which
under-counts scan-over-layers models by the trip count (verified empirically;
see EXPERIMENTS.md §Dry-run methodology). This module re-derives
per-device FLOPs, HBM bytes, and collective bytes by parsing the optimized
HLO text:

  * computations are parsed into instruction lists with result shapes;
  * ``while`` trip counts are recovered from the loop-condition comparison
    constant (jax scans lower to ``i < N`` with ``i0=0, i+=1``);
  * ``fusion`` flops come from the fused computation, but its HBM bytes are
    the fusion's operands+result (internals live in registers/VMEM);
  * ``dot`` flops = 2 * prod(result) * prod(contracted dims);
  * collective bytes sum operand sizes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (incl. -start forms),
    multiplied by enclosing trip counts; all-reduce counts 2x (ring =
    reduce-scatter + all-gather).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ARITH_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "floor",
    "ceil", "sign", "compare", "select", "and", "or", "xor", "not",
    "convert", "exponential-minus-one", "log-plus-one", "logistic",
    "cosine", "sine", "atan2", "remainder", "clamp", "round-nearest-even",
    "round-nearest-afz", "erf", "cbrt",
}


@dataclasses.dataclass
class ShapeInfo:
    elements: int
    nbytes: int


def parse_shape(text: str) -> ShapeInfo:
    """Parse 'f32[128,256]{1,0}' or '(s32[], f32[2,3])' into totals."""
    elements = 0
    nbytes = 0
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elements += n
        nbytes += n * _DTYPE_BYTES[dt]
    return ShapeInfo(elements, nbytes)


def _shape_dims(text: str) -> List[int]:
    m = re.search(r"[a-z0-9]+\[([0-9,]*)\]", text)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


@dataclasses.dataclass
class Instruction:
    name: str
    op: str
    result_type: str
    operands: List[str]
    attrs: str
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    shapes: Dict[str, str]          # instr/param name -> result type text


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?))\s*([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], {})
                if line.strip().startswith("ENTRY"):
                    entry = m.group(1)
            continue
        stripped = line.strip()
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, op, rest = m.groups()
        # Operand names: %foo tokens inside the first (...) group.
        depth = 1
        args_text = []
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args_text.append(ch)
        args = "".join(args_text)
        attrs = rest[len(args) + 1:]
        operands = re.findall(r"%([\w.\-]+)", args)
        instr = Instruction(name, op, rtype, operands, attrs, line)
        cur.instructions.append(instr)
        cur.shapes[name] = rtype
    # parameters: declared like "%param_0 = f32[...] parameter(0)" — covered.
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    # Serialization: total loop iterations on the critical path (each is a
    # dependent dispatch on real hardware — a latency floor a bytes/flops
    # roofline cannot see; sequential recurrences are bound by this).
    seq_iters: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_ops: List[Tuple[str, str, float, float]] = dataclasses.field(
        default_factory=list)   # (kind, shape_text, bytes, trip_mult)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.seq_iters += other.seq_iters * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult
        for kind, st, b, m in other.coll_ops:
            self.coll_ops.append((kind, st, b, m * mult))


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: Dict[str, Cost] = {}

    # ------------------------------------------------------------- helpers

    def _operand_type(self, comp: Computation, name: str) -> str:
        return comp.shapes.get(name, "")

    def _trip_count(self, cond_name: str) -> int:
        """Recover N from the loop condition 'i < N'."""
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        consts: Dict[str, int] = {}
        for ins in comp.instructions:
            if ins.op == "constant":
                m = re.search(r"constant\((-?\d+)\)", ins.raw)
                if m:
                    consts[ins.name] = int(m.group(1))
        # Direct compare in the condition.
        for ins in comp.instructions:
            if ins.op == "compare":
                for o in ins.operands:
                    if o in consts:
                        n = consts[o]
                        return n + 1 if "direction=LE" in ins.attrs else n
        # Compare wrapped in a fusion: constants are fusion operands.
        for ins in comp.instructions:
            if ins.op == "fusion":
                vals = [consts[o] for o in ins.operands if o in consts]
                if vals:
                    called = re.search(r"calls=%([\w.\-]+)", ins.attrs)
                    le = False
                    if called and called.group(1) in self.comps:
                        inner = self.comps[called.group(1)]
                        le = any("direction=LE" in i.attrs
                                 for i in inner.instructions
                                 if i.op == "compare")
                    n = max(vals)
                    return n + 1 if le else n
        if consts:
            return max(consts.values())
        return 1

    def _dot_flops(self, comp: Computation, ins: Instruction) -> float:
        res = parse_shape(ins.result_type).elements
        lhs_type = self._operand_type(comp, ins.operands[0]) \
            if ins.operands else ""
        lhs_dims = _shape_dims(lhs_type)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs) or \
            re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.raw)
        k = 1
        if m and m.group(1) and lhs_dims:
            for d in m.group(1).split(","):
                di = int(d)
                if di < len(lhs_dims):
                    k *= lhs_dims[di]
        return 2.0 * res * k

    # ----------------------------------------------------------- main walk

    def computation_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        cost = Cost()
        if comp is None:
            self._memo[name] = cost
            return cost
        self._memo[name] = cost      # break cycles defensively
        for ins in comp.instructions:
            self._instr_cost(comp, ins, cost)
        return cost

    def _instr_cost(self, comp: Computation, ins: Instruction,
                    cost: Cost) -> None:
        op = ins.op
        res = parse_shape(ins.result_type)
        base = op.replace("-start", "")
        if base in _COLLECTIVES:
            opbytes = sum(parse_shape(self._operand_type(comp, o)).nbytes
                          for o in ins.operands)
            opbytes = opbytes or res.nbytes
            link = 2.0 * opbytes if base == "all-reduce" else float(opbytes)
            cost.coll_bytes += link
            cost.coll_by_kind[base] = cost.coll_by_kind.get(base, 0.) + link
            cost.coll_ops.append((base, ins.result_type.split("{")[0],
                                  link, 1.0))
            cost.bytes += opbytes + res.nbytes
            return
        if op == "while":
            body = re.search(r"body=%([\w.\-]+)", ins.attrs)
            cond = re.search(r"condition=%([\w.\-]+)", ins.attrs)
            trips = max(self._trip_count(cond.group(1)) if cond else 1, 1)
            if body:
                cost.add(self.computation_cost(body.group(1)), mult=trips)
                cost.seq_iters += trips
                # Loop-invariant operands (carried through unchanged, e.g.
                # recurrent weight matrices) stay VMEM/cache-resident on
                # TPU: discount their HBM traffic to a single pass.
                inv = self._invariant_body_bytes(body.group(1))
                cost.bytes -= inv * (trips - 1)
            return
        if op == "fusion":
            called = re.search(r"calls=%([\w.\-]+)", ins.attrs)
            if called:
                inner = self.computation_cost(called.group(1))
                cost.flops += inner.flops
            sizes = [parse_shape(self._operand_type(comp, o)).nbytes
                     for o in ins.operands]
            if ("dynamic-update-slice" in ins.name or
                    "scatter" in ins.name or "dynamic_update_slice"
                    in ins.name):
                # In-place update fusions alias the big target buffer:
                # traffic is the update region (read+write), not the buffer.
                big = max(sizes) if sizes else 0
                cost.bytes += 2 * (sum(sizes) - big)
            else:
                cost.bytes += sum(sizes) + res.nbytes
            return
        if op in ("call", "async-start"):
            called = re.search(r"(?:calls|called_computation)=%([\w.\-]+)",
                               ins.attrs)
            if called:
                cost.add(self.computation_cost(called.group(1)))
            return
        if op == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                  ins.attrs)
            if branches:
                names = re.findall(r"%([\w.\-]+)", branches[0])
                costs = [self.computation_cost(n) for n in names]
                if costs:
                    best = max(costs, key=lambda c: c.flops)
                    cost.add(best)
            return
        if op == "dot":
            cost.flops += self._dot_flops(comp, ins)
            opbytes = sum(parse_shape(self._operand_type(comp, o)).nbytes
                          for o in ins.operands)
            cost.bytes += opbytes + res.nbytes
            return
        if op == "convolution":
            window = re.findall(r"size=([0-9x]+)", ins.attrs)
            wprod = 1
            if window:
                for d in window[0].split("x"):
                    wprod *= int(d)
            cost.flops += 2.0 * res.elements * wprod
            cost.bytes += res.nbytes * 2
            return
        if op in ("reduce", "reduce-window"):
            opbytes = sum(parse_shape(self._operand_type(comp, o)).nbytes
                          for o in ins.operands)
            opelems = sum(parse_shape(self._operand_type(comp, o)).elements
                          for o in ins.operands)
            cost.flops += float(opelems)
            cost.bytes += opbytes + res.nbytes
            return
        if op in ("custom-call", "custom_call"):
            opbytes = sum(parse_shape(self._operand_type(comp, o)).nbytes
                          for o in ins.operands)
            cost.bytes += opbytes + res.nbytes
            cost.flops += float(res.elements)
            return
        if op in _ARITH_OPS:
            cost.flops += float(res.elements)
            # Inside fused computations bytes don't hit HBM; top-level
            # arithmetic is rare post-fusion, count conservatively.
            cost.bytes += res.nbytes
            return
        if op in ("dynamic-update-slice", "scatter"):
            sizes = [parse_shape(self._operand_type(comp, o)).nbytes
                     for o in ins.operands]
            big = max(sizes) if sizes else 0
            cost.bytes += 2 * (sum(sizes) - big)   # aliased in-place update
            return
        if op in ("copy", "copy-start", "transpose", "reshape", "broadcast",
                  "slice", "dynamic-slice", "concatenate",
                  "gather", "pad", "reverse", "iota", "sort"):
            opbytes = sum(parse_shape(self._operand_type(comp, o)).nbytes
                          for o in ins.operands)
            cost.bytes += opbytes + res.nbytes
            return
        # parameter/constant/tuple/get-tuple-element/bitcast/...: free.

    def _invariant_body_bytes(self, body_name: str) -> float:
        """Per-iteration bytes read from loop-invariant carries: tuple slots
        whose ROOT output is exactly the input get-tuple-element (weights
        threaded through a scan), counted once per consuming instruction."""
        comp = self.comps.get(body_name)
        if comp is None:
            return 0.0
        gte_by_name: Dict[str, int] = {}
        for ins in comp.instructions:
            if ins.op == "get-tuple-element":
                m = re.search(r"index=(\d+)", ins.attrs) or \
                    re.search(r"index=(\d+)", ins.raw)
                if m:
                    gte_by_name[ins.name] = int(m.group(1))
        root = comp.instructions[-1] if comp.instructions else None
        if root is None or root.op != "tuple":
            return 0.0
        passthrough: set = set()
        for slot, operand in enumerate(root.operands):
            if gte_by_name.get(operand) == slot:
                passthrough.add(operand)
        if not passthrough:
            return 0.0
        total = 0.0
        for ins in comp.instructions:
            if ins.op in ("tuple", "get-tuple-element"):
                continue
            for o in ins.operands:
                if o in passthrough:
                    total += parse_shape(comp.shapes.get(o, "")).nbytes
        return total

    def module_cost(self) -> Cost:
        if self.entry is None:
            # Fall back: the computation with the most instructions.
            name = max(self.comps, key=lambda n: len(self.comps[n].instructions))
            return self.computation_cost(name)
        return self.computation_cost(self.entry)


def analyze_collectives(text: str) -> Dict[str, float]:
    """Quick summary used by tests: collective kind -> modeled link bytes."""
    cost = HloAnalyzer(text).module_cost()
    return dict(cost.coll_by_kind)
