"""ShapeDtypeStruct stand-ins (weak-type-correct, shardable, zero
allocation) for every (arch x shape) dry-run cell, plus the abstract
param/optimizer/decode-state trees with their shardings attached."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.models.model import init_decode_state, init_params
from repro.sharding.partition import (ShardingPolicy, RuleContext,
                                      decode_state_specs, param_specs)
from repro.train.optimizer import OptimizerConfig, adamw_init

PyTree = Any

N_PATCHES = 256       # internvl2 vision stub


def _with_shardings(abstract: PyTree, specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                          sharding=NamedSharding(mesh, s)),
        abstract, specs)


def abstract_params(cfg: ModelConfig, mesh: Mesh,
                    policy: ShardingPolicy) -> PyTree:
    aps = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(aps, mesh, policy)
    return _with_shardings(aps, specs, mesh)


def abstract_opt_state(cfg: ModelConfig, mesh: Mesh, policy: ShardingPolicy,
                       opt_cfg: OptimizerConfig) -> PyTree:
    aps = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    aopt = jax.eval_shape(lambda: adamw_init(aps_concrete(aps), opt_cfg))
    pspecs = param_specs(aps, mesh, policy)
    ospecs = {"m": pspecs, "v": pspecs, "count": P()}
    return _with_shardings(aopt, ospecs, mesh)


def aps_concrete(aps: PyTree) -> PyTree:
    # eval_shape-friendly zeros matching abstract tree (never materialized).
    return jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), aps)


def batch_shape(cfg: ModelConfig, shape: ShapeConfig
                ) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
    """(shape, dtype) per batch field for train/prefill inputs."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio_frames":
        d = {"embeds": ((B, S, cfg.d_model), jnp.bfloat16)}
        if shape.kind == "train":
            d["labels"] = ((B, S), jnp.int32)
        return d
    if cfg.frontend == "vision_patches":
        d = {"tokens": ((B, S - N_PATCHES), jnp.int32),
             "embeds": ((B, N_PATCHES, cfg.d_model), jnp.bfloat16)}
        if shape.kind == "train":
            d["labels"] = ((B, S), jnp.int32)
        return d
    d = {"tokens": ((B, S), jnp.int32)}
    if shape.kind == "train":
        d["labels"] = ((B, S), jnp.int32)
    return d


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   policy: ShardingPolicy) -> PyTree:
    ctx = RuleContext(mesh, policy)
    b_axes = ctx.fit(policy.dp_axes, shape.global_batch)
    out = {}
    for name, (shp, dtype) in batch_shape(cfg, shape).items():
        spec = P(b_axes, *([None] * (len(shp) - 1)))
        out[name] = jax.ShapeDtypeStruct(shp, dtype,
                                         sharding=NamedSharding(mesh, spec))
    return out


def abstract_decode_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                           policy: ShardingPolicy,
                           seq_axes: Optional[Tuple[str, ...]] = None
                           ) -> Dict[str, PyTree]:
    B, S = shape.global_batch, shape.seq_len
    if seq_axes is None:
        # Batch 1 (long_500k): spread the KV sequence across everything.
        seq_axes = policy.dp_axes + ("model",) if B == 1 else ("model",)
    ast = jax.eval_shape(lambda: init_decode_state(cfg, B, S))
    sspecs = decode_state_specs(ast, mesh, policy, B, seq_axes)
    state = _with_shardings(ast, sspecs, mesh)
    ctx = RuleContext(mesh, policy)
    b_axes = ctx.fit(policy.dp_axes, B)
    tokens = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32, sharding=NamedSharding(mesh, P(b_axes, None)))
    pos = jax.ShapeDtypeStruct(
        (B,), jnp.int32, sharding=NamedSharding(mesh, P(b_axes)))
    return {"tokens": tokens, "state": state, "pos": pos}
