"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run forces 512 host devices *before*
importing jax; everything else sees the real device count.
"""
from __future__ import annotations

import jax


def auto_axis_kwargs(n_axes: int) -> dict:
    """``axis_types=(AxisType.Auto,) * n`` where the installed jax has
    ``jax.sharding.AxisType`` (0.5+); empty kwargs on older releases
    whose ``make_mesh`` takes no ``axis_types`` (Auto is the default)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """v5e pod mesh: 16x16 (= 256 chips) per pod; 2 pods for multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_kwargs(len(axes)))


def make_host_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices actually exist (tests, examples)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"),
                         **auto_axis_kwargs(2))
