"""Roofline-term derivation from the compiled dry-run artifact (§Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(per chip). Terms, all in seconds per step, per the assignment:

  compute    = HLO_FLOPs(per chip) / peak_FLOPs
  memory     = HLO_bytes(per chip) / HBM_bw
  collective = collective_bytes(per chip) / link_bw

HLO_FLOPs / bytes / collective bytes come from the loop-aware analyzer
(``hlo_analysis``), which is per-device for SPMD modules. MODEL_FLOPS uses
6·N_active·D (train) or 2·N_active·D (prefill / per-token decode), so
MODEL_FLOPS / (HLO_FLOPs × chips) exposes remat/dispatch/redundancy waste.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.launch.hlo_analysis import Cost


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12          # bf16 / chip
    hbm_bw: float = 819e9               # B/s / chip
    link_bw: float = 50e9               # B/s / link (ICI)
    hbm_bytes: float = 16e9             # capacity / chip
    loop_latency: float = 2e-6          # s per dependent loop iteration


V5E = Hardware()


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    serial_s: float
    seq_iters: float
    model_flops: float
    useful_ratio: float
    bottleneck: str
    hbm_per_chip: Optional[float] = None
    coll_by_kind: Optional[Dict[str, float]] = None

    @property
    def step_time_s(self) -> float:
        """Max-of-terms roofline step time (perfect overlap assumption);
        the serialization floor cannot be overlapped away."""
        return max(self.compute_s, self.memory_s, self.collective_s,
                   self.serial_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the roofline step: how close the step
        is to a perfect 100%-MXU execution of the model math."""
        if self.step_time_s <= 0:
            return 0.0
        ideal = self.model_flops / (self.chips * V5E.peak_flops)
        return min(1.0, ideal / self.step_time_s)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence; attention reads the whole KV cache —
    # counted via 2·N_active plus 2·KV flops per layer.
    kv_layers = sum(1 for m, _ in cfg.layer_pattern if m == "attn") * \
        cfg.n_periods
    hd = cfg.resolved_head_dim
    kv_flops = 4.0 * shape.seq_len * cfg.n_heads * hd * kv_layers
    return (2.0 * n_active + kv_flops) * shape.global_batch


def build_report(arch: str, shape_cfg: ShapeConfig, mesh_name: str,
                 chips: int, cost: Cost, cfg: ModelConfig,
                 hbm_per_chip: Optional[float] = None,
                 hw: Hardware = V5E) -> RooflineReport:
    compute = cost.flops / hw.peak_flops
    memory = cost.bytes / hw.hbm_bw
    coll = cost.coll_bytes / hw.link_bw
    serial = cost.seq_iters * hw.loop_latency
    mf = model_flops(cfg, shape_cfg)
    useful = mf / max(cost.flops * chips, 1.0)
    terms = {"compute": compute, "memory": memory, "collective": coll,
             "serial": serial}
    bottleneck = max(terms, key=terms.get)
    return RooflineReport(
        arch=arch, shape=shape_cfg.name, mesh=mesh_name, chips=chips,
        flops_per_chip=cost.flops, bytes_per_chip=cost.bytes,
        coll_bytes_per_chip=cost.coll_bytes, compute_s=compute,
        memory_s=memory, collective_s=coll, serial_s=serial,
        seq_iters=cost.seq_iters, model_flops=mf,
        useful_ratio=useful, bottleneck=bottleneck,
        hbm_per_chip=hbm_per_chip,
        coll_by_kind=dict(cost.coll_by_kind))


def format_row(r: RooflineReport) -> str:
    return (f"{r.arch:<22} {r.shape:<12} {r.mesh:<10} "
            f"C={r.compute_s:9.3e}s M={r.memory_s:9.3e}s "
            f"X={r.collective_s:9.3e}s S={r.serial_s:9.3e}s "
            f"dom={r.bottleneck:<10} "
            f"useful={r.useful_ratio:6.1%} roofline={r.roofline_fraction:6.1%}")
