"""Serving launcher: batched requests through the paged-KV engine with the
paper's cost-based prefix cache.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
      --requests 12 --policy cost
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get, list_archs, reduced
from repro.models.model import init_params
from repro.serve.engine import Request, ServingEngine


def synth_requests(n: int, vocab: int, seed: int = 0, sys_len: int = 48,
                   user_len: int = 16):
    """Multi-turn-style workload: a shared system prompt + per-user tail —
    the prefix-sharing pattern the cost-based page cache exploits."""
    rng = np.random.default_rng(seed)
    system = rng.integers(1, vocab, sys_len).tolist()
    reqs = []
    for i in range(n):
        user = rng.integers(1, vocab, user_len).tolist()
        reqs.append(Request(request_id=i, prompt=system + user,
                            max_new_tokens=8))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--policy", choices=["cost", "lru"], default="cost")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced(get(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServingEngine(cfg, params, slots=args.slots,
                           policy=args.policy)
    reqs = synth_requests(args.requests, cfg.vocab_size, args.seed)
    done = engine.run(reqs)
    st = engine.stats
    print(f"served {len(done)} requests; prompt tokens {st.prompt_tokens}, "
          f"prefill executed {st.prefill_executed}, "
          f"saved by prefix cache {st.prefill_saved} "
          f"({st.prefill_saved / max(st.prompt_tokens,1):.0%})")
    return engine


if __name__ == "__main__":
    main()
