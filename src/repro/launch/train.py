"""Training launcher: end-to-end driver wiring every substrate together —
raw-array cached data pipeline, sharded model, AdamW, async checkpointing,
fault-tolerant supervision.

On this container it trains a reduced config on CPU (the examples use it to
train a ~100M-param model for a few hundred steps); on a pod the same driver
runs the full config over the production mesh — only ``--scale full`` and
the mesh change.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, list_archs, reduced
from repro.data.pipeline import build_pipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import init_params
from repro.sharding.partition import (make_policy, param_shardings)
from repro.train.checkpoint import (AsyncCheckpointer, latest_checkpoint,
                                    restore_checkpoint)
from repro.train.optimizer import OptimizerConfig, adamw_init
from repro.train.train_step import make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen1.5-0.5b")
    ap.add_argument("--scale", choices=["reduced", "full"], default="reduced")
    ap.add_argument("--d-model", type=int, default=128,
                    help="reduced-scale width")
    ap.add_argument("--periods", type=int, default=2)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--data-dir", default=None)
    ap.add_argument("--data-policy", choices=["cost", "chunk_lru",
                                              "file_lru"], default="cost")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.scale == "reduced":
        cfg = reduced(cfg, d_model=args.d_model, n_periods=args.periods,
                      vocab=args.vocab)
    mesh = (make_production_mesh() if args.scale == "full"
            else make_host_mesh())
    policy = make_policy(cfg, mesh)

    data_dir = args.data_dir or tempfile.mkdtemp(prefix="repro_data_")
    pipeline = build_pipeline(
        data_dir, n_samples=max(args.batch * 8, 64), seq=args.seq,
        vocab=cfg.vocab_size, n_hosts=4, batch=args.batch,
        policy=args.data_policy,
        host_budget_bytes=8 << 20, seed=args.seed)

    opt_cfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=10,
                              total_steps=args.steps)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    shardings = param_shardings(params, mesh, policy)
    params = jax.tree.map(jax.device_put, params, shardings)
    opt_state = adamw_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      n_microbatches=args.microbatches))

    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir, keep=3)
        latest = latest_checkpoint(args.ckpt_dir)
        if latest:
            tree, start, extra = restore_checkpoint(
                latest, {"params": params, "opt": opt_state})
            params, opt_state = tree["params"], tree["opt"]
            if "pipeline" in extra:
                pipeline.set_state(extra["pipeline"])
            print(f"restored step {start} from {latest}")

    losses = []
    t0 = time.time()
    with jax.set_mesh(mesh):
        for step in range(start, args.steps):
            batch = pipeline.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt:.1f}s)")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          extra={"pipeline": pipeline.state()})
    if ckpt:
        ckpt.wait()
    stats = pipeline.stats
    print(f"data pipeline: {stats.cache_hit_steps}/{stats.steps} cache-hit "
          f"steps, {stats.bytes_scanned/1e6:.1f} MB raw scanned")
    return {"losses": losses, "pipeline_stats": stats}


if __name__ == "__main__":
    main()
