"""GQA attention: training/prefill (naive or blockwise-online-softmax) and
single-token decode against a KV cache.

The blockwise path is the pure-JAX flash-attention formulation (scan over KV
blocks with running max/denominator) — O(S) memory, the form the Pallas
kernel in ``repro.kernels.flash_attention`` implements natively on TPU. The
implementation is selected by ``impl``: "auto" uses naive for short
sequences (cheap HLO for CPU tests) and blockwise beyond 2048.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, apply_rope

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    q_dim, kv_dim = cfg.n_heads * hd, cfg.n_kv_heads * hd
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p: Params = {
        "wq": (jax.random.normal(ks[0], (d, q_dim)) * std).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kv_dim)) * std).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kv_dim)) * std).astype(dtype),
        "wo": (jax.random.normal(ks[3], (q_dim, d)) *
               (1.0 / math.sqrt(q_dim))).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((q_dim,), dtype)
        p["bk"] = jnp.zeros((kv_dim,), dtype)
        p["bv"] = jnp.zeros((kv_dim,), dtype)
    return p


def _project_qkv(params: Params, x: jax.Array, cfg: ModelConfig
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"];  k = k + params["bk"];  v = v + params["bv"]
    B, S = x.shape[:2]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """Broadcast KV heads to Q heads for GQA (no materialized repeat: rely on
    reshape+broadcast so XLA keeps it free)."""
    B, S, Hk, D = k.shape
    rep = n_heads // Hk
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, Hk, rep, D))
    return k.reshape(B, S, Hk * rep, D)


def _naive_attention(q, k, v, causal: bool, q_offset: int = 0) -> jax.Array:
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    # bf16 dot + fp32 logits cast, matching the decode path bit-for-bit
    # (teacher-forced decode == parallel forward; see §Perf C2 note).
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        qi = jnp.arange(Sq)[:, None] + q_offset
        ki = jnp.arange(Sk)[None, :]
        logits = jnp.where(ki <= qi, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _blockwise_impl(q, k, v, causal: bool, block: int):
    """Online-softmax scan over KV blocks — O(S) memory. Returns (out, lse)
    with lse = logsumexp of the masked logits, (B, H, Sq)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    nblk = (Sk + block - 1) // block
    pad = nblk * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block, H, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block, H, D).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / math.sqrt(D)
    qi = jnp.arange(Sq)[:, None]

    def body(carry, xs):
        acc, m, denom = carry          # (B,Sq,H,D), (B,H,Sq), (B,H,Sq)
        kblk, vblk, blk_idx = xs
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kblk
                            ).astype(jnp.float32) * scale
        ki = blk_idx * block + jnp.arange(block)[None, :]
        mask = ki <= qi if causal else (ki < Sk)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        denom = denom * corr + p.sum(axis=-1)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqk,bkhd->bqhd", p.astype(q.dtype), vblk).astype(jnp.float32)
        return (acc, m_new, denom), None

    acc0 = jnp.zeros((B, Sq, H, D), jnp.float32)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    d0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, denom), _ = jax.lax.scan(
        body, (acc0, m0, d0),
        (kb, vb, jnp.arange(nblk)))
    denom = jnp.maximum(denom, 1e-30)
    out = acc / denom.transpose(0, 2, 1)[..., None]
    lse = m + jnp.log(denom)
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _blockwise_attention(q, k, v, causal: bool, block: int = 512):
    """Flash attention with a custom backward (§Perf B2): naive AD of the
    forward scan stacks every block's (Sq, block) probabilities as scan
    residuals — O(Sq*Sk) HBM traffic per layer. The custom VJP saves only
    (out, lse) and recomputes each block's probabilities in the backward
    scan, restoring O(S) memory for training."""
    return _blockwise_impl(q, k, v, causal, block)[0]


def _blockwise_fwd(q, k, v, causal: bool, block: int):
    out, lse = _blockwise_impl(q, k, v, causal, block)
    return out, (q, k, v, out, lse)


def _blockwise_bwd(causal: bool, block: int, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    nblk = (Sk + block - 1) // block
    pad = nblk * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block, H, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block, H, D).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / math.sqrt(D)
    qi = jnp.arange(Sq)[:, None]
    doutf = dout.astype(jnp.float32)
    # delta_i = sum_d dout_i * out_i  (flash-attention-2 backward).
    delta = jnp.einsum("bqhd,bqhd->bhq", doutf, out.astype(jnp.float32))

    def body(dq_acc, xs):
        kblk, vblk, blk_idx = xs
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kblk
                            ).astype(jnp.float32) * scale
        ki = blk_idx * block + jnp.arange(block)[None, :]
        mask = ki <= qi if causal else (ki < Sk)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        p = jnp.exp(logits - lse[..., None])          # (B,H,Sq,block)
        dp = jnp.einsum("bqhd,bkhd->bhqk", doutf,
                        vblk.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dv = jnp.einsum("bhqk,bqhd->bkhd", p, doutf)
        dk = jnp.einsum("bhqk,bqhd->bkhd", ds, q.astype(jnp.float32))
        dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds,
                                     kblk.astype(jnp.float32))
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((B, Sq, H, D), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(body, dq0,
                                    (kb, vb, jnp.arange(nblk)))
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(B, nblk * block, H, D)
    dv = dv_b.transpose(1, 0, 2, 3, 4).reshape(B, nblk * block, H, D)
    return (dq.astype(q.dtype), dk[:, :Sk].astype(k.dtype),
            dv[:, :Sk].astype(v.dtype))


_blockwise_attention.defvjp(_blockwise_fwd, _blockwise_bwd)


def attention_forward(params: Params, x: jax.Array, cfg: ModelConfig,
                      positions: jax.Array, causal: bool,
                      impl: str = "auto") -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    q, k, v = _project_qkv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    S = x.shape[1]
    if impl == "auto":
        impl = "naive" if S <= 2048 else "blockwise"
    if impl == "naive":
        out = _naive_attention(q, k, v, causal)
    elif impl == "blockwise":
        out = _blockwise_attention(q, k, v, causal)
    else:
        raise ValueError(f"unknown attention impl {impl!r}")
    B, S_, H, D = out.shape
    return out.reshape(B, S_, H * D) @ params["wo"]


def decode_attention(params: Params, x: jax.Array, cfg: ModelConfig,
                     kv_cache: Dict[str, jax.Array], pos: jax.Array
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B, 1, d). kv_cache: {"k","v"}: (B, S_max, Hk, Dh),
    pos: (B,) current write index. Returns output and the updated cache."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k_new, v_new = _project_qkv(params, x, cfg)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)
    # Write the new KV at per-sequence positions.
    kv_update = getattr(cfg, "kv_update", "scatter")
    if kv_update == "onehot":
        # Pre-hillclimb baseline (§Perf C1): the one-hot blend reads and
        # rewrites the ENTIRE cache every token.
        k_cache = _scatter_kv(kv_cache["k"], k_new, pos)
        v_cache = _scatter_kv(kv_cache["v"], v_new, pos)
    else:
        # Indexed scatter touches one (Hk, Dh) row per sequence; with the
        # cache buffer donated it is an in-place update.
        b_idx = jnp.arange(B)
        k_cache = kv_cache["k"].at[b_idx, pos].set(
            k_new[:, 0].astype(kv_cache["k"].dtype))
        v_cache = kv_cache["v"].at[b_idx, pos].set(
            v_new[:, 0].astype(kv_cache["v"].dtype))
    S_max = k_cache.shape[1]
    k = _expand_kv(k_cache, cfg.n_heads)
    v = _expand_kv(v_cache, cfg.n_heads)
    scale = 1.0 / math.sqrt(hd)
    # NOTE (§Perf C2, refuted): fp32 accumulation via preferred_element_type
    # looked like a free win, but XLA's CPU backend materializes fp32 copies
    # of the whole KV stripe around such dots (+47% memory term measured);
    # the bf16 dot + fp32 logits cast below avoids the copies on CPU and is
    # what the TPU MXU executes natively anyway.
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    valid = jnp.arange(S_max)[None, :] <= pos[:, None]           # (B, S_max)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    out = out.reshape(B, 1, cfg.n_heads * hd) @ params["wo"]
    return out, {"k": k_cache, "v": v_cache}


def _scatter_kv(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """cache: (B, S, Hk, D); new: (B, 1, Hk, D); pos: (B,)."""
    oh = jax.nn.one_hot(pos, cache.shape[1], dtype=cache.dtype)  # (B, S)
    return cache * (1 - oh)[..., None, None] + oh[..., None, None] * new


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
