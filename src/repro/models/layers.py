"""Shared layers: norms (incl. OLMo's non-parametric LN), RoPE, MLP variants.

Parameters are plain pytrees (dicts of jnp arrays). Every init function takes
an ``jax.random`` key and returns the param dict; every apply function takes
(params, inputs). Compute dtype is bf16 by default with fp32 accumulation for
reductions (norms, softmax) — the TPU-native policy.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]


def default_dtype() -> jnp.dtype:
    return jnp.bfloat16


# ------------------------------------------------------------------ norms --

def init_norm(key, d: int, kind: str) -> Params:
    del key
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    if kind == "nonparam_ln":       # OLMo: LN without learnable params
        return {}
    raise ValueError(f"unknown norm {kind!r}")


def apply_norm(params: Params, x: jax.Array, kind: str,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            out = out * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# ------------------------------------------------------------------- RoPE --

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                    # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------------- MLP --

def init_mlp(key, d: int, d_ff: int, mlp_type: str,
             dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(d_ff)
    p: Params = {
        "w_in": (jax.random.normal(k1, (d, d_ff)) * std_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d)) * std_out).astype(dtype),
    }
    if mlp_type == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (d, d_ff)) * std_in).astype(dtype)
    return p


def apply_mlp(params: Params, x: jax.Array, mlp_type: str) -> jax.Array:
    h = x @ params["w_in"]
    if mlp_type == "swiglu":
        g = x @ params["w_gate"]
        h = jax.nn.silu(g) * h
    elif mlp_type == "relu2":       # Nemotron-4: squared ReLU
        h = jnp.square(jax.nn.relu(h))
    elif mlp_type == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown mlp {mlp_type!r}")
    return h @ params["w_out"]


# -------------------------------------------------------------- embedding --

def init_embedding(key, vocab: int, d: int, dtype=jnp.bfloat16) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d)) *
                      (1.0 / math.sqrt(d))).astype(dtype)}


def embed(params: Params, tokens: jax.Array) -> jax.Array:
    return params["table"][tokens]


def unembed(table: jax.Array, x: jax.Array) -> jax.Array:
    """bf16 matmul with fp32 accumulation -> fp32 logits, without ever
    materializing an fp32 copy of the (vocab, d) table."""
    return jax.lax.dot_general(
        x, table, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
