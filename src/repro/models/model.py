"""Model assembly: scan-over-periods block stacks for all ten architectures.

``init_params`` builds a pytree whose block leaves have a leading
``n_periods`` axis; ``forward`` (train/prefill) and ``decode_step`` (serving)
iterate periods with ``jax.lax.scan`` so HLO size and compile time are
O(period), independent of depth. Heterogeneous stacks (Jamba's 1:7
attention:Mamba interleave, xLSTM's mLSTM/sLSTM mix, MoE-every-2) are
expressed by the per-period ``layer_pattern``.

Decode state is a pytree mirroring the pattern: attention blocks carry a
(P, B, S_max, Hk, Dh) KV cache; Mamba/xLSTM blocks carry their O(1)
recurrent states stacked over periods.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (Params, apply_mlp, apply_norm, embed,
                                 init_embedding, init_mlp, init_norm,
                                 unembed)

PyTree = Any


# ------------------------------------------------------------------- init --

def _init_block(key, cfg: ModelConfig, mixer: str, mlp: str,
                dtype=jnp.bfloat16) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": init_norm(k3, cfg.d_model, cfg.norm_type)}
    if mixer == "attn":
        p["mixer"] = attn.init_attention(k1, cfg, dtype)
    elif mixer == "mamba":
        p["mixer"] = ssm.init_mamba(k1, cfg, dtype)
    elif mixer == "mlstm":
        p["mixer"] = ssm.init_mlstm(k1, cfg, dtype)
    elif mixer == "slstm":
        p["mixer"] = ssm.init_slstm(k1, cfg, dtype)
    if mlp == "dense":
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
        p["norm2"] = init_norm(k4, cfg.d_model, cfg.norm_type)
    elif mlp == "moe":
        p["mlp"] = moe_mod.init_moe(k2, cfg, dtype)
        p["norm2"] = init_norm(k4, cfg.d_model, cfg.norm_type)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Params:
    keys = jax.random.split(key, cfg.period + 3)
    blocks: Params = {}
    for i, (mixer, mlp) in enumerate(cfg.layer_pattern):
        pkeys = jax.random.split(keys[i], cfg.n_periods)
        blocks[f"b{i}"] = jax.vmap(
            lambda k: _init_block(k, cfg, mixer, mlp, dtype))(pkeys)
    params: Params = {"blocks": blocks,
                      "final_norm": init_norm(keys[-3], cfg.d_model,
                                              cfg.norm_type)}
    if cfg.frontend != "audio_frames":
        params["embed"] = init_embedding(keys[-2], cfg.vocab_size,
                                         cfg.d_model, dtype)
    if cfg.encoder_only or cfg.frontend == "audio_frames":
        params["head"] = init_embedding(keys[-1], cfg.vocab_size,
                                        cfg.d_model, dtype)
    elif not cfg.tie_embeddings:
        params["unembed"] = init_embedding(keys[-1], cfg.vocab_size,
                                           cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------- forward --

def _apply_block_seq(bp: Params, h: jax.Array, cfg: ModelConfig,
                     mixer: str, mlp: str, positions: jax.Array,
                     causal: bool, attention_impl: str
                     ) -> Tuple[jax.Array, jax.Array]:
    """One block over a full sequence. Returns (h, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    hn = apply_norm(bp["norm1"], h, cfg.norm_type)
    if mixer == "attn":
        mixed = attn.attention_forward(bp["mixer"], hn, cfg, positions,
                                       causal, attention_impl)
    elif mixer == "mamba":
        mixed, _ = ssm.mamba_forward(bp["mixer"], hn, cfg)
    elif mixer == "mlstm":
        mixed, _ = ssm.mlstm_forward(bp["mixer"], hn, cfg,
                                     impl=cfg.mlstm_impl)
    elif mixer == "slstm":
        mixed, _ = ssm.slstm_forward(bp["mixer"], hn, cfg)
    else:
        raise ValueError(mixer)
    h = h + mixed
    if mlp != "none":
        hn = apply_norm(bp["norm2"], h, cfg.norm_type)
        if mlp == "dense":
            h = h + apply_mlp(bp["mlp"], hn, cfg.mlp_type)
        else:
            y, aux = moe_mod.moe_forward(bp["mlp"], hn, cfg)
            h = h + y
    return h, aux


def forward(params: Params, cfg: ModelConfig, *,
            tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            attention_impl: str = "auto",
            remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits, aux_loss).

    ``tokens``: (B, S) int32 (LM / VLM text); ``embeds``: (B, S_e, d)
    precomputed frontend embeddings (audio frames / vision patches). For the
    VLM both are given and the patch embeddings are prepended.
    """
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(jnp.bfloat16))
    if tokens is not None:
        parts.append(embed(params["embed"], tokens))
    h = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :]
    causal = not cfg.encoder_only

    def period_body(carry, period_params):
        hh, aux = carry
        for i, (mixer, mlp) in enumerate(cfg.layer_pattern):
            if cfg.seq_parallel:
                hh = jax.lax.with_sharding_constraint(
                    hh, jax.sharding.PartitionSpec(None, "model", None))
            hh, a = _apply_block_seq(period_params[f"b{i}"], hh, cfg, mixer,
                                     mlp, positions, causal, attention_impl)
            aux = aux + a
        return (hh, aux), None

    body = period_body
    if remat:
        body = jax.checkpoint(
            period_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    h = apply_norm(params["final_norm"], h, cfg.norm_type)
    if cfg.encoder_only or cfg.frontend == "audio_frames":
        logits = unembed(params["head"]["table"], h)
    elif cfg.tie_embeddings:
        logits = unembed(params["embed"]["table"], h)
    else:
        logits = unembed(params["unembed"]["table"], h)
    return logits, aux


# ----------------------------------------------------------------- decode --

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    """Stacked (over periods) per-pattern-position decode states."""

    def one(i: int, mixer: str) -> PyTree:
        if mixer == "attn":
            return attn.init_kv_cache(cfg, batch, max_len)
        if mixer == "mamba":
            return ssm.init_mamba_state(cfg, batch)
        if mixer == "mlstm":
            C, n, m = ssm.init_mlstm_state(cfg, batch)
            return {"C": C, "n": n, "m": m}
        if mixer == "slstm":
            c, n, m, h = ssm.init_slstm_state(cfg, batch)
            return {"c": c, "n": n, "m": m, "h": h}
        raise ValueError(mixer)

    state: Dict[str, PyTree] = {}
    for i, (mixer, _) in enumerate(cfg.layer_pattern):
        st = one(i, mixer)
        state[f"b{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape).copy(),
            st)
    return state


def _apply_block_step(bp: Params, h: jax.Array, st: PyTree, cfg: ModelConfig,
                      mixer: str, mlp: str, pos: jax.Array
                      ) -> Tuple[jax.Array, PyTree]:
    hn = apply_norm(bp["norm1"], h, cfg.norm_type)
    if mixer == "attn":
        mixed, st = attn.decode_attention(bp["mixer"], hn, cfg, st, pos)
    elif mixer == "mamba":
        mixed, st = ssm.mamba_step(bp["mixer"], hn, st, cfg)
    elif mixer == "mlstm":
        mixed, tup = ssm.mlstm_step(bp["mixer"], hn,
                                    (st["C"], st["n"], st["m"]), cfg)
        st = {"C": tup[0], "n": tup[1], "m": tup[2]}
    elif mixer == "slstm":
        mixed, tup = ssm.slstm_step(bp["mixer"], hn,
                                    (st["c"], st["n"], st["m"], st["h"]), cfg)
        st = {"c": tup[0], "n": tup[1], "m": tup[2], "h": tup[3]}
    else:
        raise ValueError(mixer)
    h = h + mixed
    if mlp != "none":
        hn = apply_norm(bp["norm2"], h, cfg.norm_type)
        if mlp == "dense":
            h = h + apply_mlp(bp["mlp"], hn, cfg.mlp_type)
        else:
            y, _ = moe_mod.moe_forward(bp["mlp"], hn, cfg)
            h = h + y
    return h, st


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                state: PyTree, pos: jax.Array
                ) -> Tuple[jax.Array, PyTree]:
    """One decode step. tokens: (B, 1) int32; pos: (B,) write positions.
    Returns (logits (B, 1, V), updated state)."""
    if cfg.encoder_only:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    h = embed(params["embed"], tokens)

    def period_body(carry, xs):
        hh = carry
        period_params, st = xs
        new_st = {}
        for i, (mixer, mlp) in enumerate(cfg.layer_pattern):
            hh, new_st[f"b{i}"] = _apply_block_step(
                period_params[f"b{i}"], hh, st[f"b{i}"], cfg, mixer, mlp, pos)
        return hh, new_st

    h, new_state = jax.lax.scan(period_body, h, (params["blocks"], state))
    h = apply_norm(params["final_norm"], h, cfg.norm_type)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"]["table"], h)
    else:
        logits = unembed(params["unembed"]["table"], h)
    return logits, new_state


# ------------------------------------------------------------------ losses --

def lm_loss(logits: jax.Array, labels: jax.Array,
            mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean cross entropy; labels < 0 are ignored.

    Written as logsumexp - <onehot, logits> so a vocab-sharded logits tensor
    stays sharded: the label pick is a local partial sum + tiny all-reduce,
    never a cross-shard gather (take_along_axis would all-gather the full
    (B, S, V) tensor)."""
    valid = (labels >= 0) if mask is None else mask
    labels = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)          # (B, S)
    onehot = jax.nn.one_hot(labels, logits.shape[-1],
                            dtype=jnp.bfloat16)
    picked = jnp.einsum("...v,...v->...", logits,
                        onehot.astype(jnp.float32))
    ll = (picked - lse) * valid
    return -(ll.sum() / jnp.maximum(valid.sum(), 1))
