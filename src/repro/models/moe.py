"""Mixture-of-Experts MLP: top-k routing with capacity-based dispatch
(GShard/Switch-style einsum dispatch — the TPU-native formulation), shared
experts (DeepSeekMoE), and an auxiliary load-balance loss.

Experts live on the leading axis of the weight stacks, which the sharding
rules map to the ``model`` mesh axis (expert parallelism). The dispatch and
combine einsums then lower to all-to-alls under pjit.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params


def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    assert cfg.moe is not None
    d = cfg.d_model
    de = cfg.d_expert_resolved
    E = cfg.moe.n_experts
    S = cfg.moe.n_shared
    ks = jax.random.split(key, 7)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(de)
    mult_gate = cfg.mlp_type == "swiglu"
    p: Params = {
        "router": (jax.random.normal(ks[0], (d, E)) * std_in).astype(
            jnp.float32),
        "w_in": (jax.random.normal(ks[1], (E, d, de)) * std_in).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (E, de, d)) *
                  std_out).astype(dtype),
    }
    if mult_gate:
        p["w_gate"] = (jax.random.normal(ks[3], (E, d, de)) *
                       std_in).astype(dtype)
    if S:
        p["sh_in"] = (jax.random.normal(ks[4], (d, S * de)) *
                      std_in).astype(dtype)
        p["sh_out"] = (jax.random.normal(ks[5], (S * de, d)) *
                       std_out).astype(dtype)
        if mult_gate:
            p["sh_gate"] = (jax.random.normal(ks[6], (d, S * de)) *
                            std_in).astype(dtype)
    return p


def _activate(h: jax.Array, g, mlp_type: str) -> jax.Array:
    if mlp_type == "swiglu":
        return jax.nn.silu(g) * h
    if mlp_type == "relu2":
        return jnp.square(jax.nn.relu(h))
    return jax.nn.gelu(h)


def moe_forward(params: Params, x: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). Capacity-based top-k dispatch."""
    mc = cfg.moe
    assert mc is not None
    B, S, d = x.shape
    T = B * S
    E, K = mc.n_experts, mc.top_k
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ params["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Aux load-balance loss (Switch): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(expert_idx[:, 0], E)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = E * jnp.sum(me * ce)

    capacity = max(1, int(mc.capacity_factor * K * T / E))
    # Position of each (token, k) slot within its expert queue.
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)       # (T, K, E)
    flat = onehot.reshape(T * K, E)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(T, K, E)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)                # (T, K)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    dispatch = getattr(cfg, "moe_dispatch", "sort")
    if dispatch == "einsum":
        # GShard-style one-hot einsum dispatch (pre-hillclimb baseline,
        # EXPERIMENTS.md §Perf B1): materializes (T, E, C) tensors — the
        # dispatch einsums cost O(T*E*C*d), dwarfing the expert matmuls for
        # fine-grained MoEs.
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity,
                                dtype=x.dtype)                    # (T, K, C)
        disp = jnp.einsum("tke,tkc->tec", onehot.astype(x.dtype), pos_oh)
        comb = jnp.einsum("tke,tkc,tk->tec", onehot.astype(jnp.float32),
                          pos_oh.astype(jnp.float32),
                          gate_vals.astype(jnp.float32)).astype(x.dtype)
        xe = jnp.einsum("td,tec->ecd", xt, disp)                  # (E, C, d)
    else:
        # Scatter/gather dispatch (§Perf B1): each (token, k) routes to a
        # unique slot e*C + pos; dropped tokens land in an overflow slot.
        # O(T*K*d) data movement instead of O(T*E*C*d) dispatch FLOPs.
        slot = jnp.where(keep, expert_idx * capacity + pos,
                         E * capacity)                            # (T, K)
        xe_flat = jnp.zeros((E * capacity + 1, d), x.dtype)
        for kk in range(K):
            xe_flat = xe_flat.at[slot[:, kk]].set(xt)
        xe = xe_flat[:E * capacity].reshape(E, capacity, d)

    h = jnp.einsum("ecd,edf->ecf", xe, params["w_in"])
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"]) \
        if "w_gate" in params else None
    h = _activate(h, g, cfg.mlp_type)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_out"])           # (E, C, d)

    if dispatch == "einsum":
        y = jnp.einsum("ecd,tec->td", ye, comb)
    else:
        ye_flat = jnp.concatenate(
            [ye.reshape(E * capacity, d), jnp.zeros((1, d), ye.dtype)])
        y = jnp.zeros((T, d), jnp.float32)
        for kk in range(K):
            y = y + ye_flat[slot[:, kk]].astype(jnp.float32) * \
                gate_vals[:, kk].astype(jnp.float32)[:, None]
        y = y.astype(x.dtype)

    if "sh_in" in params:                                          # shared
        hs = xt @ params["sh_in"]
        gs = xt @ params["sh_gate"] if "sh_gate" in params else None
        y = y + _activate(hs, gs, cfg.mlp_type) @ params["sh_out"]
    return y.reshape(B, S, d), aux
