"""Sequence-mixing SSM blocks: Mamba (Jamba's 7-of-8 layers) and xLSTM's
mLSTM / sLSTM.

Mamba runs chunkwise: ``lax.scan`` over sequence chunks with an associative
scan *inside* each chunk — O(S·d_state) compute, O(chunk) live memory, and an
O(1) recurrent state for decode. This is the TPU-native layout (the chunk is
the VMEM tile). mLSTM/sLSTM use the stabilized sequential recurrence
(``lax.scan`` over time); the chunkwise-parallel mLSTM reformulation is the
documented §Perf optimization path (see EXPERIMENTS.md).

All blocks expose:  init_*(key, cfg) -> params
                    *_forward(params, x, cfg) -> (y, final_state)
                    *_step(params, x_t, state, cfg) -> (y_t, state)
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params


# ------------------------------------------------------------------ Mamba --

def _mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    mc = cfg.mamba
    assert mc is not None
    di = mc.expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return di, mc.d_state, mc.d_conv, dt_rank


def init_mamba(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    di, ds, dc, dtr = _mamba_dims(cfg)
    ks = jax.random.split(key, 7)
    std = 1.0 / math.sqrt(d)
    return {
        "w_in": (jax.random.normal(ks[0], (d, 2 * di)) * std).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_dt_down": (jax.random.normal(ks[2], (di, dtr)) /
                      math.sqrt(di)).astype(dtype),
        "w_dt_up": (jax.random.normal(ks[3], (dtr, di)) /
                    math.sqrt(dtr)).astype(dtype),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),   # softplus^-1(~0.01)
        "w_bc": (jax.random.normal(ks[4], (di, 2 * ds)) /
                 math.sqrt(di)).astype(dtype),
        # A negative-real, channel x state (S4D-lin init).
        "A_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": (jax.random.normal(ks[5], (di, d)) /
                  math.sqrt(di)).astype(dtype),
    }


def _mamba_gates(params: Params, xc: jax.Array):
    """xc: (..., di) post-conv activations -> (dt, B, C) selective params."""
    dt = jax.nn.softplus(
        (xc @ params["w_dt_down"] @ params["w_dt_up"]).astype(jnp.float32)
        + params["dt_bias"])                                   # (..., di)
    bc = (xc @ params["w_bc"]).astype(jnp.float32)
    ds = bc.shape[-1] // 2
    return dt, bc[..., :ds], bc[..., ds:]


def _causal_conv(params: Params, x: jax.Array) -> jax.Array:
    """Depthwise causal conv over sequence (fp32 accumulate, matching the
    decode-step path bit-for-bit). x: (B, S, di)."""
    dc = params["conv_w"].shape[0]
    w = params["conv_w"].astype(jnp.float32)
    pad = jnp.pad(x.astype(jnp.float32), ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(dc))
    return jax.nn.silu(out + params["conv_b"].astype(jnp.float32)
                       ).astype(x.dtype)


def mamba_forward(params: Params, x: jax.Array, cfg: ModelConfig,
                  chunk: int = 128) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (y, {"h": (B, di, ds), "conv": (B, dc-1, di)})."""
    B, S, d = x.shape
    di, ds, dc, _ = _mamba_dims(cfg)
    xz = x @ params["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = _causal_conv(params, xi)                       # (B, S, di)
    dt, Bsel, Csel = _mamba_gates(params, xc)
    A = -jnp.exp(params["A_log"])                       # (di, ds)
    nchunks = (S + chunk - 1) // chunk
    pad = nchunks * chunk - S
    if pad:
        xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_p = jnp.pad(Bsel, ((0, 0), (0, pad), (0, 0)))
        C_p = jnp.pad(Csel, ((0, 0), (0, pad), (0, 0)))
    else:
        xc_p, dt_p, B_p, C_p = xc, dt, Bsel, Csel
    csh = (nchunks, B, chunk)
    xcs = xc_p.reshape(B, nchunks, chunk, di).transpose(1, 0, 2, 3)
    dts = dt_p.reshape(B, nchunks, chunk, di).transpose(1, 0, 2, 3)
    Bs = B_p.reshape(B, nchunks, chunk, ds).transpose(1, 0, 2, 3)
    Cs = C_p.reshape(B, nchunks, chunk, ds).transpose(1, 0, 2, 3)

    def chunk_body(h0, xs):
        xcc, dtc, Bc, Cc = xs                 # (B, Ck, di) / (B, Ck, ds)
        # per-step decay and input: a,b: (B, Ck, di, ds)
        a = jnp.exp(dtc[..., None] * A[None, None])
        b = (dtc * xcc.astype(jnp.float32))[..., None] * Bc[:, :, None, :]

        def combine(left, right):
            a1, b1 = left
            a2, b2 = right
            return a1 * a2, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_t = a_cum * h0[:, None] + b_cum     # (B, Ck, di, ds)
        y = jnp.einsum("bkis,bks->bki", h_t, Cc)
        y = y + params["D"][None, None] * xcc.astype(jnp.float32)
        return h_t[:, -1], y

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    h_final, ys = jax.lax.scan(chunk_body, h0, (xcs, dts, Bs, Cs))
    y = ys.transpose(1, 0, 2, 3).reshape(B, nchunks * chunk, di)[:, :S]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["w_out"]
    conv_state = jnp.pad(xi, ((0, 0), (dc - 1, 0), (0, 0)))[:, S:S + dc - 1]
    return out, {"h": h_final, "conv": conv_state}


def init_mamba_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    di, ds, dc, _ = _mamba_dims(cfg)
    return {"h": jnp.zeros((batch, di, ds), jnp.float32),
            "conv": jnp.zeros((batch, dc - 1, di), jnp.bfloat16)}


def mamba_step(params: Params, x_t: jax.Array, state: Dict[str, jax.Array],
               cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x_t: (B, 1, d) single-token decode."""
    B = x_t.shape[0]
    di, ds, dc, _ = _mamba_dims(cfg)
    xz = x_t[:, 0] @ params["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)                   # (B, di)
    window = jnp.concatenate([state["conv"],
                              xi[:, None].astype(state["conv"].dtype)], axis=1)
    xc = jnp.einsum("bci,ci->bi", window.astype(jnp.float32),
                    params["conv_w"].astype(jnp.float32))
    xc = jax.nn.silu(xc + params["conv_b"].astype(jnp.float32)).astype(x_t.dtype)
    dt, Bsel, Csel = _mamba_gates(params, xc)           # (B, di), (B, ds)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt[..., None] * A[None])                # (B, di, ds)
    b = (dt * xc.astype(jnp.float32))[..., None] * Bsel[:, None, :]
    h = a * state["h"] + b
    y = jnp.einsum("bis,bs->bi", h, Csel) + params["D"][None] * \
        xc.astype(jnp.float32)
    y = y.astype(x_t.dtype) * jax.nn.silu(z)
    out = (y @ params["w_out"])[:, None]
    return out, {"h": h, "conv": window[:, 1:]}


# ------------------------------------------------------------------ mLSTM --

def _xlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    dp = int(cfg.xlstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    dp -= dp % H
    return dp, H, dp // H


def init_mlstm(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    dp, H, dh = _xlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    std = 1.0 / math.sqrt(d)
    stdp = 1.0 / math.sqrt(dp)
    return {
        "w_up": (jax.random.normal(ks[0], (d, 2 * dp)) * std).astype(dtype),
        "wq": (jax.random.normal(ks[1], (dp, dp)) * stdp).astype(dtype),
        "wk": (jax.random.normal(ks[2], (dp, dp)) * stdp).astype(dtype),
        "wv": (jax.random.normal(ks[3], (dp, dp)) * stdp).astype(dtype),
        "w_if": (jax.random.normal(ks[4], (dp, 2 * H)) * stdp).astype(dtype),
        "if_bias": jnp.concatenate([jnp.full((H,), -3.0),
                                    jnp.full((H,), 3.0)]).astype(jnp.float32),
        "w_down": (jax.random.normal(ks[5], (dp, d)) * stdp).astype(dtype),
    }


def _mlstm_qkvg(params: Params, xin: jax.Array, H: int, dh: int):
    q = (xin @ params["wq"]).reshape(*xin.shape[:-1], H, dh)
    k = (xin @ params["wk"]).reshape(*xin.shape[:-1], H, dh) / math.sqrt(dh)
    v = (xin @ params["wv"]).reshape(*xin.shape[:-1], H, dh)
    gates = (xin @ params["w_if"]).astype(jnp.float32) + params["if_bias"]
    log_i = gates[..., :H]                       # input gate pre-act (log)
    log_f = -jax.nn.softplus(-gates[..., H:])    # log sigmoid(f)
    return q, k, v, log_i, log_f


def _mlstm_recurrence(q, k, v, log_i, log_f, state):
    """Stabilized mLSTM scan over time. q/k/v: (B,S,H,dh); gates: (B,S,H).
    state: (C, n, m) with C: (B,H,dh,dh), n: (B,H,dh), m: (B,H)."""

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, li, lf = xs                  # (B,H,dh) x3, (B,H) x2
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)
        f_ = jnp.exp(lf + m - m_new)
        C = f_[..., None, None] * C + i_[..., None, None] * \
            (kt[..., :, None] * vt[..., None, :]).astype(jnp.float32)
        n = f_[..., None] * n + i_[..., None] * kt.astype(jnp.float32)
        qf = qt.astype(jnp.float32)
        num = jnp.einsum("bhd,bhde->bhe", qf, C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n))
        h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (C, n, m_new), h

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (q, k, v)) + \
        tuple(a.transpose(1, 0, 2) for a in (log_i, log_f))
    state, hs = jax.lax.scan(step, state, xs)
    return hs.transpose(1, 0, 2, 3), state       # (B,S,H,dh)


def _mlstm_chunkwise(q, k, v, log_i, log_f, state, chunk: int = 64):
    """Chunkwise-parallel stabilized mLSTM (§Perf iteration A1).

    Within a chunk of length L the recurrence unrolls to an attention-like
    form: with a_t = cumsum(log_f), b_t = log_i - a_t, and running max m,
    the decay matrix D[t, tau] = exp(a_t + b_tau - m_t) for tau <= t gives

        h_num = exp(a + m0 - m) (q @ C0) + (D * (q k^T)) v
        qn    = exp(a + m0 - m) (q . n0) + rowsum(D * (q k^T))

    — MXU matmuls instead of T sequential (B,H,dh,dh) state read/writes; the
    (C, n, m) state crosses chunk boundaries only. Exact (same stabilizer)
    w.r.t. the sequential form up to fp32 rounding."""
    B, S, H, dh = q.shape
    nchunks = (S + chunk - 1) // chunk
    pad = nchunks * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(x, feat):
        return x.reshape(B, nchunks, chunk, *feat).transpose(
            1, 0, *range(2, 3 + len(feat)))

    qs = to_chunks(q, (H, dh));  ks = to_chunks(k, (H, dh))
    vs = to_chunks(v, (H, dh))
    lis = to_chunks(log_i, (H,));  lfs = to_chunks(log_f, (H,))

    def body(carry, xs):
        C0, n0, m0 = carry                      # (B,H,dh,dh),(B,H,dh),(B,H)
        qc, kc, vc, lic, lfc = xs               # (B,L,H,dh) / (B,L,H)
        a = jnp.cumsum(lfc.astype(jnp.float32), axis=1)       # (B,L,H)
        b = lic.astype(jnp.float32) - a
        # Running stabilizer: m_t = max(a_t + m0, a_t + cummax_tau<=t b_tau).
        bmax = jax.lax.cummax(b, axis=1)
        m = a + jnp.maximum(m0[:, None], bmax)                # (B,L,H)
        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        s = jnp.einsum("bthd,bshd->bhts", qf, kf)             # (B,H,L,L)
        logD = (a.transpose(0, 2, 1)[:, :, :, None]
                + b.transpose(0, 2, 1)[:, :, None, :]
                - m.transpose(0, 2, 1)[:, :, :, None])
        tri = jnp.tril(jnp.ones((s.shape[-2], s.shape[-1]), bool))
        D = jnp.where(tri[None, None], jnp.exp(logD), 0.0)
        carry_w = jnp.exp(a + m0[:, None] - m)                # (B,L,H)
        num = (jnp.einsum("bthd,bhde->bthe", qf, C0) *
               carry_w[..., None]
               + jnp.einsum("bhts,bshd->bthd", D * s, vf))
        qn = (jnp.einsum("bthd,bhd->bth", qf, n0) * carry_w
              + jnp.einsum("bhts,bhts->bht", D, s).transpose(0, 2, 1))
        h = num / jnp.maximum(jnp.abs(qn),
                              jnp.exp(-m))[..., None]         # (B,L,H,dh)
        # Chunk-end state: weights exp(a_L + b_tau - m_L) per tau.
        aL = a[:, -1];  mL = m[:, -1]                          # (B,H)
        w_tau = jnp.exp(aL[:, None] + b - mL[:, None])        # (B,L,H)
        C = (jnp.exp(aL + m0 - mL)[..., None, None] * C0
             + jnp.einsum("bshd,bsh,bshe->bhde", kf, w_tau, vf))
        n = (jnp.exp(aL + m0 - mL)[..., None] * n0
             + jnp.einsum("bshd,bsh->bhd", kf, w_tau))
        return (C, n, mL), h

    state, hs = jax.lax.scan(body, state, (qs, ks, vs, lis, lfs))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(B, nchunks * chunk, H, dh)
    return hs[:, :S], state


def mlstm_forward(params: Params, x: jax.Array, cfg: ModelConfig,
                  impl: str = "auto") -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    B, S, d = x.shape
    dp, H, dh = _xlstm_dims(cfg)
    up = x @ params["w_up"]
    xin, z = jnp.split(up, 2, axis=-1)
    q, k, v, li, lf = _mlstm_qkvg(params, xin, H, dh)
    state = init_mlstm_state(cfg, B)
    if impl == "auto":
        impl = "chunkwise" if S >= 128 else "sequential"
    if impl == "chunkwise":
        hs, state = _mlstm_chunkwise(q, k, v, li, lf, state)
    else:
        hs, state = _mlstm_recurrence(q, k, v, li, lf, state)
    y = hs.reshape(B, S, dp).astype(x.dtype) * jax.nn.silu(z)
    return y @ params["w_down"], state


def init_mlstm_state(cfg: ModelConfig, batch: int):
    dp, H, dh = _xlstm_dims(cfg)
    return (jnp.zeros((batch, H, dh, dh), jnp.float32),
            jnp.zeros((batch, H, dh), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


def mlstm_step(params: Params, x_t: jax.Array, state, cfg: ModelConfig):
    B = x_t.shape[0]
    dp, H, dh = _xlstm_dims(cfg)
    up = x_t[:, 0] @ params["w_up"]
    xin, z = jnp.split(up, 2, axis=-1)
    q, k, v, li, lf = _mlstm_qkvg(params, xin, H, dh)
    hs, state = _mlstm_recurrence(q[:, None], k[:, None], v[:, None],
                                  li[:, None], lf[:, None], state)
    y = hs[:, 0].reshape(B, dp).astype(x_t.dtype) * jax.nn.silu(z)
    return (y @ params["w_down"])[:, None], state


# ------------------------------------------------------------------ sLSTM --

def init_slstm(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    dp, _, _ = _xlstm_dims(cfg)
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    stdp = 1.0 / math.sqrt(dp)
    return {
        "w_up": (jax.random.normal(ks[0], (d, 2 * dp)) * std).astype(dtype),
        "w_gates": (jax.random.normal(ks[1], (dp, 4 * dp)) *
                    stdp).astype(dtype),
        "r_gates": (jax.random.normal(ks[2], (dp, 4 * dp)) *
                    stdp * 0.5).astype(dtype),
        "g_bias": jnp.zeros((4 * dp,), jnp.float32),
        "w_down": (jax.random.normal(ks[3], (dp, d)) * stdp).astype(dtype),
    }


def _slstm_recurrence(params: Params, xin: jax.Array, state, dp: int):
    """Stabilized sLSTM: scalar memory with exp input gate. xin: (B,S,dp).

    §Perf iteration A2: the input-side gate projection (T small matmuls) is
    hoisted out of the scan as one (B*S, dp) x (dp, 4dp) MXU matmul; only
    the recurrent R @ h_{t-1} term stays sequential (data dependence)."""
    x_pre = (xin @ params["w_gates"]).astype(jnp.float32) + params["g_bias"]

    def step(carry, xp_t):
        c, n, m, h = carry
        pre = xp_t + \
            (h.astype(xin.dtype) @ params["r_gates"]).astype(jnp.float32)
        li, lf, zg, og = jnp.split(pre, 4, axis=-1)
        lf = -jax.nn.softplus(-lf)                 # log sigmoid
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)
        f_ = jnp.exp(lf + m - m_new)
        c = f_ * c + i_ * jnp.tanh(zg)
        n = f_ * n + i_
        h_new = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h_new), h_new

    state, hs = jax.lax.scan(step, state, x_pre.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), state


def slstm_forward(params: Params, x: jax.Array, cfg: ModelConfig):
    B, S, d = x.shape
    dp, _, _ = _xlstm_dims(cfg)
    up = x @ params["w_up"]
    xin, z = jnp.split(up, 2, axis=-1)
    state = init_slstm_state(cfg, B)
    hs, state = _slstm_recurrence(params, xin, state, dp)
    y = hs.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["w_down"], state


def init_slstm_state(cfg: ModelConfig, batch: int):
    dp, _, _ = _xlstm_dims(cfg)
    z = jnp.zeros((batch, dp), jnp.float32)
    return (z, z, jnp.full((batch, dp), -1e30, jnp.float32), z)


def slstm_step(params: Params, x_t: jax.Array, state, cfg: ModelConfig):
    dp, _, _ = _xlstm_dims(cfg)
    up = x_t[:, 0] @ params["w_up"]
    xin, z = jnp.split(up, 2, axis=-1)
    hs, state = _slstm_recurrence(params, xin[:, None], state, dp)
    y = hs[:, 0].astype(x_t.dtype) * jax.nn.silu(z)
    return (y @ params["w_down"])[:, None], state
