"""Unified telemetry layer (ISSUE 8): clocks, spans, metrics, facade.

Import surface::

    from repro.obs import (Clock, ManualClock, MONOTONIC, as_clock,
                           Span, Tracer, NULL_TRACER,
                           MetricsRegistry, NULL_REGISTRY,
                           Telemetry, EventChannel, NULL_TELEMETRY,
                           make_telemetry)

``repro.obs`` deliberately imports nothing from the rest of the repo,
so core and backend modules can depend on it without cycles.
"""
from repro.obs.clock import (Clock, ManualClock, MonotonicClock, MONOTONIC,
                             as_clock)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               NULL_REGISTRY, DEFAULT_BUCKETS)
from repro.obs.trace import Span, Tracer, NullTracer, NULL_TRACER
from repro.obs.telemetry import (Telemetry, EventChannel, NULL_TELEMETRY,
                                 make_telemetry)

__all__ = [
    "Clock", "ManualClock", "MonotonicClock", "MONOTONIC", "as_clock",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NULL_REGISTRY",
    "DEFAULT_BUCKETS",
    "Span", "Tracer", "NullTracer", "NULL_TRACER",
    "Telemetry", "EventChannel", "NULL_TELEMETRY", "make_telemetry",
]
