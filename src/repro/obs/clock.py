"""Injectable time sources: the ONE clock surface for the repo.

Every timing-sensitive component (the :class:`~repro.obs.trace.Tracer`,
the coordinator's phase timers, :class:`repro.core.result_cache.
ResultCache` TTL expiry) reads time through a :class:`Clock` rather than
calling ``time.monotonic()`` / ``time.perf_counter()`` directly, so
tests can substitute a :class:`ManualClock` and make wall-clock
observables deterministic (zero, or exactly the scripted increments).

``MONOTONIC`` is the shared production default — a
:class:`MonotonicClock` over ``time.perf_counter`` (monotonic, highest
available resolution). :func:`as_clock` adapts bare ``() -> float``
callables (the seed-era ``ResultCache(clock=...)`` shape) onto the
protocol, so existing callers keep working unchanged.
"""
from __future__ import annotations

import time
from typing import Callable, Protocol, Union, runtime_checkable

__all__ = ["Clock", "MonotonicClock", "ManualClock", "MONOTONIC",
           "as_clock"]


@runtime_checkable
class Clock(Protocol):
    """A monotonic time source: ``now()`` returns seconds as a float.

    Only differences of ``now()`` values are meaningful (the epoch is
    arbitrary), exactly like ``time.monotonic``."""

    def now(self) -> float:
        """Current monotonic time in (fractional) seconds."""
        ...


class MonotonicClock:
    """The production clock: ``time.perf_counter`` behind the protocol."""

    def now(self) -> float:
        """Current ``time.perf_counter()`` reading."""
        return time.perf_counter()


class ManualClock:
    """A scripted clock for deterministic tests: time advances only via
    :meth:`advance` (or the per-read ``auto_step``), never on its own."""

    def __init__(self, start: float = 0.0, auto_step: float = 0.0):
        self._t = float(start)
        self.auto_step = float(auto_step)

    def now(self) -> float:
        """Current scripted time; advances by ``auto_step`` per read."""
        t = self._t
        self._t += self.auto_step
        return t

    def advance(self, dt: float) -> None:
        """Move the clock forward by ``dt`` seconds (must be >= 0)."""
        if dt < 0:
            raise ValueError(f"clocks are monotonic; cannot advance by {dt}")
        self._t += dt


class _CallableClock:
    """Adapter wrapping a bare ``() -> float`` callable (seed-era
    ``ResultCache(clock=...)`` signatures) onto the :class:`Clock`
    protocol."""

    def __init__(self, fn: Callable[[], float]):
        self._fn = fn

    def now(self) -> float:
        """The wrapped callable's current reading."""
        return float(self._fn())


#: Shared production clock instance (stateless — safe to share).
MONOTONIC = MonotonicClock()


def as_clock(clock: Union[Clock, Callable[[], float], None]) -> Clock:
    """Normalize a clock argument: ``None`` -> :data:`MONOTONIC`,
    :class:`Clock` implementations pass through, bare callables are
    wrapped. Anything else raises ``TypeError``."""
    if clock is None:
        return MONOTONIC
    if isinstance(clock, Clock):
        return clock
    if callable(clock):
        return _CallableClock(clock)
    raise TypeError(f"not a clock or callable: {clock!r}")
