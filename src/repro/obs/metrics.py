"""Typed metrics registry: counters, gauges, and fixed-bucket histograms.

The :class:`MetricsRegistry` is the single home for the repo's workload
counters (ISSUE 8): the per-query quantities that used to live strewn
across ``ExecutedQuery`` fields, ``coordinator.stats``, and mesh
``device_stats`` all accumulate here when telemetry is on, and
``repro.backend.base.workload_summary`` is *implemented* on top of a
fresh registry — so registry totals and summary values agree bit for
bit by construction.

Instrument types:

  * :class:`Counter` — monotonically accumulating numbers (``inc``).
    Counters named exactly as ``workload_summary`` keys carry the
    summary's values; an optional *emission group* reproduces the
    summary's conditional keys (``measured_*`` only when a backend
    measured, ``mqo_*`` only when MQO engaged, ...): a grouped counter
    appears in :meth:`MetricsRegistry.as_summary` only once its group
    was marked via :meth:`MetricsRegistry.mark_group`.
  * :class:`Gauge` — last-written point-in-time values (``set``), with
    optional labels (e.g. ``gauge("cache.budget_utilization", node=3)``)
    for per-node series.
  * :class:`Histogram` — fixed bucket bounds chosen at creation;
    ``observe`` increments exactly one bucket (the first bound >= the
    observation, else the overflow bucket), so bucket counts always sum
    to the observation count (a hypothesis-checked invariant).

``NULL_REGISTRY`` is the telemetry-off no-op: every accessor returns a
shared do-nothing instrument, so instrumented call sites stay branch-free
and allocate nothing on the hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "NULL_REGISTRY", "DEFAULT_BUCKETS"]

#: Default histogram bucket upper bounds (powers of two — a generic
#: count-shaped distribution; pass explicit bounds for anything else).
DEFAULT_BUCKETS: Tuple[float, ...] = (1, 2, 4, 8, 16, 32, 64, 128, 256)


@dataclasses.dataclass
class Counter:
    """A monotonically accumulating value. ``group`` ties the counter to
    an emission group for :meth:`MetricsRegistry.as_summary` (``None``
    = always emitted)."""

    name: str
    group: Optional[str] = None
    value: float = 0

    def inc(self, v: float = 1) -> None:
        """Accumulate ``v`` (negative increments are rejected — use a
        :class:`Gauge` for values that can go down)."""
        if v < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(inc by {v})")
        self.value += v


@dataclasses.dataclass
class Gauge:
    """A last-written point-in-time value, optionally labeled."""

    name: str
    labels: Tuple[Tuple[str, object], ...] = ()
    value: float = 0.0

    def set(self, v: float) -> None:
        """Overwrite the gauge with the current reading."""
        self.value = float(v)


class Histogram:
    """Fixed-bound bucket histogram: ``bounds[i]`` is bucket ``i``'s
    inclusive upper edge; one extra overflow bucket catches everything
    above the last bound. ``sum(bucket_counts) == count`` always."""

    def __init__(self, name: str,
                 bounds: Tuple[float, ...] = DEFAULT_BUCKETS):
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError(f"histogram {name!r} needs ascending, "
                             f"non-empty bucket bounds, got {bounds!r}")
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        self.bucket_counts: List[int] = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        """Record one observation into exactly one bucket."""
        v = float(v)
        self.count += 1
        self.sum += v
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1


class MetricsRegistry:
    """Get-or-create home for named instruments.

    A name maps to exactly one instrument kind — re-requesting it with a
    different kind (or a histogram with different bounds) raises, which
    is what keeps the naming convention honest across subsystems."""

    def __init__(self) -> None:
        self._counters: "Dict[str, Counter]" = {}
        self._gauges: Dict[Tuple[str, Tuple], Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._marked: set = set()

    # ------------------------------------------------------- instruments

    def counter(self, name: str, group: Optional[str] = None) -> Counter:
        """The counter named ``name`` (created on first use). A counter's
        emission group is fixed at creation; passing a different one
        later raises."""
        c = self._counters.get(name)
        if c is None:
            self._check_free(name, self._counters)
            c = self._counters[name] = Counter(name, group)
        elif group is not None and c.group != group:
            raise ValueError(f"counter {name!r} already registered in "
                             f"group {c.group!r}, not {group!r}")
        return c

    def gauge(self, name: str, **labels: object) -> Gauge:
        """The gauge named ``name`` with the given labels (created on
        first use); each distinct label set is its own series."""
        key = (name, tuple(sorted(labels.items())))
        g = self._gauges.get(key)
        if g is None:
            if not labels:
                self._check_free(name, {k[0]: 1 for k in self._gauges})
            g = self._gauges[key] = Gauge(name, key[1])
        return g

    def histogram(self, name: str,
                  bounds: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        """The histogram named ``name`` (created on first use with
        ``bounds``; later calls must agree on the bounds)."""
        h = self._histograms.get(name)
        if h is None:
            self._check_free(name, self._histograms)
            h = self._histograms[name] = Histogram(name, bounds)
        elif h.bounds != tuple(float(b) for b in bounds):
            raise ValueError(f"histogram {name!r} already registered with "
                             f"bounds {h.bounds}, not {bounds}")
        return h

    def _check_free(self, name: str, own: Dict) -> None:
        """Reject a name already claimed by a different instrument kind."""
        kinds = {"counter": self._counters,
                 "gauge": {k[0]: 1 for k in self._gauges},
                 "histogram": self._histograms}
        for kind, table in kinds.items():
            if table is own:
                continue
            if name in table:
                raise ValueError(f"name {name!r} already registered as a "
                                 f"{kind}")

    # ---------------------------------------------------------- emission

    def mark_group(self, group: str) -> None:
        """Mark an emission group present: its counters appear in
        :meth:`as_summary` from now on (the registry equivalent of
        ``workload_summary``'s ``any(field is not None)`` guards)."""
        self._marked.add(group)

    def group_marked(self, group: str) -> bool:
        """Whether an emission group has been marked present."""
        return group in self._marked

    def as_summary(self) -> Dict[str, float]:
        """The counter view ``workload_summary`` is built from: every
        ungrouped counter plus the counters of marked groups, as
        ``name -> float(value)`` in registration order."""
        return {c.name: float(c.value) for c in self._counters.values()
                if c.group is None or c.group in self._marked}

    def as_dict(self) -> Dict[str, object]:
        """Full snapshot for reports/debugging: every counter (grouped or
        not), gauge series, and histogram state."""
        return {
            "counters": {c.name: {"value": c.value, "group": c.group}
                         for c in self._counters.values()},
            "gauges": [{"name": g.name, "labels": dict(g.labels),
                        "value": g.value} for g in self._gauges.values()],
            "histograms": {h.name: {"bounds": list(h.bounds),
                                    "bucket_counts": list(h.bucket_counts),
                                    "count": h.count, "sum": h.sum}
                           for h in self._histograms.values()},
        }


class _NullInstrument:
    """Shared do-nothing counter/gauge/histogram for telemetry-off mode."""

    name = ""
    group = None
    value = 0.0
    labels = ()

    def inc(self, v: float = 1) -> None:
        """No-op."""

    def set(self, v: float) -> None:
        """No-op."""

    def observe(self, v: float) -> None:
        """No-op."""


class _NullRegistry(MetricsRegistry):
    """Telemetry-off registry: every accessor returns one shared no-op
    instrument and nothing is ever recorded or allocated."""

    _NULL = _NullInstrument()

    def counter(self, name: str, group: Optional[str] = None):
        """The shared no-op instrument."""
        return self._NULL

    def gauge(self, name: str, **labels: object):
        """The shared no-op instrument."""
        return self._NULL

    def histogram(self, name: str, bounds: Tuple[float, ...] = ()):
        """The shared no-op instrument."""
        return self._NULL

    def mark_group(self, group: str) -> None:
        """No-op."""


#: Shared telemetry-off registry (stateless — safe to share globally).
NULL_REGISTRY = _NullRegistry()
