"""The telemetry facade threaded through coordinator, planner, backends.

:class:`Telemetry` bundles the three observability primitives — an
injectable :class:`~repro.obs.clock.Clock`, a
:class:`~repro.obs.trace.Tracer`, and a
:class:`~repro.obs.metrics.MetricsRegistry` — behind one object that
the cluster constructs once and every layer shares.  ``telemetry="off"``
(the seed-parity default) yields :data:`NULL_TELEMETRY`: the no-op
tracer and registry, so instrumented call sites cost a method call and
nothing else.

:class:`EventChannel` is the typed replacement for the coordinator's
ad-hoc ``_pending_exec`` dict (PR 7's replication/failover drain
channel): policy rounds *post* counter deltas keyed by summary-counter
name, and the next executed query *drains* them into its
``ExecutedQuery`` fields.  The channel also mirrors every post into
``events.*`` registry counters (an unmarked emission group, so the
mirror never leaks into ``as_summary``), and ``workload_summary``
surfaces anything still pending after the last query — the ISSUE 8
satellite fix for events that previously vanished.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Union

from repro.obs.clock import Clock, MONOTONIC, as_clock
from repro.obs.metrics import MetricsRegistry, NULL_REGISTRY
from repro.obs.trace import NullTracer, Tracer, NULL_TRACER

__all__ = ["Telemetry", "EventChannel", "NULL_TELEMETRY", "make_telemetry"]


class Telemetry:
    """One shared bundle of clock + tracer + registry.

    ``mode`` is ``"on"`` or ``"off"``; off mode swaps in the shared
    no-op tracer/registry while keeping the (real or injected) clock, so
    phase timings in reports stay seed-identical either way."""

    def __init__(self, mode: str = "on",
                 clock: Union[Clock, Callable[[], float], None] = None,
                 pid: int = 0):
        if mode not in ("on", "off"):
            raise ValueError(f"telemetry mode must be 'on' or 'off', "
                             f"got {mode!r}")
        self.mode = mode
        self.clock = as_clock(clock)
        if mode == "on":
            self.tracer: Union[Tracer, NullTracer] = Tracer(
                clock=self.clock, pid=pid)
            self.registry: MetricsRegistry = MetricsRegistry()
        else:
            self.tracer = NULL_TRACER
            self.registry = NULL_REGISTRY

    @property
    def enabled(self) -> bool:
        """Whether spans/metrics are actually recorded (``mode == "on"``)."""
        return self.mode == "on"

    def export_trace(self, path: str) -> str:
        """Write the tracer's Chrome trace JSON to ``path`` (see
        :meth:`repro.obs.trace.Tracer.export`); returns ``path``."""
        import json
        with open(path, "w") as fh:
            json.dump(self.tracer.to_chrome_trace(), fh)
        return path


class _OffTelemetry(Telemetry):
    """The shared telemetry-off singleton behind ``telemetry="off"``."""

    def __init__(self) -> None:
        super().__init__(mode="off", clock=MONOTONIC)


#: Shared telemetry-off bundle (no-op tracer + registry, real clock).
NULL_TELEMETRY = _OffTelemetry()


def make_telemetry(
        spec: Union[str, Telemetry, None]) -> Telemetry:
    """Normalize a user-facing ``telemetry=`` knob: ``"off"``/``None`` ->
    :data:`NULL_TELEMETRY`, ``"on"`` -> a fresh live :class:`Telemetry`,
    an existing :class:`Telemetry` passes through unchanged."""
    if spec is None or spec == "off":
        return NULL_TELEMETRY
    if isinstance(spec, Telemetry):
        return spec
    if spec == "on":
        return Telemetry(mode="on")
    raise ValueError(f"telemetry must be 'on', 'off', or a Telemetry "
                     f"instance, got {spec!r}")


class EventChannel:
    """Pending counter deltas between policy rounds and executed queries.

    Policy rounds (replication, failover recovery) happen between
    queries, but their counters belong on ``ExecutedQuery`` records.
    The channel buffers them: :meth:`post` accumulates a delta under a
    summary-counter name, :meth:`drain` hands the buffered dict to the
    next executed query and empties the channel.  Every post is also
    mirrored into the registry as an ``events.<key>`` counter (group
    ``"events"`` — intentionally never marked, so mirrors stay out of
    ``as_summary`` and exist purely for live inspection)."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self._pending: Dict[str, float] = {}
        self._registry = registry if registry is not None else NULL_REGISTRY

    def post(self, key: str, value: float = 1) -> None:
        """Buffer ``value`` under ``key`` (accumulating with any pending
        delta for the same key) and mirror it to ``events.<key>``."""
        self._pending[key] = self._pending.get(key, 0) + value
        self._registry.counter(f"events.{key}", group="events").inc(value)

    def drain(self) -> Dict[str, float]:
        """All pending deltas, emptying the channel."""
        out = self._pending
        self._pending = {}
        return out

    def peek(self) -> Dict[str, float]:
        """A copy of the pending deltas without draining them."""
        return dict(self._pending)

    def empty(self) -> bool:
        """Whether nothing is pending."""
        return not self._pending
