"""Span tracing with Chrome trace-event JSON export (Perfetto-loadable).

A :class:`Tracer` produces nested, *explicitly parented* :class:`Span`
records over an injectable monotonic :class:`~repro.obs.clock.Clock`.
The span taxonomy instrumented across the repo (ARCHITECTURE.md
"Telemetry"):

  ``workload`` > ``batch`` / ``query`` > ``query.rewrite``,
  ``plan.scan``, ``policy.evict``, ``policy.place``,
  ``policy.replicate``, ``ship``, ``prep``, ``dispatch`` — plus
  ``recover`` around a simulated node-failure round.

Parenting is explicit: every span records its parent's id (the
innermost open span on the same logical thread at begin time, or an
explicit ``parent=`` override), so nesting invariants are testable on
the span records themselves rather than inferred from timestamps.

:meth:`Tracer.to_chrome_trace` renders the spans as Chrome trace-event
JSON ("X" complete events, microsecond timestamps) wrapped in the
``{"traceEvents": [...]}`` object format — drag the written file into
https://ui.perfetto.dev or ``chrome://tracing`` to see the timeline.

``NULL_TRACER`` is the telemetry-off tracer: :meth:`~NullTracer.span`
returns one shared no-op context manager, so instrumented call sites
cost a method call and nothing else when tracing is off.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

from repro.obs.clock import Clock, MONOTONIC

__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER"]


@dataclasses.dataclass
class Span:
    """One traced operation: a named interval with explicit parentage.

    ``start``/``end`` are raw clock readings (seconds; ``end`` is
    ``None`` while the span is open); ``parent_id`` is ``None`` only for
    root spans. ``args`` carries small key-value annotations (node ids,
    batch sizes) rendered into the trace event's ``args``."""

    span_id: int
    name: str
    start: float
    cat: str = "phase"
    tid: int = 0
    parent_id: Optional[int] = None
    end: Optional[float] = None
    args: Optional[Dict[str, object]] = None

    @property
    def duration_s(self) -> float:
        """The span's duration in seconds (0.0 while still open)."""
        return 0.0 if self.end is None else self.end - self.start


class _SpanContext:
    """Context manager closing one span on exit (re-entrant per span)."""

    __slots__ = ("_tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self.span = span

    def __enter__(self) -> Span:
        return self.span

    def __exit__(self, *exc) -> None:
        self._tracer.end(self.span)


class Tracer:
    """Collects spans over an injectable clock; exports Chrome trace JSON.

    Single-threaded by design (the repo's pipelines are synchronous):
    one open-span stack provides the implicit parent; ``parent=``
    overrides it for explicitly re-parented spans."""

    enabled = True

    def __init__(self, clock: Optional[Clock] = None, pid: int = 0):
        self.clock = clock if clock is not None else MONOTONIC
        self.pid = pid
        self.spans: List[Span] = []       # every begun span, begin order
        self._stack: List[Span] = []      # open spans, innermost last
        self._next_id = 1

    # ------------------------------------------------------------ spans

    def begin(self, name: str, cat: str = "phase", tid: int = 0,
              parent: Optional[Span] = None, **args: object) -> Span:
        """Open a span: parented to ``parent`` if given, else to the
        innermost currently-open span (``None`` at top level)."""
        pid = parent.span_id if parent is not None else (
            self._stack[-1].span_id if self._stack else None)
        span = Span(span_id=self._next_id, name=name,
                    start=self.clock.now(), cat=cat, tid=tid,
                    parent_id=pid, args=dict(args) if args else None)
        self._next_id += 1
        self.spans.append(span)
        self._stack.append(span)
        return span

    def end(self, span: Span) -> None:
        """Close a span (and any still-open descendants of it — spans
        close innermost-first, so a leaked child cannot outlive its
        parent in the record)."""
        while self._stack:
            top = self._stack.pop()
            top.end = self.clock.now()
            if top is span:
                return
        if span.end is None:              # closed out of stack order
            span.end = self.clock.now()

    def span(self, name: str, cat: str = "phase", tid: int = 0,
             parent: Optional[Span] = None, **args: object) -> _SpanContext:
        """``with tracer.span("plan.scan"): ...`` — begin/end around a
        block; returns a context manager yielding the open :class:`Span`."""
        return _SpanContext(self, self.begin(name, cat=cat, tid=tid,
                                             parent=parent, **args))

    # ------------------------------------------------------------ export

    def to_chrome_trace(self) -> Dict[str, object]:
        """The collected spans as a Chrome trace-event JSON object
        (``{"traceEvents": [...]}``, "X" complete events, microsecond
        timestamps normalized to the earliest span). Loadable in
        Perfetto and ``chrome://tracing``; open spans are exported with
        zero duration."""
        t0 = min((s.start for s in self.spans), default=0.0)
        events: List[Dict[str, object]] = [{
            "ph": "M", "pid": self.pid, "tid": 0, "name": "process_name",
            "args": {"name": "repro-raw-array-cache"},
        }]
        for s in self.spans:
            args: Dict[str, object] = {"span_id": s.span_id}
            if s.parent_id is not None:
                args["parent_id"] = s.parent_id
            if s.args:
                args.update(s.args)
            events.append({
                "ph": "X", "name": s.name, "cat": s.cat,
                "pid": self.pid, "tid": s.tid,
                "ts": (s.start - t0) * 1e6,
                "dur": max(s.duration_s, 0.0) * 1e6,
                "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write :meth:`to_chrome_trace` to ``path`` as JSON; returns
        ``path`` (convention: name it ``*.trace.json``)."""
        with open(path, "w") as fh:
            json.dump(self.to_chrome_trace(), fh)
        return path


class _NullSpanContext:
    """The shared no-op span context manager (telemetry off)."""

    __slots__ = ()
    span = None

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> None:
        return None


_NULL_CONTEXT = _NullSpanContext()


class NullTracer:
    """Telemetry-off tracer: every call is a no-op returning shared
    singletons — no span objects, no clock reads, no list growth."""

    enabled = False
    spans: List[Span] = []

    def begin(self, name: str, cat: str = "phase", tid: int = 0,
              parent: Optional[Span] = None, **args: object) -> None:
        """No-op; returns ``None``."""
        return None

    def end(self, span) -> None:
        """No-op."""

    def span(self, name: str, cat: str = "phase", tid: int = 0,
             parent: Optional[Span] = None, **args: object):
        """The shared no-op context manager."""
        return _NULL_CONTEXT

    def to_chrome_trace(self) -> Dict[str, object]:
        """An empty (but well-formed) trace object."""
        return {"traceEvents": [], "displayTimeUnit": "ms"}


#: Shared telemetry-off tracer (stateless — safe to share globally).
NULL_TRACER = NullTracer()
