"""Serving: paged KV cache (paper-cost eviction/placement), decode engine."""
