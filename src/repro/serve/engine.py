"""Batched serving engine: admission, prefix-cached prefill, decode loop.

The engine runs a reduced model end-to-end on CPU (examples/tests) while the
``PagedKVCacheManager`` tracks logical pages with the paper's cost-based
eviction; ``recompute_tokens`` from the manager decides how much prefill is
actually executed — the measurable win of the caching policy (benchmarked in
benchmarks/bench_prefix_cache.py). Decode uses the model's dense per-slot KV
cache; the paged-attention Pallas kernel is the TPU execution path for the
same page tables (validated in tests/test_kernels_paged.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import decode_step, forward, init_decode_state
from repro.serve.kvcache import PagedKVCacheManager


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    prefill_tokens_executed: int = 0


@dataclasses.dataclass
class EngineStats:
    requests: int = 0
    prompt_tokens: int = 0
    prefill_executed: int = 0
    prefill_saved: int = 0
    decode_steps: int = 0


class ServingEngine:
    """Slot-batched greedy-decode engine over a reduced config."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 128, page_size: int = 16,
                 cache_budget_pages: int = 64, policy: str = "cost"):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        kv_layers = sum(1 for m, _ in cfg.layer_pattern if m == "attn") * \
            cfg.n_periods
        page_bytes = max(1, 2 * page_size * cfg.n_kv_heads *
                         cfg.resolved_head_dim * 2 * kv_layers)
        self.manager = PagedKVCacheManager(
            page_size=page_size, budget_bytes=cache_budget_pages * page_bytes,
            page_bytes=page_bytes, policy=policy)
        self.stats = EngineStats()
        self._decode = jax.jit(
            lambda p, t, s, pos: decode_step(p, cfg, t, s, pos))

    def _prefill_into_slot(self, state, slot: int, tokens: Sequence[int],
                           start: int) -> None:
        """Run tokens [start:] through the decode path to build slot KV."""
        for t in range(start, len(tokens)):
            tok = jnp.full((self.slots, 1), 0, jnp.int32).at[slot, 0].set(
                tokens[t])
            pos = jnp.zeros((self.slots,), jnp.int32).at[slot].set(t)
            logits, new_state = self._decode(self.params, tok, state["kv"],
                                             pos)
            state["kv"] = _merge_slot(state["kv"], new_state, slot)
        state["next_logits"][slot] = None

    def run(self, requests: Sequence[Request]) -> List[Request]:
        """Serve requests through ``slots`` concurrent decode lanes."""
        queue = list(requests)
        done: List[Request] = []
        state = {"kv": init_decode_state(self.cfg, self.slots, self.max_len),
                 "next_logits": [None] * self.slots}
        active: List[Optional[Request]] = [None] * self.slots
        lengths = np.zeros(self.slots, np.int32)

        while queue or any(a is not None for a in active):
            # Admission.
            for s in range(self.slots):
                if active[s] is None and queue:
                    req = queue.pop(0)
                    alloc = self.manager.allocate(req.request_id, req.prompt)
                    cached_tokens = len(req.prompt) - alloc.recompute_tokens
                    self.stats.requests += 1
                    self.stats.prompt_tokens += len(req.prompt)
                    self.stats.prefill_saved += cached_tokens
                    self.stats.prefill_executed += alloc.recompute_tokens
                    req.prefill_tokens_executed = alloc.recompute_tokens
                    # NOTE: the dense slot cache cannot splice cached pages,
                    # so the slot replays the prompt; the *accounting* of
                    # skipped prefill comes from the manager (benchmarked),
                    # and the paged kernel is the zero-replay TPU path.
                    self._prefill_into_slot(state, s, req.prompt,
                                            start=0)
                    lengths[s] = len(req.prompt)
                    active[s] = req
            # One batched decode step for all active slots.
            toks = np.zeros((self.slots, 1), np.int32)
            poss = np.maximum(lengths - 1, 0).astype(np.int32)
            for s, req in enumerate(active):
                if req is not None:
                    last = (req.generated[-1] if req.generated
                            else req.prompt[-1])
                    toks[s, 0] = last
            logits, state["kv"] = self._decode(
                self.params, jnp.asarray(toks), state["kv"],
                jnp.asarray(poss))
            self.stats.decode_steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for s, req in enumerate(active):
                if req is None:
                    continue
                req.generated.append(int(nxt[s]))
                lengths[s] += 1
                if (len(req.generated) >= req.max_new_tokens or
                        lengths[s] >= self.max_len):
                    done.append(req)
                    active[s] = None
        return done


def _merge_slot(old, new, slot: int):
    """Keep only ``slot``'s lane from the new state (other lanes unchanged)."""
    def merge(o, n):
        if o.ndim >= 2 and o.shape[1] == n.shape[1]:
            # (P, B, ...) states: select batch lane.
            mask_shape = [1] * o.ndim
            mask_shape[1] = o.shape[1]
            mask = jnp.arange(o.shape[1]).reshape(mask_shape) == slot
            return jnp.where(mask, n, o)
        return n
    return jax.tree.map(merge, old, new)
