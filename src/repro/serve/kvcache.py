"""Paged KV cache management with the paper's cost model (beyond-paper
integration, DESIGN.md §2).

Mapping onto §3 of the paper:
  * chunk  -> KV page (``page_size`` tokens of one request's prefix)
  * file   -> a request's full prefix: a miss on *any* page of a retained
              prefix forces recomputing the *whole* prefill — exactly the
              "one uncached chunk => full file scan" structure that makes
              chunk-LRU suboptimal for raw arrays (§3.3)
  * query  -> a serving request (weighted by recency, decayed like w_Q)
  * placement -> assigning requests to replica groups so shared prefix
              pages are co-resident (Alg. 3 over the sharing relation)

Adaptation note (DESIGN.md §7): Alg. 2's *triple* granularity (keep all of a
query's chunks or none) degenerates in serving whenever the byte budget is
smaller than one request's working set — the greedy then thrashes between
whole requests and shared prefixes never survive. The serving cost is
therefore evaluated per *page* with the same exponential query decay:

    score(page) = sum_r  decay^(l_r - l_now) * (1 + prefix_position_r)

where prefix_position upweights early pages (losing them invalidates the
longest usable prefix — the analogue of "one miss => full file scan"). The
verbatim Alg. 2 runs in the input pipeline (repro.data) where query working
sets fit; decay defaults to 1.3 here (frequency matters more than recency
for prefix reuse). Prefix sharing is content-addressed: page key =
hash(tokens up to the page end).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.eviction import LRUCache, Triple, cost_based_eviction
from repro.core.placement import JoinRecord, cost_based_placement


def _prefix_hashes(tokens: Sequence[int], page_size: int) -> List[str]:
    out = []
    h = hashlib.sha1()
    for i in range(0, len(tokens) - len(tokens) % page_size, page_size):
        h.update(bytes(str(list(tokens[i:i + page_size])), "ascii"))
        out.append(h.hexdigest()[:16])
    return out


@dataclasses.dataclass
class PageMeta:
    page_id: int
    key: str                      # content hash (prefix-closed)
    nbytes: int


@dataclasses.dataclass
class AllocResult:
    page_ids: List[int]
    hit_pages: int                # served from cache (prefill skipped)
    new_pages: int
    evicted_pages: List[int]
    recompute_tokens: int         # prefill tokens actually recomputed


class PagedKVCacheManager:
    """Content-addressed page pool under a byte budget with cost-based or
    LRU eviction."""

    def __init__(self, *, page_size: int, budget_bytes: int,
                 page_bytes: int, policy: str = "cost", decay: float = 1.3):
        assert policy in ("cost", "lru")
        self.page_size = page_size
        self.budget = budget_bytes
        self.page_bytes = page_bytes
        self.policy = policy
        self.decay = decay
        self._next_id = 0
        self.by_key: Dict[str, PageMeta] = {}
        self.by_id: Dict[int, PageMeta] = {}
        self.history: List[Triple] = []      # (request idx, req id, pages)
        self.request_count = 0
        self.lru = LRUCache(budget_bytes)
        self.share_pairs: List[JoinRecord] = []

    # ---------------------------------------------------------- allocation

    def _new_page(self, key: str) -> PageMeta:
        meta = PageMeta(self._next_id, key, self.page_bytes)
        self._next_id += 1
        self.by_key[key] = meta
        self.by_id[meta.page_id] = meta
        return meta

    def allocate(self, request_id: int, tokens: Sequence[int]) -> AllocResult:
        """Admit a request's prompt; returns its page list and what must be
        recomputed. Eviction runs after admission (the current request is
        always resident, like the current query in Alg. 2)."""
        self.request_count += 1
        l = self.request_count
        keys = _prefix_hashes(tokens, self.page_size)
        page_ids: List[int] = []
        hits = 0
        shared_with: Set[int] = set()
        for k in keys:
            meta = self.by_key.get(k)
            if meta is not None and self._resident(meta.page_id):
                hits += 1
            elif meta is None:
                meta = self._new_page(k)
            page_ids.append(meta.page_id)
        # A prefix is usable only up to the first non-resident page: pages
        # after a miss must be recomputed even if individually cached.
        usable = 0
        for pid in page_ids:
            if self._resident(pid):
                usable += 1
            else:
                break
        recompute = (len(keys) - usable) * self.page_size + \
            len(tokens) % self.page_size

        evicted = self._admit(l, request_id, page_ids)
        # Sharing relation for placement: pages reused across requests.
        for t in self.history[-8:]:
            common = set(page_ids) & t.chunk_ids
            if common and t.file_id != request_id:
                shared_with.add(t.file_id)
        self.history.append(Triple(l, request_id, frozenset(page_ids)))
        if len(self.history) > 256:
            self.history = self.history[-256:]
        return AllocResult(page_ids=page_ids, hit_pages=hits,
                           new_pages=len(keys) - hits,
                           evicted_pages=evicted,
                           recompute_tokens=recompute)

    def _resident(self, page_id: int) -> bool:
        if self.policy == "lru":
            return page_id in self.lru
        return page_id in self._resident_set

    # --------------------------------------------------------- eviction ---

    @property
    def _resident_set(self) -> Set[int]:
        if not hasattr(self, "_res"):
            self._res: Set[int] = set()
        return self._res

    def _admit(self, l: int, request_id: int,
               page_ids: List[int]) -> List[int]:
        if self.policy == "lru":
            evicted: List[int] = []
            for pid in page_ids:
                evicted.extend(self.lru.admit(pid, self.page_bytes))
                self.lru.touch(pid)
            return evicted
        # Page-granular decayed-frequency score with a prefix-position term
        # (see module docstring for why Alg. 2's triple granularity is
        # adapted here).
        scores: Dict[int, float] = {}

        def credit(qidx: int, pages) -> None:
            n = len(pages)
            for k, pid in enumerate(pages):
                w = self.decay ** (qidx - l) * (1.0 + (n - k) / max(n, 1))
                scores[pid] = scores.get(pid, 0.0) + w

        for t in self.history:
            credit(t.query_index, sorted(t.chunk_ids))
        credit(l, page_ids)
        candidates = set(self._resident_set) | set(page_ids)
        max_pages = max(1, self.budget // self.page_bytes)
        keep = sorted(candidates, key=lambda p: -scores.get(p, 0.0)
                      )[:max_pages]
        before = self._resident_set
        self._res = set(keep)
        return sorted(before - self._res)

    # --------------------------------------------------------- placement --

    def assign_replica_groups(self, n_groups: int,
                              group_budget_bytes: int) -> Dict[int, int]:
        """Place resident pages onto serving replica groups, co-locating
        pages shared across recent requests (Alg. 3)."""
        resident = (self.lru.ids() if self.policy == "lru"
                    else set(self._resident_set))
        pairs = []
        for t in self.history[-32:]:
            pages = sorted(p for p in t.chunk_ids if p in resident)
            pairs.append(JoinRecord(t.query_index,
                                    tuple((a, b) for i, a in enumerate(pages)
                                          for b in pages[i + 1:])))
        replicas = {p: set(range(n_groups)) for p in resident}
        bytes_ = {p: self.page_bytes for p in resident}
        budgets = {g: group_budget_bytes for g in range(n_groups)}
        res = cost_based_placement(pairs, replicas, bytes_, budgets,
                                   self.decay)
        return res.locations

    # ------------------------------------------------------------- stats --

    @property
    def resident_bytes(self) -> int:
        if self.policy == "lru":
            return self.lru.used_bytes
        return len(self._resident_set) * self.page_bytes
