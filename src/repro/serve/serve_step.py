"""Serve-step construction: batched single-token decode and prefill.

``serve_step``: (params, tokens (B,1), state, pos (B,)) ->
(next_tokens (B,1), logits_last, state'). Greedy argmax keeps the dry-run
output small; the engine layer does real sampling on host.

``prefill_step``: full forward returning last-position logits — the compute
shape of serving prefill (KV-cache writes are modeled by the decode path)."""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import decode_step, forward

PyTree = Any


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params: PyTree, tokens: jax.Array, state: PyTree,
                   pos: jax.Array):
        logits, state = decode_step(params, cfg, tokens, state, pos)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(
            jnp.int32)[:, None]
        return next_tokens, state
    return serve_step


def make_prefill_step(cfg: ModelConfig, attention_impl: str = "auto"
                      ) -> Callable:
    def prefill_step(params: PyTree, tokens=None, embeds=None):
        logits, _ = forward(params, cfg, tokens=tokens, embeds=embeds,
                            attention_impl=attention_impl, remat=True)
        if cfg.encoder_only:
            return logits          # encoder: per-frame outputs
        return logits[:, -1]       # decoder prefill: next-token logits
    return prefill_step
