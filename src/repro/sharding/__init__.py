"""Partition rules: TP/FSDP/DP/EP/SP sharding specs."""
