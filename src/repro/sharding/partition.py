"""Sharding rules: parameter/activation PartitionSpecs for the production
meshes.

Parallelism (DESIGN.md §5):
  * TP (Megatron-style) over the ``model`` axis: attention QKV column- /
    O row-parallel, MLP in/out column/row, MoE expert-parallel (expert axis
    over ``model``), Mamba inner channels over ``model``, vocab-parallel
    embeddings.
  * DP over ``('pod','data')`` for batches.
  * FSDP (param + optimizer-state sharding) over the DP axes for models
    above ``fsdp_threshold`` parameters.

Any rule whose dimension is not divisible by the mesh-axis size silently
degrades to replication for that dimension (e.g. internvl2's 92,553 vocab is
not divisible by 16 -> embedding stays replicated). The dry-run prints the
per-leaf result so degradations are visible, not silent.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    tp_axis: str = "model"
    dp_axes: Tuple[str, ...] = ("data",)
    fsdp: bool = False
    # Dry-run-only knob: decode KV/sequence sharding axes.
    seq_axes: Tuple[str, ...] = ("model",)

    @property
    def fsdp_axes(self) -> Optional[Tuple[str, ...]]:
        return self.dp_axes if self.fsdp else None


def make_policy(cfg: ModelConfig, mesh: Mesh,
                fsdp_threshold: float = 5e9) -> ShardingPolicy:
    axes = list(mesh.axis_names)
    dp = tuple(a for a in axes if a in ("pod", "data"))
    fsdp = cfg.param_count() > fsdp_threshold
    return ShardingPolicy(tp_axis="model", dp_axes=dp, fsdp=fsdp)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


class RuleContext:
    def __init__(self, mesh: Mesh, policy: ShardingPolicy):
        self.mesh = mesh
        self.policy = policy

    def fit(self, axes, dim: int):
        """Return axes if they evenly divide dim, else None (replicate)."""
        if axes is None:
            return None
        if dim % _axis_size(self.mesh, axes) != 0:
            return None
        return axes


# Parameter rules: (path regex, lambda(shape, ctx) -> PartitionSpec entries
# for the *unstacked* param). Stacked block leaves get None prepended.
def _param_spec(path: str, shape: Tuple[int, ...], ctx: RuleContext) -> P:
    tp = ctx.policy.tp_axis
    f = ctx.policy.fsdp_axes
    leaf = path.rsplit("/", 1)[-1]

    def fit(axes, dim):
        return ctx.fit(axes, dim)

    if leaf == "table":                               # embed/unembed/head
        return P(fit(tp, shape[0]), fit(f, shape[1]))
    if leaf in ("wq", "wk", "wv"):
        return P(fit(f, shape[0]), fit(tp, shape[1]))
    if leaf in ("bq", "bk", "bv"):
        return P(fit(tp, shape[0]))
    if leaf == "wo":
        return P(fit(tp, shape[0]), fit(f, shape[1]))
    if leaf == "router":
        return P(fit(f, shape[0]), None)
    if leaf in ("w_in", "w_gate"):
        if len(shape) == 3:                           # MoE (E, d, de)
            return P(fit(tp, shape[0]), fit(f, shape[1]), None)
        return P(fit(f, shape[0]), fit(tp, shape[1]))
    if leaf == "w_out":
        if len(shape) == 3:                           # MoE (E, de, d)
            return P(fit(tp, shape[0]), None, fit(f, shape[2]))
        return P(fit(tp, shape[0]), fit(f, shape[1]))
    if leaf in ("sh_in", "sh_gate"):
        return P(fit(f, shape[0]), fit(tp, shape[1]))
    if leaf == "sh_out":
        return P(fit(tp, shape[0]), fit(f, shape[1]))
    # Mamba.
    if leaf == "conv_w":
        return P(None, fit(tp, shape[1]))
    if leaf in ("conv_b", "dt_bias", "D"):
        return P(fit(tp, shape[0]))
    if leaf in ("w_dt_down", "w_bc", "A_log"):
        return P(fit(tp, shape[0]), None)
    if leaf == "w_dt_up":
        return P(None, fit(tp, shape[1]))
    # xLSTM.
    if leaf == "w_up":
        return P(fit(f, shape[0]), fit(tp, shape[1]))
    if leaf in ("w_gates", "r_gates"):
        return P(None, fit(tp, shape[1]))
    if leaf in ("g_bias",):
        return P(fit(tp, shape[0]))
    if leaf == "w_if":
        return P(fit(tp, shape[0]), None)
    if leaf == "if_bias":
        return P(None)
    if leaf == "w_down":
        return P(fit(tp, shape[0]), fit(f, shape[1]))
    # Norm scales/biases and anything unmatched: replicate.
    return P(*([None] * len(shape)))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(abstract_params: PyTree, mesh: Mesh,
                policy: ShardingPolicy) -> PyTree:
    """PartitionSpec pytree for a param tree (abstract or concrete). Leaves
    under ``blocks/`` carry a stacked leading period axis -> prepend None."""
    ctx = RuleContext(mesh, policy)

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if ps.startswith("blocks/"):
            inner = _param_spec(ps, shape[1:], ctx)
            return P(None, *inner)
        return _param_spec(ps, shape, ctx)

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def param_shardings(abstract_params: PyTree, mesh: Mesh,
                    policy: ShardingPolicy) -> PyTree:
    specs = param_specs(abstract_params, mesh, policy)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, policy: ShardingPolicy, batch: int,
               extra_dims: int = 1) -> P:
    """Shard the batch dim over DP axes when divisible."""
    ctx = RuleContext(mesh, policy)
    b_axes = ctx.fit(policy.dp_axes, batch)
    return P(b_axes, *([None] * extra_dims))


def decode_state_specs(abstract_state: PyTree, mesh: Mesh,
                       policy: ShardingPolicy, batch: int,
                       seq_axes: Tuple[str, ...]) -> PyTree:
    """Decode-state sharding: KV caches (P, B, S, Hk, Dh) shard B over DP
    and S over ``seq_axes``; recurrent states shard their channel axis over
    TP when divisible."""
    ctx = RuleContext(mesh, policy)
    b_axes = ctx.fit(policy.dp_axes, batch)

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        leaf_name = ps.rsplit("/", 1)[-1]
        if leaf_name in ("k", "v"):          # (P, B, S, Hk, Dh)
            s_axes = ctx.fit(seq_axes, shape[2])
            return P(None, b_axes, s_axes, None, None)
        if leaf_name == "h" and len(shape) == 4:     # mamba (P, B, di, ds)
            return P(None, b_axes, ctx.fit(policy.tp_axis, shape[2]), None)
        if leaf_name == "conv":              # (P, B, dc-1, di)
            return P(None, b_axes, None, ctx.fit(policy.tp_axis, shape[3]))
        if leaf_name in ("C",):              # mlstm (P, B, H, dh, dh)
            return P(None, b_axes, None, None, None)
        if leaf_name in ("n", "m"):          # mlstm/slstm small states
            return P(None, b_axes, *([None] * (len(shape) - 2)))
        if len(shape) >= 2:
            return P(None, b_axes, *([None] * (len(shape) - 2)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(one, abstract_state)


def tree_shardings(spec_tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
