"""Sharded, atomic, async checkpointing with deterministic resume.

Layout (per step):
    <dir>/step_<N>.tmp/            — written first
        MANIFEST.json              — tree structure, shapes, dtypes, step,
                                     data-pipeline state, process shards
        proc00000/leaf_<k>.npy     — this process's shard of leaf k
    <dir>/step_<N>/                — atomic rename on completion

On a multi-host pod each process writes only its addressable shards and the
coordinator (process 0) writes the manifest; this container has one process,
but the format and the restore path are process-sharded so the same code
runs on a real pod. ``AsyncCheckpointer`` moves the host copy + serialization
off the training thread (compute/IO overlap); ``keep`` bounds retention.
Restores place leaves with the target shardings via ``jax.device_put``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_names(tree: PyTree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    extra: Optional[Dict[str, Any]] = None,
                    keep: int = 3) -> str:
    """Synchronous sharded save. Returns the final checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    proc = jax.process_index()
    shard_dir = os.path.join(tmp, f"proc{proc:05d}")
    os.makedirs(shard_dir, exist_ok=True)
    leaves = _flatten_with_names(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {},
                "process_count": jax.process_count(),
                "format_version": 1}
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            # numpy can't round-trip ml_dtypes (bfloat16 etc.) through .npy;
            # store the raw bits and record the logical dtype.
            logical_dtype = "bfloat16"
            arr = arr.view(np.uint16)
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(shard_dir, fn), arr)
        manifest["leaves"].append({"name": name, "file": fn,
                                   "shape": list(arr.shape),
                                   "dtype": logical_dtype})
    if proc == 0:
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):            # re-save of the same step
            shutil.rmtree(tmp)
        else:
            os.replace(tmp, final)           # atomic commit
        _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for old in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, old), ignore_errors=True)


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(directory, ckpts[-1]) if ckpts else None


def restore_checkpoint(path: str, like: PyTree,
                       shardings: Optional[PyTree] = None
                       ) -> Tuple[PyTree, int, Dict[str, Any]]:
    """Restore into the structure of ``like`` (and optional shardings)."""
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    shard_dir = os.path.join(path, f"proc{jax.process_index():05d}")
    names = _flatten_with_names(like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    leaves = []
    for name, leaf in names:
        entry = by_name.get(name)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = np.load(os.path.join(shard_dir, entry["file"]))
        if entry["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree,
                            shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, manifest["step"], manifest.get("extra", {})


class AsyncCheckpointer:
    """Snapshot-to-host on the caller thread (cheap), serialize + fsync on a
    background thread; ``wait()`` joins the in-flight save. A crash between
    saves loses at most one checkpoint interval — the .tmp/rename protocol
    guarantees no torn checkpoints are ever restored."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: List[int] = []

    def save(self, step: int, tree: PyTree,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # device->host snapshot

        def run():
            save_checkpoint(self.directory, step, host_tree, extra,
                            self.keep)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
