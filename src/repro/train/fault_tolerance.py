"""Fault tolerance for 1000+-node runs: heartbeats, straggler detection,
checkpoint/restart supervision, and elastic re-meshing.

The elastic re-mesh reuses the *paper's own placement heuristic* (Alg. 3):
checkpoint shards are "chunks", shards of the same layer stack are
"join-correlated" (they are read together at restore), surviving hosts are
the nodes, and ``cost_based_placement`` redistributes the lost host's shards
while maximizing layer co-locality under per-host memory budgets — the same
code path that places array chunks places parameter shards. This is the
beyond-paper reuse documented in DESIGN.md §5.

Hardware is simulated (this container is one box): ``ClusterMonitor`` is fed
heartbeat/step-time observations by the harness or tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.placement import JoinRecord, cost_based_placement


@dataclasses.dataclass
class NodeState:
    node_id: int
    last_heartbeat: float
    step_times: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True


@dataclasses.dataclass
class StragglerReport:
    stragglers: List[int]
    median_step_s: float
    threshold_s: float


class ClusterMonitor:
    """Heartbeat + straggler tracking. ``heartbeat_timeout`` declares a node
    dead; step times beyond ``straggler_factor`` x median flag a straggler
    (candidate for data re-balancing or preemptive replacement)."""

    def __init__(self, n_nodes: int, heartbeat_timeout: float = 30.0,
                 straggler_factor: float = 1.5, window: int = 16,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.timeout = heartbeat_timeout
        self.factor = straggler_factor
        self.window = window
        now = clock()
        self.nodes = {i: NodeState(i, now) for i in range(n_nodes)}

    def heartbeat(self, node_id: int,
                  step_time_s: Optional[float] = None) -> None:
        st = self.nodes[node_id]
        st.last_heartbeat = self.clock()
        if step_time_s is not None:
            st.step_times.append(step_time_s)
            st.step_times = st.step_times[-self.window:]

    def dead_nodes(self) -> List[int]:
        now = self.clock()
        out = []
        for st in self.nodes.values():
            if st.alive and now - st.last_heartbeat > self.timeout:
                st.alive = False
            if not st.alive:
                out.append(st.node_id)
        return out

    def stragglers(self) -> StragglerReport:
        means = {i: float(np.mean(st.step_times))
                 for i, st in self.nodes.items()
                 if st.alive and st.step_times}
        if not means:
            return StragglerReport([], 0.0, 0.0)
        med = float(np.median(list(means.values())))
        thr = med * self.factor
        return StragglerReport(
            [i for i, m in means.items() if m > thr], med, thr)


@dataclasses.dataclass
class RemeshPlan:
    old_dp: int
    new_dp: int
    mesh_shape: Tuple[int, ...]
    shard_moves: Dict[int, int]          # shard_id -> destination host
    dropped_batch_fraction: float


def plan_elastic_remesh(n_hosts_alive: int, model_parallel: int,
                        shard_sizes: Dict[int, int],
                        shard_layer: Dict[int, int],
                        lost_host_shards: Sequence[int],
                        host_budget_bytes: int,
                        current_host: Dict[int, int]) -> RemeshPlan:
    """Shrink the DP axis to the largest size the survivors support and
    redistribute the lost host's checkpoint shards via Alg. 3.

    ``shard_layer`` drives co-locality: shards of the same layer-period form
    join pairs so restore reads stay host-local."""
    new_dp = max(1, n_hosts_alive // model_parallel)
    # Join-correlate shards within a layer (they restore together).
    by_layer: Dict[int, List[int]] = {}
    for s, layer in shard_layer.items():
        by_layer.setdefault(layer, []).append(s)
    pairs = []
    for layer, shards in by_layer.items():
        shards = sorted(shards)
        pairs.extend((a, b) for i, a in enumerate(shards)
                     for b in shards[i + 1:])
    workload = [JoinRecord(1, tuple(pairs))]
    # Replicas: surviving shards stay put (single replica); lost shards may
    # go to any survivor (modeled as replicas everywhere).
    survivors = sorted(set(current_host.values()))[:n_hosts_alive]
    replicas: Dict[int, Set[int]] = {}
    for s in shard_sizes:
        if s in lost_host_shards:
            replicas[s] = set(survivors)
        else:
            replicas[s] = {current_host[s]}
    budgets = {h: host_budget_bytes for h in survivors}
    placement = cost_based_placement(workload, replicas, shard_sizes,
                                     budgets)
    moves = {s: n for s, n in placement.locations.items()
             if s in lost_host_shards or n != current_host.get(s)}
    return RemeshPlan(
        old_dp=(n_hosts_alive + 1) // model_parallel, new_dp=new_dp,
        mesh_shape=(new_dp, model_parallel),
        shard_moves=moves,
        dropped_batch_fraction=1.0 - new_dp * model_parallel /
        ((n_hosts_alive + 1) // model_parallel * model_parallel))


class TrainingSupervisor:
    """Checkpoint/restart driver: runs ``step_fn`` until ``total_steps``,
    checkpointing every ``ckpt_every``; on a (simulated) failure exception it
    restores the latest checkpoint and continues — the integration test
    injects failures and asserts bit-exact convergence with an uninterrupted
    run."""

    def __init__(self, checkpointer, restore_fn, ckpt_every: int = 10):
        self.ckpt = checkpointer
        self.restore_fn = restore_fn
        self.every = ckpt_every

    def run(self, state, step_fn, total_steps: int,
            failure_at: Optional[Set[int]] = None):
        failure_at = failure_at or set()
        step = state["step"]
        while step < total_steps:
            try:
                if step in failure_at:
                    failure_at.discard(step)
                    raise RuntimeError(f"injected node failure at {step}")
                state = step_fn(state)
                step = state["step"]
                if step % self.every == 0:
                    self.ckpt.save(step, state["tree"],
                                   extra={"pipeline": state.get("pipeline",
                                                                {})})
            except RuntimeError:
                self.ckpt.wait()
                state = self.restore_fn()
                step = state["step"]
        self.ckpt.wait()
        return state
