"""AdamW with global-norm clipping, warmup+cosine schedule, and an optional
bf16 moment policy for the >50B models (halves optimizer HBM; the update is
still computed in fp32). Pure pytree implementation — states inherit the
parameter shardings, so FSDP sharding of params automatically shards the
optimizer state (ZeRO)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Optional[Any] = None      # None -> fp32; jnp.bfloat16 for XXL


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * \
        (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: PyTree, cfg: OptimizerConfig) -> Dict[str, PyTree]:
    dt = cfg.state_dtype

    def zeros(p):
        return jnp.zeros(p.shape, dt or jnp.float32)

    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params: PyTree, grads: PyTree, state: Dict[str, PyTree],
                 cfg: OptimizerConfig
                 ) -> Tuple[PyTree, Dict[str, PyTree], Dict[str, jax.Array]]:
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) \
            if p.ndim >= 2 else 0.0   # no decay on norms/biases
        new_p = p.astype(jnp.float32) - lr * (step + decay)
        sd = m.dtype
        return new_p.astype(p.dtype), m32.astype(sd), v32.astype(sd)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"m": new_m, "v": new_v, "count": count}, metrics
