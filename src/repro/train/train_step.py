"""Train-step construction: loss, grads, microbatch accumulation, AdamW.

``make_train_step(cfg, opt_cfg)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with sharded inputs. Batches are dicts:

  LM:    {"tokens": (B,S) i32, "labels": (B,S) i32}
  audio: {"embeds": (B,S,d) bf16, "labels": (B,S) i32}
  VLM:   {"tokens": (B,S_t) i32, "embeds": (B,Np,d) bf16, "labels": (B,S) i32}

With ``n_microbatches > 1`` the leading batch dim is split and gradients are
accumulated with a ``lax.scan`` (the production path for large global
batches); remat is applied per layer-period inside the model."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward, lm_loss
from repro.train.optimizer import (OptimizerConfig, adamw_init, adamw_update)

PyTree = Any
Batch = Dict[str, jax.Array]


def loss_fn(params: PyTree, cfg: ModelConfig, batch: Batch,
            attention_impl: str = "auto", remat: bool = True,
            aux_weight: float = 0.01) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"),
                          attention_impl=attention_impl, remat=remat)
    loss = lm_loss(logits, batch["labels"])
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux}


def make_train_step(cfg: ModelConfig,
                    opt_cfg: Optional[OptimizerConfig] = None,
                    n_microbatches: int = 1,
                    attention_impl: str = "auto",
                    remat: bool = True) -> Callable:
    opt_cfg = opt_cfg or OptimizerConfig()
    lfn = functools.partial(loss_fn, cfg=cfg, attention_impl=attention_impl,
                            remat=remat)

    def train_step(params: PyTree, opt_state: PyTree, batch: Batch):
        if n_microbatches == 1:
            (_, metrics), grads = jax.value_and_grad(
                lambda p: lfn(p, batch=batch), has_aux=True)(params)
        else:
            def split(x):
                b = x.shape[0]
                mb = b // n_microbatches
                return x.reshape(n_microbatches, mb, *x.shape[1:])

            mb_batch = jax.tree.map(split, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                g_sum, m_sum = carry
                (_, metrics), g = jax.value_and_grad(
                    lambda p: lfn(p, batch=mb), has_aux=True)(params)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g)
                m_sum = jax.tree.map(lambda a, b: a + b, m_sum, metrics)
                return (g_sum, m_sum), None

            zero_m = {"loss": jnp.zeros(()), "aux_loss": jnp.zeros(())}
            (grads, msum), _ = jax.lax.scan(acc, (zero_g, zero_m), mb_batch)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            metrics = jax.tree.map(lambda m: m / n_microbatches, msum)
        params, opt_state, opt_metrics = adamw_update(params, grads,
                                                      opt_state, opt_cfg)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, key,
                     opt_cfg: Optional[OptimizerConfig] = None):
    from repro.models.model import init_params
    params = init_params(cfg, key)
    opt_state = adamw_init(params, opt_cfg or OptimizerConfig())
    return params, opt_state
