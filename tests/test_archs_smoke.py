"""Per-architecture smoke tests: a reduced config of the same family runs
one forward and one train-gradient step on CPU; decoders also run one decode
step. Asserts output shapes and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get, list_archs, reduced
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, lm_loss)

B, S = 2, 16
N_PATCH = 4


def _inputs(cfg, key):
    """(tokens, embeds, labels) for a reduced config."""
    kt, ke = jax.random.split(key)
    if cfg.frontend == "audio_frames":
        embeds = jax.random.normal(ke, (B, S, cfg.d_model), jnp.float32)
        labels = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
        return None, embeds, labels
    if cfg.frontend == "vision_patches":
        tokens = jax.random.randint(kt, (B, S - N_PATCH), 0, cfg.vocab_size)
        embeds = jax.random.normal(ke, (B, N_PATCH, cfg.d_model), jnp.float32)
        labels = jnp.concatenate(
            [jnp.full((B, N_PATCH), -1, jnp.int32),
             jax.random.randint(ke, (B, S - N_PATCH), 0, cfg.vocab_size)],
            axis=1)
        return tokens, embeds, labels
    tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    return tokens, None, tokens


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_grad(arch):
    cfg = reduced(get(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens, embeds, labels = _inputs(cfg, key)
    logits, aux = forward(params, cfg, tokens=tokens, embeds=embeds)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    def loss_fn(p):
        lg, a = forward(p, cfg, tokens=tokens, embeds=embeds)
        return lm_loss(lg, labels) + 0.01 * a

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    # At least one grad is nonzero (the model is actually differentiable).
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if not ARCHS[a].encoder_only])
def test_decode_step(arch):
    cfg = reduced(get(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    state = init_decode_state(cfg, batch=B, max_len=32)
    pos = jnp.zeros((B,), jnp.int32)
    tok = jnp.ones((B, 1), jnp.int32)
    for step in range(3):
        logits, state = decode_step(params, cfg, tok, state, pos + step)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
        tok = logits.argmax(-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if ARCHS[a].uses_attention
                                  and not ARCHS[a].encoder_only
                                  and ARCHS[a].frontend is None])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must agree with the parallel forward pass."""
    cfg = reduced(get(arch))
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (B, 8), 0, cfg.vocab_size)
    logits_fw, _ = forward(params, cfg, tokens=tokens)
    state = init_decode_state(cfg, batch=B, max_len=8)
    outs = []
    for t in range(8):
        lg, state = decode_step(params, cfg, tokens[:, t:t + 1], state,
                                jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    # bf16 residual stacks accumulate noise; compare with bf16-scale slack.
    np.testing.assert_allclose(np.asarray(logits_fw, np.float32),
                               np.asarray(logits_dec, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_param_count_sane():
    # Analytic counts should be within 25% of the advertised sizes.
    expect = {
        "qwen1.5-0.5b": 0.5e9, "nemotron-4-340b": 340e9, "olmo-1b": 1.2e9,
        "llama3.2-3b": 3.2e9, "deepseek-moe-16b": 16e9,
        "phi3.5-moe-42b-a6.6b": 42e9, "xlstm-125m": 0.125e9,
        "hubert-xlarge": 1.0e9, "jamba-1.5-large-398b": 398e9,
        "internvl2-2b": 2.0e9,
    }
    for name, target in expect.items():
        got = get(name).param_count()
        assert 0.5 * target < got < 1.6 * target, \
            f"{name}: {got/1e9:.2f}B vs expected ~{target/1e9:.0f}B"


def test_moe_active_params_less_than_total():
    for name in ("deepseek-moe-16b", "phi3.5-moe-42b-a6.6b",
                 "jamba-1.5-large-398b"):
        cfg = get(name)
        assert cfg.active_param_count() < 0.6 * cfg.param_count()


def test_reduced_preserves_structure():
    for name in list_archs():
        cfg, r = get(name), reduced(get(name))
        assert r.layer_pattern == cfg.layer_pattern
        assert r.family == cfg.family
        assert r.qkv_bias == cfg.qkv_bias
        assert r.encoder_only == cfg.encoder_only
        assert (r.moe is None) == (cfg.moe is None)
