import numpy as np
import pytest

from repro.arrayio import formats
from repro.arrayio.catalog import FileReader, build_catalog
from repro.arrayio.generator import make_geo_files, make_ptf_files
from repro.core.geometry import points_in_box


@pytest.fixture(scope="module")
def sample():
    rng = np.random.default_rng(0)
    coords = rng.integers(1, 10_000, size=(257, 3)).astype(np.int64)
    attrs = rng.normal(size=(257, 2)).astype(np.float32)
    return coords, attrs


@pytest.mark.parametrize("fmt", formats.FORMATS)
def test_roundtrip(tmp_path, sample, fmt):
    coords, attrs = sample
    path = str(tmp_path / f"t.{fmt}")
    nbytes = formats.write_array_file(path, fmt, coords, attrs)
    assert nbytes > 0
    c2, a2 = formats.read_array_file(path, fmt)
    np.testing.assert_array_equal(coords, c2)
    if fmt == "csv":
        np.testing.assert_allclose(attrs, a2, rtol=1e-4)
    else:
        np.testing.assert_allclose(attrs, a2, rtol=1e-6)


@pytest.mark.parametrize("fmt", formats.FORMATS)
def test_empty_and_single_row(tmp_path, fmt):
    path = str(tmp_path / f"s.{fmt}")
    coords = np.array([[3, 4]], dtype=np.int64)
    attrs = np.array([[1.5]], dtype=np.float32)
    formats.write_array_file(path, fmt, coords, attrs)
    c2, a2 = formats.read_array_file(path, fmt)
    np.testing.assert_array_equal(coords, c2)


def test_fits_header_is_blocked(tmp_path, sample):
    coords, attrs = sample
    path = str(tmp_path / "h.fits")
    n = formats.write_array_file(path, "fits", coords, attrs)
    assert n % 2880 == 0          # FITS files are multiples of 2880 bytes


def test_generators_respect_domain_and_skew():
    files = make_ptf_files(n_files=8, cells_per_file_mean=500, seed=1)
    sizes = [f.coords.shape[0] for f in files]
    assert len(files) == 8 and min(sizes) >= 16
    assert max(sizes) > 2 * (sum(sizes) / len(sizes))   # heavy tail
    for f in files:
        assert points_in_box(f.coords, f.box).all()
        # Boxes of consecutive nights overlap in (ra, dec) — files overlap.
    geo = make_geo_files(n_files=4, n_seeds=50, clones_per_seed=5)
    assert len(geo) == 4
    for g in geo:
        assert g.coords.shape[1] == 2


@pytest.mark.parametrize("fmt", formats.FORMATS)
def test_build_catalog(tmp_path, fmt):
    files = make_ptf_files(n_files=4, cells_per_file_mean=200, seed=2)
    catalog, data = build_catalog(files, str(tmp_path), fmt, n_nodes=3)
    assert len(catalog.files) == 4
    assert {f.node for f in catalog.files} <= {0, 1, 2}
    reader = FileReader(catalog, data)
    c, a = reader.read(2)
    np.testing.assert_array_equal(c, files[2].coords)
    # Disk path agrees with the in-memory path.
    reader_disk = FileReader(catalog, None)
    c2, _ = reader_disk.read(2)
    np.testing.assert_array_equal(c, c2)
    # Catalog boxes are the acquisition boxes.
    assert catalog.files[1].box == files[1].box
    assert catalog.domain.contains_box(files[0].box)
