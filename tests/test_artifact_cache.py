"""Join-artifact caching and adaptive prune selection: warm results must
be bit-identical to cold across evict -> re-admit -> split, artifacts
must be invalidated on every residency event (``on_drop``/``on_split``/
``reconcile`` — no stale-artifact path survives), and ``prune="auto"``
must count exactly what ``"dense"`` and ``"block"`` count on random and
clustered workloads under both execution backends."""
import tempfile

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.backend.artifacts import (ChunkView, JoinArtifactCache,  # noqa: E402
                                     task_coords)
from repro.backend.executors import (NumpyJoinExecutor,  # noqa: E402
                                     PallasJoinExecutor,
                                     count_similar_pairs_np,
                                     make_join_executor)
from repro.backend.jax_mesh import JaxMeshBackend  # noqa: E402
from repro.core.geometry import Box  # noqa: E402


def clustered_coords(rng, n, d=3, n_clusters=6, domain=50_000, spread=30):
    centers = rng.integers(0, domain, (n_clusters, d))
    pick = rng.integers(0, n_clusters, n)
    return (centers[pick] + rng.integers(-spread, spread + 1,
                                         (n, d))).astype(np.int32)


# ----------------------------------------------------- cache unit tests

def test_view_keying_canonicalizes_coverage():
    cache = JoinArtifactCache()
    coords = np.zeros((4, 2), np.int32)
    chunk = Box((10, 10), (20, 20))
    # Full coverage from two different query boxes -> ONE artifact key.
    v1 = cache.view(7, chunk, Box((0, 0), (100, 100)), coords)
    v2 = cache.view(7, chunk, Box((5, 5), (50, 50)), coords)
    assert v1.key == v2.key == (7, ())
    # Partial coverage keys by the intersected box.
    v3 = cache.view(7, chunk, Box((0, 0), (15, 15)), coords)
    assert v3.key == (7, ((10, 10), (15, 15)))
    assert v3.key != v1.key
    # Unknown geometry / disjoint boxes degrade to uncacheable views.
    assert cache.view(7, None, Box((0, 0), (1, 1)), coords).key is None
    assert cache.view(7, chunk, Box((0, 0), (5, 5)), coords).key is None
    assert task_coords(v1) is coords
    assert task_coords(coords) is coords


def test_getters_memoize_and_count():
    cache = JoinArtifactCache()
    coords = np.arange(12, dtype=np.int32).reshape(6, 2)
    v = cache.view(1, Box((0, 0), (11, 11)), Box((0, 0), (99, 99)), coords)
    calls = []
    got1 = cache.sorted_coords(v, lambda: calls.append("s") or coords[::-1])
    got2 = cache.sorted_coords(v, lambda: calls.append("s") or coords[::-1])
    assert got1 is got2 and calls == ["s"]
    assert cache.misses == 1 and cache.hits == 1
    pad = np.ones((2, 128), np.int32)
    assert cache.padded(v, 5, lambda: pad) is pad
    assert cache.padded(v, 5, lambda: 0 / 0) is pad       # memoized
    assert cache.padded(v, -5, lambda: -pad) is not pad   # per join side
    pairs = (np.ones((2, 3), np.int32), 4)
    w = cache.view(2, Box((50, 50), (60, 60)), Box((0, 0), (99, 99)),
                   coords)
    assert cache.block_pairs(v, w, 128, 3, False, lambda: pairs) is pairs
    assert cache.block_pairs(v, w, 128, 3, False, lambda: 0 / 0) is pairs
    # Different eps is a different artifact.
    pairs9 = (np.zeros((1, 3), np.int32), 4)
    assert cache.block_pairs(v, w, 128, 9, False, lambda: pairs9) is pairs9
    # Uncacheable side -> computed every time, no counters.
    raw = np.zeros((3, 2), np.int32)
    h, m = cache.hits, cache.misses
    assert cache.block_pairs(v, raw, 128, 3, False, lambda: pairs) is pairs
    assert (cache.hits, cache.misses) == (h, m)


def test_bitmap_getters_memoize_and_invalidate():
    """The bitmap sidecars and refined pair lists are content-addressed
    join artifacts: memoized per (block, scale) / chunk pair, distinct
    from the bbox pair list under the same coordinates, recomputed after
    either side's chunk leaves residency, and uncached for raw-array
    sides."""
    cache = JoinArtifactCache()
    coords = np.arange(12, dtype=np.int32).reshape(6, 2)
    q = Box((0, 0), (99, 99))
    v = cache.view(1, Box((0, 0), (11, 11)), q, coords)
    w = cache.view(2, Box((50, 50), (60, 60)), q, coords)
    bm = [(np.zeros((1, 2), np.int64), np.zeros((1, 2), np.int64))]
    assert cache.bitmaps(v, 128, 8, lambda: bm) is bm
    assert cache.bitmaps(v, 128, 8, lambda: 0 / 0) is bm      # memoized
    assert cache.bitmaps(v, 128, 4, lambda: list(bm)) is not bm  # per scale
    ref = (np.ones((2, 3), np.int32), 1)
    assert cache.refined_pairs(v, w, 128, 3, False, lambda: ref) is ref
    assert cache.refined_pairs(v, w, 128, 3, False, lambda: 0 / 0) is ref
    # The bbox pair list at the same (pair, block, eps, same) key
    # coordinates is a DIFFERENT artifact (distinct tag).
    bbox = (np.ones((3, 3), np.int32), 4)
    assert cache.block_pairs(v, w, 128, 3, False, lambda: bbox) is bbox
    assert cache.refined_pairs(v, w, 128, 3, False, lambda: 0 / 0) is ref
    # Dropping either side's chunk invalidates the refined list too.
    cache.on_drop(2)
    assert not cache.has_chunk(2)
    ref2 = (np.zeros((1, 3), np.int32), 2)
    assert cache.refined_pairs(v, w, 128, 3, False, lambda: ref2) is ref2
    # on_split retires the bitmap sidecars with the parent id.
    cache.on_split(1, leaves=[])
    bm2 = [(np.ones((1, 2), np.int64), np.ones((1, 2), np.int64))]
    assert cache.bitmaps(v, 128, 8, lambda: bm2) is bm2
    # Uncacheable side -> computed every time, no counters.
    raw = np.zeros((3, 2), np.int32)
    h, m = cache.hits, cache.misses
    assert cache.refined_pairs(v, raw, 128, 3, False, lambda: ref) is ref
    assert (cache.hits, cache.misses) == (h, m)


def test_invalidation_on_drop_split_reconcile():
    class FakeState:
        cached = {1}

    cache = JoinArtifactCache()
    q = Box((0, 0), (99, 99))
    coords = np.zeros((2, 2), np.int32)
    v1 = cache.view(1, Box((0, 0), (9, 9)), q, coords)
    v2 = cache.view(2, Box((10, 10), (19, 19)), q, coords)
    cache.sorted_coords(v1, lambda: coords)
    cache.sorted_coords(v2, lambda: coords)
    cache.block_pairs(v1, v2, 128, 3, False,
                      lambda: (np.ones((1, 3), np.int32), 1))
    assert cache.chunk_ids() == {1, 2}
    # on_drop removes the chunk's entries AND pair lists it fed.
    cache.on_drop(2)
    assert cache.chunk_ids() == {1}
    assert not cache.has_chunk(2)
    # on_split retires the parent id the same way.
    cache.on_split(1, leaves=[])
    assert cache.chunk_ids() == set()
    assert len(cache) == 0
    # reconcile prunes everything not resident.
    v1 = cache.view(1, Box((0, 0), (9, 9)), q, coords)
    v3 = cache.view(3, Box((30, 30), (39, 39)), q, coords)
    cache.sorted_coords(v1, lambda: coords)
    cache.sorted_coords(v3, lambda: coords)
    cache.reconcile(FakeState())
    assert cache.chunk_ids() == {1}
    assert cache.invalidations > 0


def test_subset_cap_evicts_least_recently_used():
    cache = JoinArtifactCache(max_subsets_per_chunk=2)
    chunk = Box((0, 0), (99, 99))
    coords = np.zeros((2, 2), np.int32)
    views = [cache.view(1, chunk, Box((0, 0), (k, k)), coords)
             for k in (10, 20, 30)]
    for v in views:
        cache.sorted_coords(v, lambda: coords)
    assert len(cache) == 2
    # Oldest subset recomputes (miss), newest still hits.
    h = cache.hits
    cache.sorted_coords(views[-1], lambda: 0 / 0)
    assert cache.hits == h + 1
    m = cache.misses
    cache.sorted_coords(views[0], lambda: coords)
    assert cache.misses == m + 1
    # LRU, not FIFO: a hit refreshes the subset's position, so a hot
    # subset survives a newer one-off insertion.
    cache.sorted_coords(views[-1], lambda: 0 / 0)      # touch 30: hot
    cache.sorted_coords(cache.view(1, chunk, Box((0, 0), (40, 40)),
                                   coords), lambda: coords)
    cache.sorted_coords(views[-1], lambda: 0 / 0)      # 30 still cached


# ------------------------------------------------- executor-level parity

def make_tasks(rng, k=8, maker=clustered_coords):
    tasks = []
    for i in range(k):
        a = maker(rng, int(rng.integers(1, 700)))
        b = maker(rng, int(rng.integers(1, 700)))
        tasks.append((i % 3, a, b, False))
        tasks.append((i % 3, a, a, True))
    tasks.append((0, np.zeros((0, 3), np.int32), a, False))
    return tasks


def uniform_coords(rng, n, d=3, hi=400):
    return rng.integers(0, hi, size=(n, d)).astype(np.int32)


@pytest.mark.parametrize("maker", [clustered_coords, uniform_coords])
def test_auto_parity_and_counters(maker):
    """prune="auto" counts exactly what dense/block/bitmap/numpy count,
    its dense-grid denominator matches theirs, and its evaluated work
    sits between bitmap's (the tightest prune — auto's block-routed
    tasks carry the same refined lists, dense-routed ones their full
    grid) and dense's (upper bound)."""
    rng = np.random.default_rng(11)
    tasks = make_tasks(rng, maker=maker)
    eps = 40
    dense = PallasJoinExecutor(prune="dense")
    block = PallasJoinExecutor(prune="block")
    bitmap = PallasJoinExecutor(prune="bitmap")
    auto = PallasJoinExecutor(prune="auto")
    ref = NumpyJoinExecutor(count_similar_pairs_np)
    cd = dense.count_pairs(tasks, eps)
    cb = block.count_pairs(tasks, eps)
    cm = bitmap.count_pairs(tasks, eps)
    ca = auto.count_pairs(tasks, eps)
    cn = ref.count_pairs(tasks, eps)
    assert cd == cb == cm == ca == cn
    assert sum(ca) > 0
    t = dense.last_stats["block_pairs_total"]
    assert auto.last_stats["block_pairs_total"] == t
    assert block.last_stats["block_pairs_total"] == t
    assert bitmap.last_stats["block_pairs_total"] == t
    assert (bitmap.last_stats["block_pairs_evaluated"]
            <= block.last_stats["block_pairs_evaluated"] <= t)
    assert (bitmap.last_stats["block_pairs_evaluated"]
            <= auto.last_stats["block_pairs_evaluated"] <= t)
    assert bitmap.last_stats["block_pairs_bitmap_killed"] >= 0
    assert bitmap.last_stats["bitmap_build_s"] >= 0
    for ex in (dense, block, bitmap, auto):
        assert ex.last_stats["prep_s"] >= 0
        assert ex.last_stats["dispatch_s"] >= 0


def test_auto_single_block_tasks_skip_pair_lists():
    """Single-block chunk pairs go dense without building a pair list:
    the pruning denominator is the grid size and nothing is pruned."""
    rng = np.random.default_rng(3)
    tasks = [(0, clustered_coords(rng, 100), clustered_coords(rng, 90),
              False)]
    auto = PallasJoinExecutor(prune="auto")
    batches, stats = auto.iter_batches(tasks, 10)
    assert [b.fn_key[0] for b in batches] == ["dense"]
    assert stats == {"block_pairs_total": 1, "block_pairs_evaluated": 1,
                     "prep_s": stats["prep_s"],
                     "artifact_hits": 0, "artifact_misses": 0}


def test_auto_routes_near_dense_to_dense_and_sparse_to_block():
    rng = np.random.default_rng(5)
    # Tight multi-block cross-join: every block pair survives the eps
    # prune, so the padded pair list is at least the dense grid -> auto
    # must pick the dense grid (no prefetch overhead to recoup).
    near_a = rng.integers(0, 10, size=(600, 3)).astype(np.int32)
    near_b = rng.integers(0, 10, size=(500, 3)).astype(np.int32)
    # Widely clustered: most block pairs pruned -> block-sparse grid.
    sparse = clustered_coords(rng, 4096, n_clusters=12, domain=100_000)
    auto = PallasJoinExecutor(prune="auto")
    b1, s1 = auto.iter_batches([(0, near_a, near_b, False)], 30)
    assert {b.fn_key[0] for b in b1} == {"dense"}
    assert s1["block_pairs_evaluated"] == s1["block_pairs_total"]
    # A dense self-join still routes to block: the i <= j pair list is
    # roughly half the full grid the dense kernel would sweep.
    b1s, _ = auto.iter_batches([(0, near_a, near_a, True)], 30)
    assert {b.fn_key[0] for b in b1s} == {"block"}
    b2, s2 = auto.iter_batches([(0, sparse, sparse, True)], 64)
    assert {b.fn_key[0] for b in b2} == {"block"}
    assert s2["block_pairs_evaluated"] < s2["block_pairs_total"] // 2


def test_executor_artifact_reuse_with_views():
    """Repeated dispatch over the same ChunkViews hits the artifact
    cache; counts are bit-identical to the cold pass and to raw-array
    (uncached) tasks."""
    rng = np.random.default_rng(9)
    a = clustered_coords(rng, 900)
    b = clustered_coords(rng, 500)
    q = Box((0, 0, 0), tuple([60_000] * 3))
    for mode in ("dense", "block", "bitmap", "auto"):
        ex = PallasJoinExecutor(prune=mode)
        va = ex.artifacts.view(1, Box((0, 0, 0), (50_100, 50_100, 50_100)),
                               q, a)
        vb = ex.artifacts.view(2, Box((0, 0, 0), (50_100, 50_100, 50_100)),
                               q, b)
        tasks = [(0, va, vb, False), (1, va, va, True)]
        raw = [(0, a, b, False), (1, a, a, True)]
        cold = ex.count_pairs(tasks, 35)
        assert ex.last_stats["artifact_misses"] > 0, mode
        warm = ex.count_pairs(tasks, 35)
        assert warm == cold == PallasJoinExecutor(
            prune=mode).count_pairs(raw, 35), mode
        assert ex.last_stats["artifact_hits"] > 0, mode
        assert ex.last_stats["artifact_misses"] == 0, mode


def test_bitmap_eps0_and_duplicate_parity():
    """The eps=0 edge of the cell-exact stage: the quantization step
    degenerates to 1 and the occupied-cell test is an exact point
    membership test — duplicated cells (the only eps=0 matches) must
    count identically under every prune mode."""
    rng = np.random.default_rng(17)
    base = clustered_coords(rng, 600)
    dup = np.repeat(base[:40], 10, axis=0)        # duplicates: matches
    tasks = [(0, base, base, True), (1, dup, dup, True),
             (0, base, dup, False)]
    for eps in (0, 1):
        want = NumpyJoinExecutor(count_similar_pairs_np).count_pairs(
            tasks, eps)
        assert sum(want) > 0
        for mode in ("dense", "block", "bitmap", "auto"):
            got = PallasJoinExecutor(prune=mode).count_pairs(tasks, eps)
            assert got == want, (mode, eps)


def test_bitmap_stats_only_when_engaged():
    """The bitmap counters ride a conditional emission group: present
    exactly when the refinement stage ran on >= 1 multi-block candidate
    — absent under dense/block modes and on auto's single-block fast
    path, so summaries of workloads that never engage the feature are
    bit-identical to the pre-bitmap ones."""
    rng = np.random.default_rng(23)
    multi = clustered_coords(rng, 600)
    ex = PallasJoinExecutor(prune="bitmap")
    ex.count_pairs([(0, multi, multi, True)], 40)
    assert ex.last_stats["block_pairs_bitmap_killed"] >= 0
    assert ex.last_stats["bitmap_build_s"] >= 0
    blk = PallasJoinExecutor(prune="block")
    blk.count_pairs([(0, multi, multi, True)], 40)
    assert "block_pairs_bitmap_killed" not in blk.last_stats
    small = clustered_coords(rng, 100)            # single 128-block
    au = PallasJoinExecutor(prune="auto")
    au.count_pairs([(0, small, small, True)], 40)
    assert "block_pairs_bitmap_killed" not in au.last_stats


def test_auto_default_is_accepted_by_every_executor():
    """``"auto"`` is the safe default everywhere: the numpy executor
    (no block structure) accepts it as a no-op, the pallas executor
    adopts it as its default prune mode (explicit ``"block"`` rejection
    stays covered in test_simjoin_pruning)."""
    assert isinstance(make_join_executor("numpy", count_similar_pairs_np),
                      NumpyJoinExecutor)
    assert isinstance(make_join_executor(
        "numpy", count_similar_pairs_np, prune="auto"), NumpyJoinExecutor)
    assert PallasJoinExecutor().prune == "auto"


# ------------------------------------------------- cluster-level parity

@pytest.fixture(scope="module")
def dataset():
    from repro.arrayio.catalog import build_catalog
    from repro.arrayio.generator import make_geo_files
    files = make_geo_files(n_files=3, n_seeds=150, clones_per_seed=25,
                           seed=13)
    catalog, data = build_catalog(files, tempfile.mkdtemp(prefix="bart_"),
                                  "csv", n_nodes=4)
    return catalog, data


def make_cluster(dataset, backend="simulated", prune="auto",
                 budget_frac=8, min_cells=512):
    from repro.arrayio.catalog import FileReader
    from repro.core.cluster import RawArrayCluster
    catalog, data = dataset
    total = sum(f.n_cells * f.cell_bytes for f in catalog.files)
    return RawArrayCluster(catalog, FileReader(catalog, data), 4,
                           max(total // budget_frac, 4_000) // 4,
                           policy="cost", min_cells=min_cells,
                           backend=backend, join_backend="pallas",
                           prune=prune)


def workload(catalog, eps=400):
    from repro.core.workload import geo_workload
    return geo_workload(catalog.domain, eps=eps, range_frac=0.45)


@pytest.mark.parametrize("backend", ["simulated", "jax_mesh"])
def test_prune_mode_parity_both_backends(dataset, backend):
    """Match counts bit-identical across prune=dense|block|bitmap|auto
    on each backend (the ISSUE-5 acceptance gate, extended to the
    cell-exact bitmap stage by ISSUE 9)."""
    catalog, _ = dataset
    queries = workload(catalog)
    runs = {p: [e.matches for e in
                make_cluster(dataset, backend, p).run_workload(queries)]
            for p in ("dense", "block", "bitmap", "auto")}
    assert (runs["dense"] == runs["block"] == runs["bitmap"]
            == runs["auto"])
    assert sum(m or 0 for m in runs["dense"]) > 0


def test_warm_equals_cold_with_hits(dataset):
    """A repeated workload over an all-resident cache: pass 2 answers
    from memoized artifacts (hits > 0, zero misses on the pallas prep)
    with bit-identical per-query matches."""
    from repro.core.cluster import workload_summary
    catalog, _ = dataset
    queries = workload(catalog)
    cluster = make_cluster(dataset, budget_frac=1)   # everything fits
    cold = cluster.run_workload(queries)
    warm = cluster.run_workload(queries)
    assert [e.matches for e in warm] == [e.matches for e in cold]
    cold_s, warm_s = workload_summary(cold), workload_summary(warm)
    assert warm_s["artifact_hits"] > 0
    assert warm_s["artifact_misses"] == 0
    assert cold_s["artifact_misses"] > 0
    for e in warm:
        if e.report.join_plan is not None:
            assert e.prep_s is not None and e.dispatch_s is not None


@pytest.mark.parametrize("prune_mode", ["auto", "bitmap"])
def test_warm_bit_identical_across_evict_readmit_split(dataset,
                                                       prune_mode):
    """The acceptance sequence: evict -> re-admit -> split, every step
    answered identically by a long-lived (warm) cluster, a fresh dense
    cluster, and the numpy reference — no stale-artifact path, including
    the bitmap sidecars and refined pair lists of prune="bitmap"."""
    from repro.arrayio.catalog import FileReader
    from repro.core.cluster import RawArrayCluster
    from repro.core.coordinator import SimilarityJoinQuery
    catalog, data = dataset
    q_main = workload(catalog)[:2]
    # A sub-box query forces R-tree refinement (splits) on re-touch.
    d = catalog.domain
    mid = tuple((l + h) // 2 for l, h in zip(d.lo, d.hi))
    q_sub = SimilarityJoinQuery(box=Box(d.lo, mid), eps=400)
    seq = q_main + q_main + [q_sub] + q_main     # repeat / split / repeat
    warm = make_cluster(dataset, prune=prune_mode,
                        budget_frac=16,             # tight: forces evicts
                        min_cells=256)
    got = [e.matches for e in warm.run_workload(seq)]
    dense = make_cluster(dataset, prune="dense", budget_frac=16,
                         min_cells=256)
    want = [e.matches for e in dense.run_workload(seq)]
    np_cluster = RawArrayCluster(
        catalog, FileReader(catalog, data), 4,
        warm.coordinator.cache.node_budget, policy="cost", min_cells=256,
        join_backend="numpy")
    ref = [e.matches for e in np_cluster.run_workload(seq)]
    assert got == want == ref
    assert sum(m or 0 for m in got) > 0
    assert warm.backend.artifacts.invalidations > 0   # evict/split fired


def test_artifacts_never_outlive_residency(dataset):
    """After a reconcile, every chunk with live artifacts is resident —
    the invalidation guarantee of the CacheState listener hooks."""
    catalog, _ = dataset
    cluster = make_cluster(dataset, budget_frac=16, min_cells=256)
    cluster.run_workload(workload(catalog))
    cache = cluster.coordinator.cache
    art = cluster.backend.artifacts
    assert art is cluster.backend.executor.artifacts
    assert art in cache.listeners
    cache.sync_devices()                        # post-round reconcile
    assert art.chunk_ids() <= cache.cached
    assert len(art.chunk_ids()) > 0


def test_mesh_pins_padded_batches_across_queries(dataset):
    """The mesh backend device_puts a resident chunk set's stacked batch
    once: the repeat pass re-dispatches pinned device buffers
    (pinned_batch_hits > 0) with identical matches, and pinned entries
    never outlive residency."""
    catalog, _ = dataset
    cluster = make_cluster(dataset, backend="jax_mesh", budget_frac=1)
    queries = workload(catalog)
    cold = [e.matches for e in cluster.run_workload(queries)]
    backend = cluster.backend
    assert isinstance(backend, JaxMeshBackend)
    assert backend.device_stats["pinned_batch_misses"] > 0
    warm = [e.matches for e in cluster.run_workload(queries)]
    assert warm == cold
    assert backend.device_stats["pinned_batch_hits"] > 0
    cluster.coordinator.cache.sync_devices()
    assert set(backend._pinned_by_chunk) <= cluster.coordinator.cache.cached
    # Device memory is LRU-capped: shrinking the cap and re-running
    # evicts down to it (with the chunk index pruned alongside), while
    # match counts stay identical.
    backend.pinned_batch_cap = 1
    again = [e.matches for e in cluster.run_workload(queries)]
    assert again == cold
    assert len(backend._pinned) <= 1
    assert backend.device_stats["pinned_batches_freed"] > 0
    live = set()
    for refs in backend._pinned_by_chunk.values():
        live |= refs
    assert live <= set(backend._pinned)


def test_workload_summary_amortization_counters(dataset):
    """workload_summary aggregates the prep/dispatch split and artifact
    counters on the pallas path and omits them on the numpy path."""
    from repro.arrayio.catalog import FileReader
    from repro.core.cluster import RawArrayCluster, workload_summary
    catalog, data = dataset
    queries = workload(catalog)[:3]
    summ = workload_summary(make_cluster(dataset).run_workload(queries))
    for key in ("prep_s", "dispatch_s", "artifact_hits",
                "artifact_misses"):
        assert key in summ, key
    np_run = RawArrayCluster(catalog, FileReader(catalog, data), 4, 8_000,
                             policy="cost", min_cells=512,
                             join_backend="numpy").run_workload(queries)
    assert "prep_s" not in workload_summary(np_run)


def test_workload_summary_bitmap_group_gating(dataset):
    """``block_pairs_bitmap_killed``/``bitmap_build_s`` surface in
    ``workload_summary`` exactly when the cell-exact stage engaged:
    present under prune="bitmap", absent under prune="block" (whose
    summaries must stay bit-identical to the pre-bitmap seed shape)."""
    from repro.core.cluster import workload_summary
    catalog, _ = dataset
    queries = workload(catalog)[:3]
    with_bitmap = workload_summary(
        make_cluster(dataset, prune="bitmap").run_workload(queries))
    assert "block_pairs_bitmap_killed" in with_bitmap
    assert with_bitmap["bitmap_build_s"] >= 0
    without = workload_summary(
        make_cluster(dataset, prune="block").run_workload(queries))
    assert "block_pairs_bitmap_killed" not in without
    assert "bitmap_build_s" not in without
