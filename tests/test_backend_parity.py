"""Backend parity: the jax device-mesh backend executes the *same plans*
as the simulated backend — identical match counts and identical planned
ship/scan byte totals on the seed workloads (including with semantic
reuse on) — while committing every cached chunk as a device buffer on the
node its ``CacheState.locations`` entry names.

The suite runs at any device count (with one device the node axis wraps
and transfers collapse to the same device); the CI ``tier1-mesh`` job
runs it under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so
cross-device placement and real transfers are exercised on every push.
"""
import tempfile

import pytest

jax = pytest.importorskip("jax")

from repro.arrayio.catalog import FileReader, build_catalog
from repro.arrayio.generator import make_ptf_files
from repro.backend import JaxMeshBackend, SimulatedBackend, make_backend
from repro.core.cluster import RawArrayCluster, workload_summary
from repro.core.workload import ptf1_workload, ptf2_workload

N_NODES = 4
NODE_BUDGET = 6_000


@pytest.fixture(scope="module")
def dataset():
    files = make_ptf_files(n_files=10, cells_per_file_mean=900, seed=21)
    catalog, data = build_catalog(files, tempfile.mkdtemp(prefix="bparity_"),
                                  "fits", n_nodes=N_NODES)
    return catalog, data


def fixed_workload(catalog):
    return (ptf1_workload(catalog.domain, n_queries=4, eps=300, seed=7)
            + ptf2_workload(catalog.domain, n_queries=4, eps=300))


def make(dataset, backend, policy="cost", reuse="off", budget=NODE_BUDGET):
    catalog, data = dataset
    return RawArrayCluster(catalog, FileReader(catalog, data), N_NODES,
                           budget, policy=policy, min_cells=64,
                           backend=backend, reuse=reuse)


def planned_bytes(executed):
    """(total planned ship bytes, total planned scan bytes) of a run."""
    ship = sum(sum(e.report.join_plan.bytes_in.values())
               for e in executed if e.report.join_plan is not None)
    scan = sum(sum(e.report.scan_bytes_by_node.values()) for e in executed)
    return ship, scan


@pytest.mark.parametrize("policy", ["cost", "chunk_lru", "file_lru"])
def test_match_and_planned_byte_parity(dataset, policy):
    catalog, _ = dataset
    queries = fixed_workload(catalog)
    runs = {b: make(dataset, b, policy=policy).run_workload(queries)
            for b in ("simulated", "jax_mesh")}
    assert ([e.matches for e in runs["jax_mesh"]]
            == [e.matches for e in runs["simulated"]])
    assert planned_bytes(runs["jax_mesh"]) == planned_bytes(runs["simulated"])
    assert sum(e.matches for e in runs["simulated"]) > 0


def test_parity_with_semantic_reuse(dataset):
    catalog, _ = dataset
    # Repeat the workload so the second pass is served from cache.
    queries = fixed_workload(catalog) + fixed_workload(catalog)
    runs = {b: make(dataset, b, reuse="on",
                    budget=10 * NODE_BUDGET).run_workload(queries)
            for b in ("simulated", "jax_mesh")}
    assert ([e.matches for e in runs["jax_mesh"]]
            == [e.matches for e in runs["simulated"]])
    assert planned_bytes(runs["jax_mesh"]) == planned_bytes(runs["simulated"])
    assert workload_summary(runs["jax_mesh"])["reuse_hits"] > 0


def test_committed_buffers_track_locations(dataset):
    """Every cached chunk's committed buffer lives on the device matching
    its CacheState.locations node, and eviction frees buffers (the
    buffer table equals the resident set)."""
    catalog, _ = dataset
    cluster = make(dataset, "jax_mesh")
    cluster.run_workload(fixed_workload(catalog))
    backend = cluster.backend
    cache = cluster.coordinator.cache
    assert isinstance(backend, JaxMeshBackend)
    assert set(backend.committed_chunks()) == cache.cached
    assert len(cache.cached) > 0
    for cid, node in cache.primary_map().items():
        assert backend.buffer_device(cid) == backend.device_for_node(node), \
            f"chunk {cid} not on node {node}'s device"


@pytest.mark.skipif(len(jax.devices()) < N_NODES,
                    reason="needs >= 4 devices (tier1-mesh CI job sets "
                           "XLA_FLAGS=--xla_force_host_platform_device_count)")
def test_distinct_devices_and_real_transfers(dataset):
    """With one device per node, chunks at different nodes occupy
    *different* physical devices and ship decisions move real bytes."""
    catalog, _ = dataset
    cluster = make(dataset, "jax_mesh")
    executed = cluster.run_workload(fixed_workload(catalog))
    backend = cluster.backend
    cache = cluster.coordinator.cache
    nodes_used = set(cache.primary_map().values())
    devices_used = {backend.buffer_device(cid)
                    for cid in cache.primary_map()}
    assert len(devices_used) == len(nodes_used) > 1
    assert backend.device_stats["ship_bytes_measured"] > 0
    assert sum(e.measured_ship_bytes for e in executed) \
        == backend.device_stats["ship_bytes_measured"]


def test_measured_fields_by_backend(dataset):
    catalog, _ = dataset
    queries = fixed_workload(catalog)[:3]
    sim = make(dataset, "simulated").run_workload(queries)
    mesh = make(dataset, "jax_mesh").run_workload(queries)
    assert all(e.measured_net_s is None for e in sim)
    assert all(e.backend == "simulated" for e in sim)
    assert all(e.measured_net_s is not None and e.measured_net_s >= 0
               for e in mesh)
    assert all(e.measured_compute_s is not None for e in mesh)
    assert all(e.backend == "jax_mesh" for e in mesh)
    summ = workload_summary(mesh)
    assert "measured_net_s" in summ and "measured_ship_bytes" in summ
    assert "measured_net_s" not in workload_summary(sim)


@pytest.mark.slow
def test_compiled_mode_parity(dataset):
    """With ``compiled=True`` (TPU/GPU only) the mesh backend's compiled
    Pallas dispatch returns the same match counts as the simulated
    backend; skipped on CPU, where Pallas has no compiled path."""
    from repro.backend.jax_mesh import compiled_mode_supported
    if not compiled_mode_supported():
        pytest.skip("compiled Pallas needs TPU/GPU (CPU is interpret-only)")
    catalog, _ = dataset
    queries = fixed_workload(catalog)
    catalog_, data = dataset
    sim = make(dataset, "simulated").run_workload(queries)
    mesh = RawArrayCluster(catalog_, FileReader(catalog_, data), N_NODES,
                           NODE_BUDGET, policy="cost", min_cells=64,
                           backend="jax_mesh",
                           compiled=True).run_workload(queries)
    assert [e.matches for e in mesh] == [e.matches for e in sim]


def test_backend_factory_validation():
    with pytest.raises(ValueError, match="unknown backend"):
        make_backend("warp_drive", 4)
    with pytest.raises(ValueError, match="Pallas simjoin kernel"):
        make_backend("jax_mesh", 4, join_fn=lambda a, b, e, s: 0)
    assert isinstance(make_backend("simulated", 4), SimulatedBackend)
