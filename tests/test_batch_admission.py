"""Batched query admission, budget scopes, and the policy registry."""
import tempfile

import pytest

from repro.arrayio.catalog import FileReader, build_catalog
from repro.arrayio.generator import make_ptf_files
from repro.core.cluster import RawArrayCluster, workload_summary
from repro.core.policies import (POLICY_REGISTRY, PolicySpec,
                                 register_policy, resolve_policy)
from repro.core.workload import ptf1_workload, ptf2_workload

N_NODES = 4


@pytest.fixture(scope="module")
def dataset():
    files = make_ptf_files(n_files=10, cells_per_file_mean=900, seed=21)
    catalog, data = build_catalog(files, tempfile.mkdtemp(prefix="batch_"),
                                  "fits", n_nodes=N_NODES)
    return catalog, data


def make_cluster(dataset, policy="cost", budget=6_000, **kw):
    catalog, data = dataset
    return RawArrayCluster(catalog, FileReader(catalog, data), N_NODES,
                           budget, policy=policy, min_cells=64, **kw)


def workload(catalog, n1=4, n2=4):
    return (ptf1_workload(catalog.domain, n_queries=n1, eps=300, seed=7)
            + ptf2_workload(catalog.domain, n_queries=n2, eps=300))


# ------------------------------------------------------- batched admission

@pytest.mark.parametrize("policy", ["cost", "chunk_lru", "file_lru"])
def test_batch_admission_preserves_join_results(dataset, policy):
    """Caching/admission strategy must never change query answers."""
    catalog, _ = dataset
    queries = workload(catalog)
    seq = [e.matches
           for e in make_cluster(dataset, policy).run_workload(queries)]
    bat = [e.matches for e in make_cluster(dataset, policy)
           .run_workload(queries, batch_size=3)]
    assert bat == seq
    assert sum(seq) > 0


def test_batch_admission_shares_file_scans(dataset):
    """A file materialized for one query in a batch is not rescanned by a
    later query of the same batch."""
    catalog, _ = dataset
    queries = workload(catalog)
    seq = workload_summary(
        make_cluster(dataset).run_workload(queries))
    bat = workload_summary(
        make_cluster(dataset).run_workload(queries,
                                           batch_size=len(queries)))
    assert bat["bytes_scanned"] < seq["bytes_scanned"]


def test_batch_runs_one_evict_place_round(dataset):
    """Eviction/placement observables land on the batch's last report;
    earlier reports carry only their own planning output."""
    catalog, _ = dataset
    queries = workload(catalog)
    cluster = make_cluster(dataset)
    reports = cluster.coordinator.process_batch(queries)
    assert [r.batch_size for r in reports] == [len(queries)] * len(queries)
    assert all(r.placement is None for r in reports[:-1])
    assert reports[-1].placement is not None
    assert all(r.opt_time_evict_place_s == 0.0 for r in reports[:-1])
    # Post-batch cache state is reported uniformly.
    assert len({(r.cached_chunks_after, r.cached_bytes_after)
                for r in reports}) == 1


def test_batch_of_one_equals_single_query_admission(dataset):
    catalog, _ = dataset
    queries = workload(catalog)
    a = make_cluster(dataset).run_workload(queries)
    b = make_cluster(dataset).run_workload(queries, batch_size=1)
    for ea, eb in zip(a, b):
        assert ea.report.files_scanned == eb.report.files_scanned
        assert ea.report.cached_chunks_after == eb.report.cached_chunks_after
        assert ea.report.evicted_items == eb.report.evicted_items
        assert ea.matches == eb.matches


# ----------------------------------------------------------- budget scope

@pytest.mark.parametrize("policy", ["cost", "chunk_lru", "file_lru"])
def test_node_budget_scope_respects_per_node_limits(dataset, policy):
    catalog, _ = dataset
    budget = 12_000
    cluster = make_cluster(dataset, policy=policy, budget=budget,
                           budget_scope="node")
    coord = cluster.coordinator
    for _ in cluster.run_workload(workload(catalog)):
        chunk_bytes, _ = coord.chunks.size_tables()
        for node, used in coord.cache.bytes_by_node(chunk_bytes).items():
            assert used <= budget, f"node {node} over its hard limit"
        if hasattr(coord.eviction, "cache"):
            # Placement drops must not leave ghosts in the LRU/LFU
            # structures: both residency views stay identical.
            assert coord.eviction.cache.ids() == coord.cache.cached


def test_batch_admission_respects_global_budget(dataset):
    """One eviction round per batch must still enforce the aggregate
    budget: earlier batch queries' triples compete through the cost heap
    instead of being forcibly retained."""
    catalog, _ = dataset
    budget = 6_000
    cluster = make_cluster(dataset, budget=budget)
    coord = cluster.coordinator
    queries = workload(catalog)
    cluster.run_workload(queries, batch_size=len(queries))
    chunk_bytes, _ = coord.chunks.size_tables()
    assert coord.cache.cached_bytes(chunk_bytes) <= budget * N_NODES


def test_global_scope_packs_against_aggregate(dataset):
    """Default scope reproduces §4.2.1 unified-memory semantics: the
    aggregate stays within N * node_budget."""
    catalog, _ = dataset
    budget = 6_000
    cluster = make_cluster(dataset, budget=budget)
    coord = cluster.coordinator
    for _ in cluster.run_workload(workload(catalog)):
        chunk_bytes, _ = coord.chunks.size_tables()
        assert coord.cache.cached_bytes(chunk_bytes) <= budget * N_NODES


def test_unknown_budget_scope_rejected(dataset):
    with pytest.raises(ValueError):
        make_cluster(dataset, budget_scope="rack")


# --------------------------------------------------------- policy registry

def test_new_policy_combinations_answer_identically(dataset):
    """The registry's new combos change cache economics, never answers."""
    catalog, _ = dataset
    queries = workload(catalog)
    base = [e.matches
            for e in make_cluster(dataset, "cost").run_workload(queries)]
    for policy in ("chunk_lfu", "file_lfu", "cost_static"):
        got = [e.matches for e in
               make_cluster(dataset, policy).run_workload(queries)]
        assert got == base, policy


def test_resolve_policy_errors():
    with pytest.raises(ValueError):
        resolve_policy("nope")
    with pytest.raises(ValueError):
        resolve_policy("cost", placement_mode="sideways")
    # cost-based eviction needs chunk triples: no file granularity.
    with pytest.raises(ValueError):
        PolicySpec("bad", "file", "cost", "origin").validate()


def test_register_custom_combo_end_to_end(dataset):
    """Proving the seam: a combo registered by name is immediately usable
    through the coordinator/cluster constructors."""
    name = "lfu_static_test"
    register_policy(PolicySpec(name, "chunk", "lfu", "static"))
    try:
        catalog, _ = dataset
        queries = workload(catalog, n1=2, n2=2)
        executed = make_cluster(dataset, name).run_workload(queries)
        assert len(executed) == 4
        assert executed[-1].report.policy == name
    finally:
        POLICY_REGISTRY.pop(name, None)
