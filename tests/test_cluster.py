"""End-to-end behaviour of the caching cluster — the paper's core claims at
test scale: pruning works, caching eliminates rescans, cost-based beats LRU
on shifting workloads, placement reduces network bytes."""
import numpy as np
import pytest

from repro.arrayio.catalog import FileReader, build_catalog
from repro.arrayio.generator import make_geo_files, make_ptf_files
from repro.core.cluster import (CostModel, RawArrayCluster,
                                count_similar_pairs_np, workload_summary)
from repro.core.coordinator import SimilarityJoinQuery
from repro.core.geometry import Box, points_in_box
from repro.core.workload import geo_workload, ptf1_workload, ptf2_workload

N_NODES = 4


@pytest.fixture(scope="module")
def ptf(tmp_path_factory):
    root = tmp_path_factory.mktemp("ptf")
    files = make_ptf_files(n_files=10, cells_per_file_mean=900, seed=21)
    catalog, data = build_catalog(files, str(root), "fits", n_nodes=N_NODES)
    return catalog, data


def make_cluster(ptf, policy, budget=200_000, placement="dynamic",
                 min_cells=64):
    catalog, data = ptf
    return RawArrayCluster(catalog, FileReader(catalog, data), N_NODES,
                           budget, policy=policy, placement_mode=placement,
                           min_cells=min_cells)


def brute_force_matches(catalog, data, q):
    coords = np.concatenate([data[f.file_id][0] for f in catalog.files])
    coords = np.unique(coords, axis=0)
    pts = coords[points_in_box(coords, q.box)]
    return count_similar_pairs_np(pts, pts, q.eps, same=True)


def test_join_results_match_brute_force(ptf):
    catalog, data = ptf
    for policy in ("cost", "chunk_lru", "file_lru"):
        cluster = make_cluster(ptf, policy)
        dom = catalog.domain
        qbox = Box((dom.lo[0], dom.lo[1], dom.lo[2]),
                   (dom.lo[0] + dom.side(0) // 3,
                    dom.lo[1] + dom.side(1) // 3, dom.hi[2]))
        q = SimilarityJoinQuery(qbox, eps=2)
        got = cluster.run_query(q)
        expect = brute_force_matches(catalog, data, q)
        assert got.matches == expect, policy


def test_repeated_query_hits_cache(ptf):
    cluster = make_cluster(ptf, "cost", budget=10_000_000)
    q = ptf1_workload(cluster.catalog.domain, n_queries=1)[0]
    first = cluster.run_query(q)
    assert sum(first.report.scan_bytes_by_node.values()) > 0
    second = cluster.run_query(q)
    assert sum(second.report.scan_bytes_by_node.values()) == 0
    assert second.report.files_scanned == []
    assert second.matches == first.matches


def test_refined_boxes_prune_files(ptf):
    catalog, _ = ptf
    cluster = make_cluster(ptf, "cost", budget=10_000_000)
    dom = catalog.domain
    wide = SimilarityJoinQuery(dom, eps=1)
    cluster.run_query(wide)          # builds trees everywhere
    # A query in empty space: overlaps file boxes but no refined chunk.
    probe = None
    for f in catalog.files:
        got = cluster.coordinator.trees[f.file_id]
        assert got.n_leaves() >= 1
    report = cluster.run_query(wide).report
    assert report.files_pruned + len(report.files_scanned) <= len(catalog.files)


def test_cost_policy_beats_lru_on_shifting_workload(ptf):
    catalog, _ = ptf
    total_cells = sum(f.n_cells * f.cell_bytes for f in catalog.files)
    # The paper's regime: budget well below the data (8x), so whole-file
    # caching thrashes while chunk-level caching must choose what to keep.
    budget = total_cells // (8 * N_NODES)
    queries = ptf2_workload(catalog.domain, n_queries=10)
    results = {}
    for policy in ("cost", "chunk_lru", "file_lru"):
        cluster = make_cluster(ptf, policy, budget=budget)
        executed = cluster.run_workload(queries)
        results[policy] = workload_summary(executed)
    assert (results["cost"]["bytes_scanned"]
            <= results["chunk_lru"]["bytes_scanned"])
    assert (results["cost"]["bytes_scanned"]
            <= results["file_lru"]["bytes_scanned"])


def test_dynamic_placement_reduces_network(ptf):
    catalog, _ = ptf
    queries = ptf2_workload(catalog.domain, n_queries=10)
    nets = {}
    for mode in ("dynamic", "static"):
        cluster = make_cluster(ptf, "cost", budget=2_000_000, placement=mode)
        executed = cluster.run_workload(queries)
        nets[mode] = workload_summary(executed)["net_time_s"]
    assert nets["dynamic"] <= nets["static"] * 1.25


def test_matches_identical_across_policies_full_workload(ptf):
    catalog, _ = ptf
    queries = ptf1_workload(catalog.domain, n_queries=4, seed=5)
    per_policy = {}
    for policy in ("cost", "chunk_lru", "file_lru"):
        cluster = make_cluster(ptf, policy, budget=300_000)
        per_policy[policy] = [e.matches
                              for e in cluster.run_workload(queries)]
    assert per_policy["cost"] == per_policy["chunk_lru"] == \
        per_policy["file_lru"]


def test_geo_workload_runs(tmp_path):
    files = make_geo_files(n_files=6, n_seeds=120, clones_per_seed=8, seed=3)
    catalog, data = build_catalog(files, str(tmp_path), "csv", n_nodes=N_NODES)
    cluster = RawArrayCluster(catalog, FileReader(catalog, data), N_NODES,
                              100_000, policy="cost", min_cells=32)
    queries = geo_workload(catalog.domain)
    executed = cluster.run_workload(queries)
    assert len(executed) == 10
    # Reverse-shift phase (queries 6-10) must re-use cache: fewer scans than
    # the forward phase.
    fwd = sum(len(e.report.files_scanned) for e in executed[:5])
    back = sum(len(e.report.files_scanned) for e in executed[5:])
    assert back <= fwd


def test_cache_budget_respected_at_nodes(ptf):
    catalog, _ = ptf
    budget = 50_000
    cluster = make_cluster(ptf, "cost", budget=budget)
    queries = ptf1_workload(catalog.domain, n_queries=6, seed=8)
    for e in cluster.run_workload(queries):
        pass
    coord = cluster.coordinator
    per_node = {}
    for cid, node in coord.locations.items():
        fid = coord.chunk_file[cid]
        tree = coord.trees[fid]
        if cid in tree._leaves:
            per_node[node] = per_node.get(node, 0) + tree.get_chunk(cid).nbytes
    for node, used in per_node.items():
        assert used <= budget, f"node {node} over budget"
