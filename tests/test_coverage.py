"""Geometry decomposition and CoverageIndex unit tests.

Covers the box-subtraction edge cases the semantic-reuse rewrite leans on
(0/1/2k residual boxes, exact fit, touching-but-not-overlapping) and the
coverage-index consistency invariants across admit/evict/split-remap.
"""
import tempfile

import pytest

from repro.arrayio.catalog import FileReader, build_catalog
from repro.arrayio.generator import make_ptf_files
from repro.core.chunk import ChunkMeta
from repro.core.cluster import RawArrayCluster
from repro.core.coverage import CoverageIndex
from repro.core.geometry import Box, box_subtract, residual_boxes
from repro.core.workload import ptf2_workload


# ---------------------------------------------------------- box_subtract

def total_volume(boxes):
    return sum(b.volume() for b in boxes)


def pairwise_disjoint(boxes):
    return all(not a.overlaps(b)
               for i, a in enumerate(boxes) for b in boxes[i + 1:])


def test_subtract_disjoint_returns_original():
    a = Box((0, 0), (9, 9))
    b = Box((20, 20), (30, 30))
    assert box_subtract(a, b) == [a]


def test_subtract_touching_but_not_overlapping_returns_original():
    a = Box((0, 0), (9, 9))
    # Closed integer boxes: [10, 20] shares no cell with [0, 9].
    b = Box((10, 0), (20, 9))
    assert box_subtract(a, b) == [a]


def test_subtract_exact_fit_produces_zero_residuals():
    a = Box((3, 4), (8, 9))
    assert box_subtract(a, a) == []


def test_subtract_cover_superset_produces_zero_residuals():
    a = Box((3, 4), (8, 9))
    b = Box((0, 0), (100, 100))
    assert box_subtract(a, b) == []


def test_subtract_half_produces_one_residual():
    a = Box((0,), (9,))
    b = Box((5,), (9,))
    assert box_subtract(a, b) == [Box((0,), (4,))]


def test_subtract_strict_interior_produces_2k_residuals():
    # b strictly inside a along every one of k dimensions -> 2k slabs.
    for k in (1, 2, 3):
        a = Box((0,) * k, (9,) * k)
        b = Box((3,) * k, (6,) * k)
        out = box_subtract(a, b)
        assert len(out) == 2 * k
        assert pairwise_disjoint(out)
        assert total_volume(out) == a.volume() - b.volume()
        assert all(a.contains_box(piece) for piece in out)
        assert all(not piece.overlaps(b) for piece in out)


def test_subtract_corner_overlap_volume_conserved():
    a = Box((0, 0), (9, 9))
    b = Box((5, 5), (14, 14))
    out = box_subtract(a, b)
    inter = a.intersection(b)
    assert pairwise_disjoint(out)
    assert total_volume(out) == a.volume() - inter.volume()


# --------------------------------------------------------- residual_boxes

def test_residual_composes_to_full_coverage():
    q = Box((0, 0), (9, 9))
    covers = [Box((0, 0), (9, 4)), Box((0, 5), (4, 9)), Box((5, 5), (9, 9))]
    assert residual_boxes(q, covers) == []


def test_residual_partial_coverage_is_disjoint_and_exact():
    q = Box((0, 0), (9, 9))
    covers = [Box((0, 0), (3, 9)), Box((6, 0), (9, 9))]
    out = residual_boxes(q, covers)
    assert pairwise_disjoint(out)
    assert total_volume(out) == q.volume() - sum(c.volume() for c in covers)
    for piece in out:
        assert q.contains_box(piece)
        assert all(not piece.overlaps(c) for c in covers)


def test_residual_no_covers_returns_query():
    q = Box((0, 0), (9, 9))
    assert residual_boxes(q, []) == [q]


# ---------------------------------------------------------- CoverageIndex

def CM(cid, fid, lo, hi, n_cells=10, nbytes=100):
    return ChunkMeta(cid, fid, Box(lo, hi), n_cells, nbytes)


def test_index_add_remove_overlapping():
    idx = CoverageIndex()
    idx.add(CM(1, 0, (0, 0), (9, 9)))
    idx.add(CM(2, 0, (20, 20), (29, 29)))
    idx.add(CM(3, 1, (5, 5), (14, 14)))
    assert len(idx) == 3 and 1 in idx
    got = [m.chunk_id for m in idx.overlapping(Box((8, 8), (10, 10)))]
    assert got == [1, 3]
    idx.remove(1)
    assert 1 not in idx
    got = [m.chunk_id for m in idx.overlapping(Box((8, 8), (10, 10)))]
    assert got == [3]
    idx.remove(1)                       # idempotent on unknown ids
    assert len(idx) == 2


def test_index_file_level_prune_recomputes_after_removal():
    idx = CoverageIndex()
    idx.add(CM(1, 0, (0, 0), (9, 9)))
    idx.add(CM(2, 0, (100, 100), (109, 109)))
    # File bb spans both chunks; removing the far one must shrink it so the
    # probe near it no longer reaches file 0's entries.
    idx.remove(2)
    assert idx.overlapping(Box((100, 100), (109, 109))) == []
    assert [m.chunk_id for m in idx.overlapping(Box((0, 0), (1, 1)))] == [1]


def test_index_rewrite_covered_and_residual():
    idx = CoverageIndex()
    idx.add(CM(1, 0, (0, 0), (9, 9)))
    rw = idx.rewrite(Box((5, 5), (14, 14)))
    assert [s.chunk_id for s in rw.covered] == [1]
    assert rw.covered[0].box == Box((5, 5), (9, 9))
    assert not rw.fully_covered
    assert pairwise_disjoint(rw.residual)
    assert total_volume(rw.residual) == 10 * 10 - 5 * 5
    # Full coverage -> empty residual.
    rw2 = idx.rewrite(Box((2, 2), (7, 7)))
    assert rw2.fully_covered and rw2.covered_chunk_ids() == {1}


def test_index_remap_split_children_inherit_coverage():
    idx = CoverageIndex()
    idx.add(CM(1, 0, (0, 0), (9, 9)))
    idx.remap_split(1, [CM(2, 0, (0, 0), (4, 9)), CM(3, 0, (5, 0), (9, 9))])
    assert 1 not in idx and 2 in idx and 3 in idx
    assert idx.rewrite(Box((0, 0), (9, 9))).fully_covered
    # Remapping an unindexed parent is a no-op (uncached chunk split).
    idx.remap_split(99, [CM(4, 2, (0, 0), (1, 1))])
    assert 4 not in idx


# ------------------------------------- consistency through the real engine

@pytest.mark.parametrize("policy", ["cost", "chunk_lru", "file_lru"])
def test_coverage_index_tracks_residency_across_evict_and_split(policy):
    """After every admission batch the coverage index holds exactly the
    resident units (eviction pressure forces drops, Alg.-1 refinement
    forces split remaps), with the boxes of the live units."""
    files = make_ptf_files(n_files=8, cells_per_file_mean=800, seed=3)
    catalog, data = build_catalog(files, tempfile.mkdtemp(prefix="cov_"),
                                  "fits", n_nodes=4)
    cluster = RawArrayCluster(catalog, FileReader(catalog, data), 4, 6_000,
                              policy=policy, min_cells=64, reuse="on")
    coord = cluster.coordinator
    for q in ptf2_workload(catalog.domain, n_queries=6, eps=300):
        cluster.run_query(q)
        live = {cid for cid in coord.cache.cached
                if coord.chunks.meta_of(cid) is not None}
        assert coord.cache.coverage.ids() == live
        for cid in live:
            meta = coord.chunks.meta_of(cid)
            hits = [m for m in coord.cache.coverage.overlapping(meta.box)
                    if m.chunk_id == cid]
            assert len(hits) == 1 and hits[0].box == meta.box
