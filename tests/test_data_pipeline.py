"""Raw-array-cached input pipeline: batch correctness, epoch-2 cache reuse,
deterministic resume, and the cost-policy advantage on shifted epochs."""
import numpy as np
import pytest

from repro.arrayio.catalog import FileReader, build_catalog
from repro.data.pipeline import (RawArrayTokenPipeline, build_pipeline,
                                 make_token_corpus)


@pytest.fixture(scope="module")
def corpus_pipeline(tmp_path_factory):
    root = tmp_path_factory.mktemp("tokens")
    return build_pipeline(str(root), n_samples=64, seq=32, vocab=256,
                          n_files=6, n_hosts=4, batch=8,
                          host_budget_bytes=4 << 20, seed=1)


def test_batches_match_source(corpus_pipeline, tmp_path):
    files, lens = make_token_corpus(64, 32, 256, 6, seed=1)
    # Rebuild the dense source for verification.
    dense = np.zeros((65, 34), np.int64)
    have = np.zeros((65, 34), bool)
    for f in files:
        for (s, p), t in zip(f.coords, f.attrs[:, 0]):
            dense[s, p] = int(t)
            have[s, p] = True
    batch = corpus_pipeline.next_batch()
    assert batch["tokens"].shape == (8, 32)
    assert batch["labels"].shape == (8, 32)
    s_lo = 1
    for r in range(8):
        for c in range(32):
            s, p = s_lo + r, c + 1
            if have[s, p]:
                assert batch["tokens"][r, c] == dense[s, p]
            if have[s, p + 1]:
                assert batch["labels"][r, c] == dense[s, p + 1]
            else:
                assert batch["labels"][r, c] == -1


def test_second_epoch_hits_cache(corpus_pipeline):
    p = corpus_pipeline
    for _ in range(p.steps_per_epoch * 2):
        p.next_batch()
    st = p.stats
    assert st.cache_hit_steps > 0
    # Raw bytes scanned stop growing once the cache is warm.
    before = st.bytes_scanned
    p.next_batch()
    assert p.stats.bytes_scanned - before == 0


def test_deterministic_resume(tmp_path):
    a = build_pipeline(str(tmp_path / "a"), n_samples=48, seq=16, vocab=128,
                       n_files=4, n_hosts=2, batch=8, seed=3)
    b = build_pipeline(str(tmp_path / "b"), n_samples=48, seq=16, vocab=128,
                       n_files=4, n_hosts=2, batch=8, seed=3)
    for _ in range(4):
        a.next_batch()
    state = a.state()
    b.set_state(state)
    x, y = a.next_batch(), b.next_batch()
    np.testing.assert_array_equal(x["tokens"], y["tokens"])
    np.testing.assert_array_equal(x["labels"], y["labels"])


def test_cost_policy_scans_less_than_file_lru(tmp_path):
    stats = {}
    for policy in ("cost", "file_lru"):
        p = build_pipeline(str(tmp_path / policy), n_samples=64, seq=32,
                           vocab=256, n_files=6, n_hosts=4, batch=8,
                           host_budget_bytes=96 << 10, policy=policy,
                           seed=5)
        for _ in range(p.steps_per_epoch * 2):
            p.next_batch()
        stats[policy] = p.stats.bytes_scanned
    assert stats["cost"] <= stats["file_lru"]
