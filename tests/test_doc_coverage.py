"""Doc-coverage gate: every public class/function in ``src/repro/core``,
``src/repro/backend``, ``src/repro/kernels``, ``src/repro/obs``, and
``src/repro/faults`` must carry a docstring (100% aggregate), enforced
by the stdlib ``tools/check_docstrings.py`` checker (an ``interrogate``
equivalent that needs no extra dependency). CI runs the same command
standalone."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_core_doc_coverage_gate():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docstrings.py"),
         str(REPO / "src" / "repro" / "core"),
         str(REPO / "src" / "repro" / "backend"),
         str(REPO / "src" / "repro" / "kernels"),
         str(REPO / "src" / "repro" / "obs"),
         str(REPO / "src" / "repro" / "faults"), "--fail-under", "100"],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "PASSED" in proc.stdout


def test_checker_flags_missing_docstrings(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text('"""Module documented."""\n\n\ndef public():\n    pass\n')
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docstrings.py"),
         str(bad), "--fail-under", "90"],
        capture_output=True, text=True)
    assert proc.returncode == 1
    assert "MISSING" in proc.stdout and "public" in proc.stdout
