"""Deliverable (e) guard: the multi-pod dry-run lowers+compiles in a fresh
subprocess (512 forced host devices) for representative cells, and the
roofline row has sane fields."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_dryrun(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=540)


@pytest.mark.slow
def test_single_cell_multipod(tmp_path):
    out = str(tmp_path / "r.jsonl")
    res = run_dryrun("--arch", "olmo-1b", "--shape", "decode_32k",
                     "--mesh", "multipod", "--out", out)
    assert res.returncode == 0, res.stdout + res.stderr
    row = json.loads(open(out).readline())
    assert row["status"] == "ok"
    assert row["chips"] == 512
    assert row["hlo_flops_per_chip"] > 0
    assert row["memory_s"] > 0
    assert row["bottleneck"] in ("compute", "memory", "collective", "serial")
    assert row["memory_analysis"]["temp_bytes"] is not None


@pytest.mark.slow
def test_skip_cells_are_reported(tmp_path):
    out = str(tmp_path / "s.jsonl")
    res = run_dryrun("--arch", "hubert-xlarge", "--shape", "decode_32k",
                     "--mesh", "pod", "--out", out)
    assert res.returncode == 0, res.stdout + res.stderr
    row = json.loads(open(out).readline())
    assert row["status"] == "skip"
    assert "encoder-only" in row["reason"]
