import math

import pytest

from repro.core.eviction import (LRUCache, Triple, cost_based_eviction)


def T(l, f, chunks):
    return Triple(l, f, frozenset(chunks))


CHUNKS = {1: 100, 2: 100, 3: 100, 4: 100, 5: 300, 6: 50}
FILES = {0: 10_000, 1: 10_000, 2: 500}


def test_current_query_always_kept():
    res = cost_based_eviction([], [T(3, 0, [1, 2])], budget_bytes=50,
                              chunk_bytes=CHUNKS, file_bytes=FILES)
    assert res.cached_chunks == {1, 2}
    assert len(res.state) == 1


def test_recent_query_preferred():
    history = [T(1, 0, [1]), T(2, 1, [2])]
    res = cost_based_eviction(history, [T(3, 2, [6])], budget_bytes=160,
                              chunk_bytes=CHUNKS, file_bytes=FILES)
    # Only one of chunks 1/2 fits; exponential decay favors query 2.
    assert 2 in res.cached_chunks and 1 not in res.cached_chunks
    assert 6 in res.cached_chunks


def test_expensive_file_preferred_over_cheap():
    # Same query index; file 0 costs 10000 to scan, file 2 costs 500.
    history = [T(1, 0, [1]), T(1, 2, [2])]
    res = cost_based_eviction(history, [], budget_bytes=100,
                              chunk_bytes=CHUNKS, file_bytes=FILES)
    assert 1 in res.cached_chunks and 2 not in res.cached_chunks


def test_shared_chunk_boost():
    # Keeping (1,2) halves what it takes to complete triple (2,3): its cost
    # is boosted (line 6) and it must beat the cheap-file triple (4,).
    history = [T(5, 0, [1, 2]), T(2, 1, [2, 3]), T(2, 2, [4])]
    res = cost_based_eviction(history, [], budget_bytes=300,
                              chunk_bytes=CHUNKS, file_bytes=FILES)
    assert {1, 2, 3} <= res.cached_chunks
    assert 4 not in res.cached_chunks


def test_fully_cached_triples_are_free():
    history = [T(1, 0, [1]), T(2, 1, [1])]   # same chunk via two queries
    res = cost_based_eviction(history, [], budget_bytes=100,
                              chunk_bytes=CHUNKS, file_bytes=FILES)
    assert res.cached_chunks == {1}
    assert res.kept_from_history == 2        # second one rides along free


def test_budget_respected():
    history = [T(i, 0, [i]) for i in (1, 2, 3, 4)]
    res = cost_based_eviction(history, [], budget_bytes=250,
                              chunk_bytes=CHUNKS, file_bytes=FILES)
    used = sum(CHUNKS[c] for c in res.cached_chunks)
    assert used <= 250
    # Greedy by recency: chunks 4 and 3 kept.
    assert res.cached_chunks == {3, 4}


def test_deferred_triple_fits_after_boost():
    # (5,6): 350 bytes does not fit alone in 150; after chunk 5 is cached by
    # the newer triple, the leftover 50 fits.
    history = [T(1, 0, [5, 6]), T(9, 1, [5])]
    res = cost_based_eviction(history, [], budget_bytes=350,
                              chunk_bytes=CHUNKS, file_bytes=FILES)
    assert {5, 6} <= res.cached_chunks


def test_lru_cache_basics():
    lru = LRUCache(250)
    assert lru.admit(1, 100) == []
    assert lru.admit(2, 100) == []
    lru.touch(1)                     # 2 is now least recent
    assert lru.admit(3, 100) == [2]
    assert 1 in lru and 3 in lru and 2 not in lru
    # Items over budget are rejected outright.
    assert lru.admit(9, 999) == []
    assert 9 not in lru


def test_lru_rename_preserves_position():
    lru = LRUCache(300)
    lru.admit(1, 100)
    lru.admit(2, 100)
    lru.rename(1, [(10, 50), (11, 50)])
    assert 10 in lru and 11 in lru and 1 not in lru
    # Children inherit the oldest slot: they evict first.
    evicted = lru.admit(3, 200)
    assert set(evicted) == {10, 11}


def test_lfu_cache_prefers_frequent_items():
    from repro.core.eviction import LFUCache
    lfu = LFUCache(250)
    assert lfu.admit(1, 100) == []
    assert lfu.admit(2, 100) == []
    lfu.touch(1)
    lfu.touch(1)                     # 1 is hot, 2 used once
    assert lfu.admit(3, 100) == [2]  # LFU victim, despite 2 being recent
    assert 1 in lfu and 3 in lfu and 2 not in lfu
    # Items over budget are rejected outright.
    assert lfu.admit(9, 999) == []
    assert 9 not in lfu


def test_lfu_rename_inherits_frequency():
    from repro.core.eviction import LFUCache
    lfu = LFUCache(300)
    lfu.admit(1, 100)
    lfu.touch(1)
    lfu.touch(1)
    lfu.admit(2, 100)
    lfu.rename(1, [(10, 50), (11, 50)])
    assert 10 in lfu and 11 in lfu and 1 not in lfu
    # Children carry the parent's frequency: the cold item 2 evicts first.
    assert lfu.admit(3, 200) == [2]
