"""Transient-fault pipeline: seeded injection, retry/timeout/backoff,
degraded-mode serving, and the cross-layer invariant auditor.

Covers the fault-injector determinism contract (same seed → identical
schedule, per-site stream isolation), the checksum registry, the retrier
budget semantics (attempts, timeout, backoff on a virtual clock), the
``fail_node`` guard rails, typed ``ScanError`` on missing/truncated
catalog files, degraded-result geometry, and the faults-off seed-parity
gate. The hypothesis property at the bottom is the satellite acceptance
test: for ANY seeded fault schedule, completed queries are bit-identical
to the fault-free reference, ``DegradedResult`` regions are exactly the
retried-out sub-boxes, and the auditor reports zero violations.
"""
import functools
import os
import tempfile

import numpy as np
import pytest

from repro.arrayio.catalog import FileReader, build_catalog
from repro.arrayio.generator import make_ptf_files
from repro.core.cluster import RawArrayCluster, workload_summary
from repro.core.geometry import residual_boxes
from repro.core.workload import zipf_workload
from repro.faults import (FAULT_KINDS, FAULT_POINTS, ChecksumRegistry,
                          DegradedResult, FaultInjector, FaultSpec,
                          InvariantAuditor, Retrier, RetryPolicy,
                          make_degraded, make_faults, make_retry)
from repro.faults.errors import (BatchInFlightError, ChecksumError,
                                 InjectedFaultError, RetryExhaustedError,
                                 ScanError, TransientFaultError)
from repro.obs.clock import ManualClock

N_NODES = 4


# ----------------------------------------------------------- injector


def test_faultspec_validation():
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultSpec("ship.nope", 0.1)
    with pytest.raises(ValueError, match="rate"):
        FaultSpec("scan.read", 1.5)
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("scan.read", 0.1, kinds=("explode",))
    with pytest.raises(ValueError, match="must not be empty"):
        FaultSpec("scan.read", 0.1, kinds=())
    with pytest.raises(ValueError, match="duplicate"):
        FaultInjector([FaultSpec("scan.read", 0.1),
                       FaultSpec("scan.read", 0.2)])
    with pytest.raises(ValueError, match="faults must be"):
        make_faults(123)
    assert make_faults(None) is None and make_faults("off") is None
    inj = make_faults({"scan.read": 0.5}, seed=3)
    assert inj.specs["scan.read"].rate == 0.5 and inj.seed == 3
    assert make_faults(inj) is inj


def _cross_all(inj, n=60):
    for _ in range(n):
        for p in FAULT_POINTS:
            try:
                inj.fault_point(p, payload=np.zeros(4))
            except TransientFaultError:
                pass


def test_injector_same_seed_reproduces_schedule():
    a = FaultInjector.storm(0.3, seed=7)
    b = FaultInjector.storm(0.3, seed=7)
    _cross_all(a), _cross_all(b)
    assert a.schedule_log == b.schedule_log and a.schedule_log
    assert a.counters() == b.counters()
    c = FaultInjector.storm(0.3, seed=8)
    _cross_all(c)
    assert c.schedule_log != a.schedule_log


def test_injector_per_site_streams_isolated():
    # A site's schedule depends only on its own crossing count: crossing
    # OTHER points between its crossings must not perturb it.
    alone = FaultInjector([FaultSpec("ship.transfer", 0.4)], seed=5)
    mixed = FaultInjector([FaultSpec("ship.transfer", 0.4)], seed=5)
    for i in range(80):
        for inj in (alone, mixed):
            try:
                inj.fault_point("ship.transfer")
            except InjectedFaultError:
                pass
        if i % 2:                      # extra crossings on another site
            mixed.fault_point("scan.read")
    assert alone.schedule_log == mixed.schedule_log


def test_injector_kinds():
    # error: typed, carries point + context
    inj = FaultInjector([FaultSpec("scan.read", 1.0)])
    with pytest.raises(InjectedFaultError) as ei:
        inj.fault_point("scan.read", file=9)
    assert ei.value.point == "scan.read" and ei.value.context["file"] == 9
    # corrupt: bit-flipped COPY; original untouched; payload-less → error
    inj = FaultInjector([FaultSpec("ship.transfer", 1.0,
                                   kinds=("corrupt",))])
    clean = np.arange(16, dtype=np.int64)
    keep = clean.copy()
    dirty = inj.fault_point("ship.transfer", payload=clean)
    assert not np.array_equal(dirty, clean)
    assert np.array_equal(clean, keep)
    with pytest.raises(InjectedFaultError):
        inj.fault_point("ship.transfer")     # no payload to corrupt
    # latency: virtual on a manual clock, accumulated in latency_s
    clock = ManualClock()
    inj = FaultInjector([FaultSpec("prep.build", 1.0, kinds=("latency",),
                                   delay_s=0.25)], clock=clock)
    t0 = clock.now()
    assert inj.fault_point("prep.build", payload="x") == "x"
    assert clock.now() - t0 == pytest.approx(0.25)
    assert inj.latency_s == pytest.approx(0.25)
    # max_fires caps total fires
    inj = FaultInjector([FaultSpec("scan.read", 1.0, max_fires=2)])
    fired = 0
    for _ in range(10):
        try:
            inj.fault_point("scan.read")
        except InjectedFaultError:
            fired += 1
    assert fired == 2 and inj.injected == 2
    with pytest.raises(ValueError, match="unknown fault point"):
        inj.fault_point("not.a.point")


def test_checksum_registry():
    reg = ChecksumRegistry()
    payload = np.arange(32, dtype=np.float32)
    crc = reg.record(7, payload)
    assert reg.record(7, np.zeros(1)) == crc    # record is first-wins
    reg.verify(7, payload.copy())               # clean copy passes
    bad = payload.copy()
    bad.view(np.uint8)[3] ^= 0xFF
    with pytest.raises(ChecksumError) as ei:
        reg.verify(7, bad)
    assert ei.value.chunk_id == 7 and reg.mismatches == 1
    # lifecycle hygiene: listener hooks forget retired ids
    reg.on_drop(7)
    assert len(reg) == 0
    reg.record(8, payload), reg.record(9, payload)
    reg.on_split(8, [])

    class _State:
        cached = {10}
    reg.reconcile(_State())
    assert len(reg) == 0


# ------------------------------------------------------------ retrier


def test_retry_policy_validation_and_make():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_multiplier=0.5)
    with pytest.raises(ValueError):
        RetryPolicy(timeout_s=0)
    with pytest.raises(ValueError, match="retry must be"):
        make_retry(7)
    assert make_retry(None) == RetryPolicy() == make_retry("default")
    p = make_retry({"max_attempts": 5, "backoff_base_s": 0.01})
    assert p.max_attempts == 5 and make_retry(p) is p
    assert p.backoff_s(2) == pytest.approx(0.01 * 4)


def test_retrier_succeeds_after_transients():
    clock = ManualClock()
    r = Retrier(RetryPolicy(max_attempts=4, backoff_base_s=1.0), clock=clock)
    seen = []

    def fn(attempt):
        seen.append(attempt)
        if attempt < 2:
            raise InjectedFaultError("ship.transfer")
        return "ok"

    assert r.call("ship.transfer", fn) == "ok"
    assert seen == [0, 1, 2] and r.retries == 2 and r.giveups == 0
    assert r.backoff_s == pytest.approx(1.0 + 2.0)   # virtual, no sleep
    assert clock.now() == pytest.approx(3.0)


def test_retrier_exhaustion_and_non_transient():
    r = Retrier(RetryPolicy(max_attempts=3, backoff_base_s=0.0),
                clock=ManualClock())
    with pytest.raises(RetryExhaustedError) as ei:
        r.call("scan.read", lambda a: (_ for _ in ()).throw(
            InjectedFaultError("scan.read")))
    assert ei.value.op == "scan.read" and ei.value.attempts == 3
    assert not ei.value.timed_out and r.giveups == 1
    assert isinstance(ei.value.last_error, InjectedFaultError)
    # non-transient errors escape immediately, uncounted
    with pytest.raises(KeyError):
        r.call("scan.read", lambda a: {}[1])
    assert r.giveups == 1


def test_retrier_timeout_budget():
    clock = ManualClock()
    r = Retrier(RetryPolicy(max_attempts=10, backoff_base_s=4.0,
                            timeout_s=5.0), clock=clock)
    with pytest.raises(RetryExhaustedError) as ei:
        r.call("prep.build", lambda a: (_ for _ in ()).throw(
            InjectedFaultError("prep.build")))
    # first backoff (4s) fits the 5s budget, the second (8s) cannot
    assert ei.value.timed_out and ei.value.attempts == 2
    assert r.timeouts == 1 and r.retries == 1


def test_make_degraded_residual_geometry():
    from repro.core.geometry import Box
    q = Box((0, 0), (100, 100))
    failed = (Box((0, 0), (40, 100)),)
    d = make_degraded(q, failed, ("scan.read",), matches=12)
    assert isinstance(d, DegradedResult) and not d.fully_failed
    assert d.served_boxes == tuple(residual_boxes(q, list(failed)))
    assert d.matches_lower_bound == 12
    total = make_degraded(q, (q,), ("ship.transfer",))
    assert total.fully_failed and total.served_boxes == ()


# ----------------------------------------------------- cluster fixture


@pytest.fixture(scope="module")
def dataset():
    # 12 files over 4 nodes: query boxes at field_frac=0.5 span files on
    # several nodes, so join plans carry live transfer routes and the
    # ship.transfer fault point actually gets crossings.
    files = make_ptf_files(n_files=12, cells_per_file_mean=500, seed=13)
    catalog, data = build_catalog(files, tempfile.mkdtemp(prefix="faults_"),
                                  "fits", n_nodes=N_NODES)
    return catalog, data


def _queries(catalog, n=10, seed=3):
    # field_frac=0.5 spans files on several nodes → live transfer routes
    return zipf_workload(catalog.domain, n_queries=n, n_templates=3,
                         s=1.5, eps=120, field_frac=0.5, seed=seed)


def _cluster(dataset, faults="off", backend="simulated", **kw):
    catalog, data = dataset
    return RawArrayCluster(catalog, FileReader(catalog, data), N_NODES,
                           300_000, policy="cost", min_cells=64,
                           backend=backend, replication="hot", replica_k=2,
                           replication_threshold=2.0, faults=faults, **kw)


# ------------------------------------------------- fail_node guard rails


def test_fail_node_rejects_bad_nodes(dataset):
    cluster = _cluster(dataset)
    cluster.run_workload(_queries(dataset[0], n=4))
    with pytest.raises(ValueError, match="outside"):
        cluster.fail_node(99)
    with pytest.raises(ValueError, match="outside"):
        cluster.fail_node(-1)
    with pytest.raises(ValueError, match="integer"):
        cluster.fail_node("node0")


def test_fail_node_twice_without_batch_rejected(dataset):
    cluster = _cluster(dataset)
    cluster.run_workload(_queries(dataset[0], n=4))
    cluster.fail_node(1)
    with pytest.raises(ValueError, match="already failed"):
        cluster.fail_node(1)
    cluster.fail_node(2)               # a DIFFERENT node is fine
    cluster.run_workload(_queries(dataset[0], n=2, seed=9))
    cluster.fail_node(1)               # re-armed after an admission batch


def test_fail_node_mid_batch_is_typed_error(dataset):
    # A listener that crash-restarts a node during the in-batch
    # sync_devices reconcile must get the typed in-flight rejection,
    # not silently corrupt residency accounting.
    cluster = _cluster(dataset)
    caught = []

    class _Saboteur:
        def on_drop(self, cid):
            pass

        def on_split(self, parent, leaves):
            pass

        def reconcile(self, state):
            try:
                cluster.fail_node(0)
            except BatchInFlightError as e:
                caught.append(e)

    cluster.coordinator.cache.add_listener(_Saboteur())
    cluster.run_workload(_queries(dataset[0], n=2))
    assert caught and all(isinstance(e, BatchInFlightError) for e in caught)


# --------------------------------------------------- typed scan errors


@pytest.fixture()
def disk_dataset(tmp_path):
    files = make_ptf_files(n_files=4, cells_per_file_mean=250, seed=17)
    catalog, _ = build_catalog(files, str(tmp_path), "fits",
                               n_nodes=N_NODES)
    return catalog


def test_scan_error_missing_and_truncated_file(disk_dataset):
    catalog = disk_dataset
    reader = FileReader(catalog)       # no in-memory data → real decode
    victim = catalog.files[0]
    with open(victim.path, "rb") as fh:
        blob = fh.read()
    with open(victim.path, "wb") as fh:
        fh.write(blob[: len(blob) // 2])      # truncate
    with pytest.raises(ScanError) as ei:
        reader.read(victim.file_id)
    assert ei.value.file_id == victim.file_id
    assert ei.value.path == victim.path and ei.value.cause is not None
    os.remove(victim.path)                    # now missing entirely
    with pytest.raises(ScanError) as ei:
        FileReader(catalog).read(victim.file_id)
    assert isinstance(ei.value.cause, OSError)


def test_scan_error_routes_through_degrade_path(disk_dataset):
    catalog = disk_dataset
    victim = catalog.files[0]
    os.remove(victim.path)
    queries = [q for q in _queries(catalog, n=8, seed=5)
               if q.box.intersection(victim.box) is not None]
    assert queries, "workload never touched the victim file"
    # faults off: the typed error propagates to the caller, annotated
    # with the queried box
    cluster = RawArrayCluster(catalog, FileReader(catalog), N_NODES,
                              300_000, policy="cost", min_cells=64)
    with pytest.raises(ScanError) as ei:
        cluster.run_workload(queries[:1])
    assert ei.value.file_id == victim.file_id
    assert ei.value.box == queries[0].box
    # faults on (zero injection rate — the retry/degrade machinery alone):
    # the scan retries out and the query degrades over file ∩ query
    cluster = RawArrayCluster(catalog, FileReader(catalog), N_NODES,
                              300_000, policy="cost", min_cells=64,
                              faults=make_faults({}))
    executed = cluster.run_workload(queries[:2])
    for e, q in zip(executed, queries[:2]):
        assert e.degraded is not None
        assert "scan.read" in e.degraded.failed_ops
        assert victim.box.intersection(q.box) in e.degraded.failed_boxes
    assert cluster.coordinator.auditor.violations_total == 0


# ------------------------------------- degraded serving + seed parity


_FAULT_KEYS = ("faults_injected", "retries", "retry_backoff_s",
               "retry_giveups", "transfer_reroutes", "raw_fallbacks",
               "checksum_mismatch", "degraded_queries", "audit_violations")


def test_faults_off_leaks_no_counters(dataset):
    cluster = _cluster(dataset, faults="off")
    executed = cluster.run_workload(_queries(dataset[0], n=6), batch_size=3)
    assert cluster.coordinator.faults is None
    assert cluster.coordinator.retrier is None
    assert cluster.coordinator.auditor is None          # audit="auto"
    for e in executed:
        assert e.degraded is None
        for key in _FAULT_KEYS:
            assert getattr(e, key) is None, key
    summ = workload_summary(executed)
    assert not set(_FAULT_KEYS) & set(summ)


def test_total_scan_outage_degrades_exactly(dataset):
    # scan.read always fails → nothing can be planned; every query must
    # come back as a DegradedResult whose failed boxes are exactly the
    # candidate files' overlap with the query box (and served = residual).
    catalog, _ = dataset
    faults = FaultInjector([FaultSpec("scan.read", 1.0)], seed=0)
    cluster = _cluster(dataset, faults=faults)
    queries = _queries(catalog, n=4)
    executed = cluster.run_workload(queries)
    assert all(e.degraded is not None for e in executed)
    for e, q in zip(executed, queries):
        expected = {f.box.intersection(q.box) for f in catalog.files
                    if f.box.intersection(q.box) is not None}
        assert set(e.degraded.failed_boxes) == expected
        assert set(e.degraded.served_boxes) == set(
            residual_boxes(q.box, list(e.degraded.failed_boxes)))
        assert e.matches == 0 and e.degraded_queries == 1
    summ = workload_summary(executed)
    assert summ["degraded_queries"] == len(queries)
    assert summ["retry_giveups"] > 0
    assert cluster.coordinator.auditor.violations_total == 0


# --------------------------------------------- property test (storms)


def _storm_specs(rates, kinds_mask, delay_s=0.001):
    specs = []
    for point, rate, mask in zip(FAULT_POINTS, rates, kinds_mask):
        kinds = tuple(k for k, on in zip(FAULT_KINDS, mask) if on)
        specs.append(FaultSpec(point, rate, kinds=kinds or ("error",),
                               delay_s=delay_s))
    return specs


@functools.lru_cache(maxsize=None)
def _prop_state(wl_seed):
    """(catalog, data, queries, fault-free match list) per workload."""
    files = make_ptf_files(n_files=8, cells_per_file_mean=350, seed=13)
    catalog, data = build_catalog(files, tempfile.mkdtemp(prefix="fprop_"),
                                  "fits", n_nodes=N_NODES)
    queries = _queries(catalog, n=8, seed=wl_seed)
    cluster = _cluster((catalog, data))
    ref = [e.matches for e in cluster.run_workload(queries, batch_size=3)]
    return catalog, data, queries, ref


def _assert_storm_invariants(dataset, queries, ref, injector,
                             backend="simulated"):
    cluster = _cluster(dataset, faults=injector, backend=backend)
    executed = cluster.run_workload(queries, batch_size=3)
    for i, (e, q, m) in enumerate(zip(executed, queries, ref)):
        if e.degraded is None:
            # completed queries must be bit-identical to the reference
            assert e.matches == m, f"query {i} diverged under faults"
        else:
            # degraded regions are exactly the retried-out sub-boxes
            d = e.degraded
            assert d.query_box == q.box and d.failed_ops
            for fb in d.failed_boxes:
                assert q.box.intersection(fb) == fb
            assert set(d.served_boxes) == set(
                residual_boxes(q.box, list(d.failed_boxes)))
    assert cluster.coordinator.auditor.violations_total == 0
    return cluster, executed


def test_storm_invariants_fixed_seed_simulated(dataset):
    catalog, data = dataset
    queries = _queries(catalog, n=10)
    ref = [e.matches for e in
           _cluster(dataset).run_workload(queries, batch_size=3)]
    cluster, executed = _assert_storm_invariants(
        dataset, queries, ref, FaultInjector.storm(0.3, seed=42))
    summ = workload_summary(executed)
    assert summ["faults_injected"] > 0 and summ["retries"] > 0
    # acceptance: the same seed reproduces the identical schedule and
    # counters twice
    cluster2, executed2 = _assert_storm_invariants(
        dataset, queries, ref, FaultInjector.storm(0.3, seed=42))
    assert (cluster.coordinator.faults.schedule_log
            == cluster2.coordinator.faults.schedule_log)
    assert (cluster.coordinator.faults.counters()
            == cluster2.coordinator.faults.counters())
    summ2 = workload_summary(executed2)
    for key in _FAULT_KEYS + ("total_matches_sum",):
        assert summ.get(key) == summ2.get(key), key
    assert [e.matches for e in executed] == [e.matches for e in executed2]


def test_storm_invariants_fixed_seed_mesh(dataset):
    pytest.importorskip("jax")
    catalog, data = dataset
    queries = _queries(catalog, n=6)
    ref = [e.matches for e in
           _cluster(dataset, backend="jax_mesh")
           .run_workload(queries, batch_size=3)]
    _assert_storm_invariants(dataset, queries, ref,
                             FaultInjector.storm(0.15, seed=42),
                             backend="jax_mesh")


# Guarded import (NOT importorskip: that would skip the whole module —
# the deterministic tests above must run without the dev extra).
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @given(wl_seed=st.integers(0, 2),
           fault_seed=st.integers(0, 10_000),
           rates=st.tuples(*[st.floats(0.0, 0.4) for _ in FAULT_POINTS]),
           kinds_mask=st.tuples(*[st.tuples(st.booleans(), st.booleans(),
                                            st.booleans())
                                  for _ in FAULT_POINTS]))
    @settings(max_examples=10, deadline=None)
    def test_any_fault_schedule_preserves_results(wl_seed, fault_seed,
                                                  rates, kinds_mask):
        """Satellite acceptance property: ANY seeded fault schedule
        (random points × rates × kinds × workloads) leaves completed
        queries bit-identical to the fault-free reference, makes
        ``DegradedResult`` regions exactly the retried-out sub-boxes,
        and keeps the invariant auditor at zero violations."""
        catalog, data, queries, ref = _prop_state(wl_seed)
        injector = FaultInjector(_storm_specs(rates, kinds_mask),
                                 seed=fault_seed)
        _assert_storm_invariants((catalog, data), queries, ref, injector)
else:                                                 # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed (dev extra)")
    def test_any_fault_schedule_preserves_results():
        """Placeholder so the skip is visible when hypothesis is absent."""
