import numpy as np
import pytest

from repro.core.geometry import (Box, bounding_box, enclosing, expand,
                                 points_in_box, split_boundaries)


def test_box_basics():
    b = Box((1, 1), (4, 8))
    assert b.volume() == 32
    assert b.side(0) == 4 and b.side(1) == 8
    assert b.contains_point((1, 8)) and not b.contains_point((0, 8))
    assert b.overlaps(Box((4, 8), (9, 9)))
    assert not b.overlaps(Box((5, 1), (9, 9)))
    assert b.intersection(Box((3, 4), (10, 10))) == Box((3, 4), (4, 8))
    assert b.intersection(Box((5, 9), (6, 10))) is None
    assert b.union_bb(Box((0, 2), (2, 9))) == Box((0, 1), (4, 9))


def test_empty_box_raises():
    with pytest.raises(ValueError):
        Box((2, 1), (1, 5))


def test_bounding_box_and_membership():
    pts = np.array([[1, 5], [3, 2], [2, 9]])
    bb = bounding_box(pts)
    assert bb == Box((1, 2), (3, 9))
    assert bounding_box(np.zeros((0, 2), np.int64)) is None
    mask = points_in_box(pts, Box((1, 2), (2, 9)))
    assert mask.tolist() == [True, False, True]


def test_expand_clips_to_domain():
    dom = Box((1, 1), (10, 10))
    assert expand(Box((1, 4), (2, 5)), 2, dom) == Box((1, 2), (4, 7))


def test_split_boundaries_faces():
    q = Box((3, 3), (6, 6))
    bb = Box((1, 4), (9, 5))        # q bisects bb only along dim 0
    bnds = set(split_boundaries(q, bb))
    assert bnds == {(0, 2), (0, 6)}
    # bb inside q -> no face passes through
    assert split_boundaries(q, Box((4, 4), (5, 5))) == []
