"""Loop-aware HLO cost analyzer: trip-count recovery, fusion/while walking,
collective accounting, in-place aliasing, invariant-carry discounts."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import HloAnalyzer, parse_shape


def cost_of(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return HloAnalyzer(comp.as_text()).module_cost()


def test_parse_shape():
    s = parse_shape("f32[128,256]{1,0}")
    assert s.elements == 128 * 256 and s.nbytes == 128 * 256 * 4
    t = parse_shape("(s32[], bf16[2,3])")
    assert t.nbytes == 4 + 12
    assert parse_shape("pred[7]").nbytes == 7


def test_scan_flops_multiplied():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=10)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    cost = cost_of(f, x, w)
    expect = 10 * 2 * 64 ** 3
    assert 0.9 * expect < cost.flops < 1.3 * expect
    assert cost.seq_iters >= 10


def test_nested_scan():
    def f(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=5)
            return jnp.tanh(y), None
        return jax.lax.scan(outer, x, None, length=3)[0]

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    cost = cost_of(f, x, w)
    expect = 15 * 2 * 32 ** 3
    assert 0.9 * expect < cost.flops < 1.4 * expect
    assert cost.seq_iters >= 15


def test_dus_aliasing_counts_slice_not_buffer():
    big = jax.ShapeDtypeStruct((4096, 1024), jnp.float32)   # 16 MB
    small = jax.ShapeDtypeStruct((1, 1024), jnp.float32)    # 4 KB

    def f(buf, row):
        return jax.lax.dynamic_update_slice(buf, row, (7, 0))

    comp = jax.jit(f, donate_argnums=(0,)).lower(big, small).compile()
    cost = HloAnalyzer(comp.as_text()).module_cost()
    # With the buffer donated the update is in place: charge ~the update
    # region, not ~2x the 16MB buffer.
    assert cost.bytes < 1e6


def test_invariant_weight_discount():
    # h_t = tanh(h_{t-1} @ W): W is a loop-invariant carry. Traffic should
    # be ~one pass over W, not 100x.
    def f(h, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, h, None, length=100)[0]

    h = jax.ShapeDtypeStruct((8, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)     # 1 MB
    cost = cost_of(f, h, w)
    assert cost.bytes < 100 * 512 * 512 * 4 * 0.5


def test_dot_flops_from_contracting_dims():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    cost = cost_of(f, a, b)
    expect = 2 * 4 * 32 * 16 * 64
    assert 0.9 * expect < cost.flops < 1.2 * expect
