"""Property-based tests (hypothesis), consolidated from the per-module
suites so the rest of the suite collects when the ``hypothesis`` dev extra
is not installed (``pip install -e .[dev]`` provides it)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.backend.artifacts import JoinArtifactCache  # noqa: E402
from repro.core.cache_state import CacheState  # noqa: E402
from repro.core.eviction import Triple, cost_based_eviction  # noqa: E402
from repro.core.geometry import (Box, bounding_box, box_subtract,  # noqa: E402
                                 expand, points_in_box, residual_boxes)
from repro.core.policies import (HotChunkReplication,  # noqa: E402
                                 ReplicationContext)
from repro.core.result_cache import ResultCache  # noqa: E402
from repro.core.rtree import EvolvingRTree  # noqa: E402


# ------------------------------------------------------------- eviction

def T(l, f, chunks):
    return Triple(l, f, frozenset(chunks))


@given(st.integers(0, 10_000), st.integers(50, 2000))
@settings(max_examples=40, deadline=None)
def test_budget_never_exceeded_property(seed, budget):
    import random
    rnd = random.Random(seed)
    chunk_bytes = {i: rnd.randint(10, 200) for i in range(30)}
    file_bytes = {i: rnd.randint(500, 5000) for i in range(6)}
    history = []
    for l in range(1, 12):
        f = rnd.randrange(6)
        cs = rnd.sample(range(30), rnd.randint(1, 5))
        history.append(T(l, f, cs))
    current = [T(12, 0, rnd.sample(range(30), 3))]
    res = cost_based_eviction(history, current, budget,
                              chunk_bytes, file_bytes)
    used = sum(chunk_bytes[c] for c in res.cached_chunks)
    current_bytes = sum(chunk_bytes[c] for c in
                        set().union(*[t.chunk_ids for t in current]))
    # Current query may overflow on its own; beyond that, budget holds.
    assert used <= max(budget, current_bytes)
    for t in res.state:
        assert t.chunk_ids <= res.cached_chunks


# ---------------------------------------------------------- replication

def _random_state_ops(rnd, state, n_nodes, n_ops=60):
    """Drive a CacheState through a random admit/drop/split-like/fail
    sequence using only the accessor surface; yields after every op."""
    for _ in range(n_ops):
        op = rnd.randrange(5)
        cid = rnd.randint(1, 24)
        if op == 0:                      # admit with a random replica set
            state.cached.add(cid)
            ks = rnd.randint(1, n_nodes)
            state.set_replicas(
                cid, tuple(rnd.randrange(n_nodes) for _ in range(ks)))
        elif op == 1:                    # admit single-copy
            state.cached.add(cid)
            state.ensure_location(cid, rnd.randrange(n_nodes))
        elif op == 2:                    # full drop
            state.drop(cid)
        elif op == 3:                    # one copy dies
            state.drop_replica(cid, rnd.randrange(n_nodes))
        else:                            # node failure: every copy there
            node = rnd.randrange(n_nodes)
            for c, reps in state.location_items():
                if node in reps:
                    state.drop_replica(c, node)
        yield


@given(st.integers(0, 2**31 - 1), st.integers(2, 6))
@settings(max_examples=40, deadline=None)
def test_replica_sets_wellformed_and_bytes_account_per_replica(seed,
                                                               n_nodes):
    """After ANY accessor-driven op sequence: every stored replica set
    is a non-empty de-duplicated tuple whose head is the primary, every
    cached chunk stays located, and per-node byte accounting equals the
    sum of per-replica charges (= ``cached_bytes``)."""
    import random
    rnd = random.Random(seed)
    state = CacheState(n_nodes=n_nodes, node_budget_bytes=10_000)
    chunk_bytes = {cid: rnd.randint(1, 500) for cid in range(1, 25)}
    for _ in _random_state_ops(rnd, state, n_nodes):
        for c, reps in state.location_items():
            assert reps, "empty replica tuple stored"
            assert len(set(reps)) == len(reps), "duplicate replica"
            assert state.node_of(c) == reps[0]
            assert all(0 <= n < n_nodes for n in reps)
        assert all(state.replicas_of(c) for c in state.cached)
        per_node = state.bytes_by_node(chunk_bytes)
        assert sum(per_node.values()) == sum(
            chunk_bytes[c] * len(state.replicas_of(c))
            for c in state.cached)
        assert sum(per_node.values()) == state.cached_bytes(chunk_bytes)


@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(2, 5),
       st.sampled_from(["global", "node"]))
@settings(max_examples=40, deadline=None)
def test_hot_replication_never_touches_residency_or_primaries(
        seed, k, n_nodes, scope):
    """A replication round only ADDS copies within leftover budget: the
    resident set and every primary are bit-identical afterwards, no
    chunk exceeds ``k`` copies, budget-charged bytes never grow past the
    scope's limit, and an immediate second round is an exact no-op."""
    import random
    rnd = random.Random(seed)
    budget = rnd.randint(500, 3000)
    state = CacheState(n_nodes=n_nodes, node_budget_bytes=budget,
                       budget_scope=scope)
    chunk_bytes = {}
    for cid in range(1, rnd.randint(3, 15)):
        chunk_bytes[cid] = rnd.randint(1, budget)
        state.cached.add(cid)
        state.set_replicas(cid, rnd.randrange(n_nodes))
    freq = {cid: rnd.uniform(0.0, 6.0) for cid in chunk_bytes}
    pol = HotChunkReplication(k=k, threshold=3.0)
    before_primary = before_cached = None
    for round_no in range(2):
        before_primary = state.primary_map()
        before_cached = set(state.cached)
        before_used = state.bytes_by_node(chunk_bytes)
        shed = pol.replicate(ReplicationContext(
            state=state, chunk_bytes=chunk_bytes, freq=freq,
            home_of=lambda c: 0))
        assert shed >= 0
        assert state.primary_map() == before_primary
        assert state.cached == before_cached
        after_used = state.bytes_by_node(chunk_bytes)
        if scope == "node":
            for n in range(n_nodes):
                assert after_used.get(n, 0) <= max(budget,
                                                   before_used.get(n, 0))
        else:
            assert sum(after_used.values()) <= max(
                state.total_budget, sum(before_used.values()))
        for c in state.cached:
            reps = state.replicas_of(c)
            assert 1 <= len(reps) <= max(k, len(set(reps)))
            assert len(set(reps)) == len(reps)
        if round_no == 1:                # idempotent re-run
            assert shed == 0
            assert after_used == before_used


@given(st.integers(0, 2**31 - 1), st.integers(2, 5))
@settings(max_examples=30, deadline=None)
def test_drop_split_fail_never_orphans_artifact_or_result_entries(
        seed, n_nodes):
    """The listener contract under churn: after any drop/split-like/fail
    sequence plus the post-round ``sync_devices`` reconcile, the
    artifact cache holds no entry for a non-resident chunk and the
    result tier serves no entry stored before a residency change."""
    import random
    rnd = random.Random(seed)
    state = CacheState(n_nodes=n_nodes, node_budget_bytes=10_000)
    artifacts = JoinArtifactCache()
    results = ResultCache()
    state.add_listener(artifacts)
    state.add_listener(results)
    key = ResultCache.key_of(Box((0,), (9,)), 1)
    coords = np.zeros((3, 2), dtype=np.int64)
    box = Box((0, 0), (9, 9))
    prev = (frozenset(state.cached), state.location_snapshot())
    for _ in _random_state_ops(rnd, state, n_nodes, n_ops=40):
        for cid in state.cached:         # warm artifacts for residents
            artifacts.sorted_coords(artifacts.view(cid, box, box, coords),
                                    lambda: coords)
        results.store(key, 1)            # stored against current state
        state.sync_devices()             # reconcile every listener
        assert artifacts.chunk_ids() <= state.cached, "orphaned artifact"
        now = (frozenset(state.cached), state.location_snapshot())
        if now != prev:                  # ANY residency/replica change
            assert results.lookup(key) is None, \
                "result entry survived a residency change"
        prev = now
    state.sync_devices()
    assert artifacts.chunk_ids() <= state.cached


# ------------------------------------------------------------- geometry

coords_strategy = st.lists(
    st.tuples(st.integers(0, 50), st.integers(0, 50), st.integers(0, 50)),
    min_size=1, max_size=200)


@given(coords_strategy)
@settings(max_examples=50, deadline=None)
def test_bounding_box_is_tight_and_contains_all(pts):
    arr = np.array(pts, dtype=np.int64)
    bb = bounding_box(arr)
    assert points_in_box(arr, bb).all()
    lo, hi = bb.as_arrays()
    assert (arr.min(axis=0) == lo).all() and (arr.max(axis=0) == hi).all()


@given(coords_strategy, st.integers(0, 5))
@settings(max_examples=30, deadline=None)
def test_expand_contains_all_l1_neighbors(pts, eps):
    arr = np.array(pts, dtype=np.int64)
    bb = bounding_box(arr)
    grown = expand(bb, eps)
    # Any point at L1 distance <= eps from a member is inside the expansion.
    shifted = arr.copy()
    shifted[:, 0] += eps
    assert points_in_box(shifted, grown).all()


box_strategy = st.builds(
    lambda lo, side: Box(tuple(lo), tuple(l + s for l, s in zip(lo, side))),
    st.tuples(st.integers(0, 40), st.integers(0, 40), st.integers(0, 40)),
    st.tuples(st.integers(0, 30), st.integers(0, 30), st.integers(0, 30)))


@given(box_strategy, box_strategy)
@settings(max_examples=60, deadline=None)
def test_box_subtract_partitions_exactly(a, b):
    """The residual pieces of a \\ b are disjoint, inside a, outside b,
    and conserve volume — the semantic-reuse decomposition invariant."""
    pieces = box_subtract(a, b)
    inter = a.intersection(b)
    assert len(pieces) <= 2 * a.ndim
    assert sum(p.volume() for p in pieces) == \
        a.volume() - (inter.volume() if inter else 0)
    for i, p in enumerate(pieces):
        assert a.contains_box(p)
        assert not p.overlaps(b)
        for q in pieces[i + 1:]:
            assert not p.overlaps(q)


@given(box_strategy, st.lists(box_strategy, max_size=4))
@settings(max_examples=40, deadline=None)
def test_residual_boxes_cover_exactly_the_uncovered_cells(q, covers):
    """Every integer cell of the query is either inside some cover or in
    exactly one residual box."""
    residual = residual_boxes(q, covers)
    rng = np.random.default_rng(0)
    pts = np.stack([rng.integers(lo, hi + 1, size=64)
                    for lo, hi in zip(q.lo, q.hi)], axis=1)
    for p in pts:
        covered = any(c.contains_point(p) for c in covers)
        in_residual = sum(r.contains_point(p) for r in residual)
        assert in_residual == (0 if covered else 1)


# ---------------------------------------------------------------- rtree

def make_tree(coords, min_cells=5):
    counter = iter(range(1, 1_000_000))
    return EvolvingRTree(0, np.asarray(coords, dtype=np.int64), 12,
                         min_cells, lambda: next(counter))


@given(st.integers(0, 2**31 - 1), st.integers(2, 40))
@settings(max_examples=25, deadline=None)
def test_invariants_under_random_workload(seed, min_cells):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 400))
    coords = rng.integers(0, 80, size=(n, 2))
    t = make_tree(coords, min_cells=min_cells)
    for _ in range(8):
        lo = rng.integers(0, 70, size=2)
        hi = lo + rng.integers(1, 25, size=2)
        q = Box(tuple(int(x) for x in lo), tuple(int(x) for x in hi))
        got = t.refine(q)
        t.validate()
        # Leaves returned are exactly those holding >= 1 queried cell.
        expect = set()
        for c in t.leaves():
            if points_in_box(t.coords[c.cell_idx], q).any():
                expect.add(c.chunk_id)
        assert {c.chunk_id for c in got} == expect


# -------------------------------------------------------- simjoin kernel

@given(st.lists(st.tuples(st.integers(0, 300), st.integers(0, 300)),
                min_size=1, max_size=60),
       st.lists(st.tuples(st.integers(0, 300), st.integers(0, 300)),
                min_size=1, max_size=60),
       st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_min_l1_box_dist_lower_bounds_cell_distance(pts_a, pts_b, block):
    """Soundness of the block prune: the minimal L1 distance between two
    blocks' bounding boxes never exceeds the L1 distance of ANY cell
    pair drawn from the two blocks — so dropping block pairs with box
    distance > eps cannot drop a matching cell pair. Blocks are taken
    over the *spatially sorted* order (longest-dimension key with
    lexicographic tie-break), exactly as the executor builds them.
    (Pure numpy: the prune module never imports jax.)"""
    from repro.kernels.simjoin.prune import (block_bounds, min_l1_box_dist,
                                             spatial_sort)
    a = spatial_sort(np.asarray(pts_a, dtype=np.int64))
    b = spatial_sort(np.asarray(pts_b, dtype=np.int64))
    lo_a, hi_a = block_bounds(a, block)
    lo_b, hi_b = block_bounds(b, block)
    dmat = min_l1_box_dist(lo_a, hi_a, lo_b, hi_b)
    for i in range(a.shape[0]):
        for j in range(b.shape[0]):
            cell_dist = int(np.abs(a[i] - b[j]).sum())
            assert dmat[i // block, j // block] <= cell_dist


@given(st.lists(st.tuples(st.integers(-300, 300), st.integers(-300, 300)),
                min_size=1, max_size=80),
       st.lists(st.tuples(st.integers(-300, 300), st.integers(-300, 300)),
                min_size=1, max_size=80),
       st.integers(1, 16), st.integers(0, 120))
@settings(max_examples=60, deadline=None)
def test_bitmap_refine_never_kills_a_matching_pair(pts_a, pts_b, block,
                                                   eps):
    """Soundness of the cell-exact bitmap stage (the superset-of-matches
    invariant): ``refine_block_pairs`` never kills a block pair that
    contains a true match — every cell pair within eps lives in a block
    pair that survives BOTH prune stages. Exercises negative
    coordinates (floor-division quantization), the eps=0 exact edge,
    and arbitrary block sizes. (Pure numpy: the prune module never
    imports jax.)"""
    from repro.kernels.simjoin.prune import (bitmap_scale, build_bitmaps,
                                             build_block_pairs,
                                             refine_block_pairs,
                                             spatial_sort)
    a = spatial_sort(np.asarray(pts_a, dtype=np.int64))
    b = spatial_sort(np.asarray(pts_b, dtype=np.int64))
    pairs, _ = build_block_pairs(a, b, block, eps, False)
    scale = bitmap_scale(eps)
    bm_a = build_bitmaps(a, block, scale)
    bm_b = build_bitmaps(b, block, scale)
    refined, killed = refine_block_pairs(pairs, bm_a, bm_b, eps, scale)
    assert killed == pairs.shape[0] - refined.shape[0]
    live = {(int(i), int(j)) for i, j, _ in refined}
    for i in range(a.shape[0]):
        for j in range(b.shape[0]):
            if int(np.abs(a[i] - b[j]).sum()) <= eps:
                assert (i // block, j // block) in live


@given(st.lists(st.tuples(st.integers(0, 50), st.integers(0, 6),
                          st.integers(0, 6)), min_size=2, max_size=80))
@settings(max_examples=50, deadline=None)
def test_spatial_sort_permutation_and_tiebreak(pts):
    """``spatial_sort`` is a permutation whose order is the primary
    (longest-span) dimension with a full lexicographic tie-break over
    the remaining dimensions — equal-key runs can never interleave."""
    from repro.kernels.simjoin.prune import spatial_sort
    a = np.asarray(pts, dtype=np.int64)
    s = spatial_sort(a)
    assert sorted(map(tuple, s)) == sorted(map(tuple, a))
    spans = a.max(axis=0) - a.min(axis=0)
    dim = int(np.argmax(spans))
    rest = [k for k in range(a.shape[1]) if k != dim]
    keys = [tuple(int(r[k]) for k in [dim] + rest) for r in s]
    assert keys == sorted(keys)


@given(st.integers(0, 2**31 - 1), st.integers(1, 300), st.integers(0, 6))
@settings(max_examples=25, deadline=None)
def test_pruned_simjoin_property_random(seed, n, eps):
    """Pruned-vs-oracle parity over random self-joins (block-boundary
    sizes and eps=0 included by generation)."""
    pytest.importorskip("jax")
    from repro.kernels.simjoin import ops
    from repro.kernels.simjoin.ref import count_pairs_ref
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 40, size=(n, 2)).astype(np.int32)
    got, total, evaluated = ops.count_similar_pairs_pruned_np(a, a, eps,
                                                              True)
    want = int(count_pairs_ref(jnp.asarray(a), jnp.asarray(a), eps, True))
    assert got == want
    assert evaluated <= total


@given(st.integers(0, 2**31 - 1), st.integers(1, 80), st.integers(1, 80),
       st.integers(0, 4))
@settings(max_examples=20, deadline=None)
def test_simjoin_property_random(seed, n, m, eps):
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.simjoin import ops
    from repro.kernels.simjoin.ref import count_pairs_ref
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 12, size=(n, 2)).astype(np.int32)
    b = rng.integers(0, 12, size=(m, 2)).astype(np.int32)
    got = int(ops.count_similar_pairs(jnp.asarray(a), jnp.asarray(b),
                                      eps, False))
    want = int(count_pairs_ref(jnp.asarray(a), jnp.asarray(b), eps, False))
    assert got == want


# ------------------------------------------------------ telemetry (obs)

@given(st.lists(st.floats(min_value=-1e9, max_value=1e9,
                          allow_nan=False, allow_infinity=False),
                max_size=200),
       st.lists(st.floats(min_value=0, max_value=1e6,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=12, unique=True))
@settings(max_examples=60, deadline=None)
def test_histogram_bucket_counts_sum_to_observation_count(values, bounds):
    from repro.obs import Histogram
    h = Histogram("prop", bounds=tuple(sorted(bounds)))
    for v in values:
        h.observe(v)
    assert sum(h.bucket_counts) == h.count == len(values)
    assert len(h.bucket_counts) == len(h.bounds) + 1
    # every observation landed in exactly the first bucket whose upper
    # bound admits it
    recomputed = [0] * (len(h.bounds) + 1)
    for v in values:
        for i, b in enumerate(h.bounds):
            if v <= b:
                recomputed[i] += 1
                break
        else:
            recomputed[-1] += 1
    assert recomputed == h.bucket_counts
