"""flash attention Pallas kernel vs pure-jnp oracle: seq/head/dtype sweeps,
GQA ratios, causal + non-causal, rectangular decode-append."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops
from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def make_qkv(key, b, sq, sk, h, hk, d, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (b, sk, hk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (b, sk, hk, d), jnp.float32).astype(dtype)
    return q, k, v


def run_ref(q, k, v, causal):
    return attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3),
                         causal=causal).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("sq,causal", [(128, True), (256, True),
                                       (130, True), (256, False),
                                       (384, True)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(sq, causal, dtype):
    q, k, v = make_qkv(jax.random.PRNGKey(0), 2, sq, sq if causal else 256,
                       4, 4, 64, dtype)
    got = ops.flash_attention(q, k, v, causal=causal)
    want = run_ref(q, k, v, causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("h,hk", [(8, 8), (8, 2), (4, 1)])
def test_gqa_ratios(h, hk):
    q, k, v = make_qkv(jax.random.PRNGKey(1), 1, 128, 128, h, hk, 32,
                       jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True)
    want = run_ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_block_size_invariance():
    q, k, v = make_qkv(jax.random.PRNGKey(2), 1, 256, 256, 2, 2, 64,
                       jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True, bq=128, bk=128)
    b = ops.flash_attention(q, k, v, causal=True, bq=64, bk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_q_offset_decode_append():
    """Rectangular causal: 64 new queries appended after 192 cached keys."""
    b, h, d = 1, 2, 32
    q, k, v = make_qkv(jax.random.PRNGKey(3), b, 64, 256, h, h, d,
                       jnp.float32)
    got = flash_attention_fwd(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              causal=True, bq=64, bk=64, q_offset=192)
    want = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), causal=True, q_offset=192)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_matches_model_attention_path():
    """The kernel agrees with the model's naive attention on equal inputs."""
    from repro.models.attention import _naive_attention
    q, k, v = make_qkv(jax.random.PRNGKey(4), 2, 128, 128, 4, 4, 64,
                       jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True)
    want = _naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
