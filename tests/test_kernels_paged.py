"""paged decode attention Pallas kernel vs pure-jnp oracle: page-count,
page-size, GQA, ragged seq_lens, and permuted page tables."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


def setup(key, b, h, hk, d, n_pages, page_size, maxp, seed_lens=None):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, page_size, hk, d), jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, page_size, hk, d), jnp.float32)
    # Disjoint random page assignment per request.
    perm = jax.random.permutation(ks[3], n_pages)[:b * maxp]
    table = perm.reshape(b, maxp).astype(jnp.int32)
    if seed_lens is None:
        lens = jnp.full((b,), maxp * page_size, jnp.int32)
    else:
        lens = jnp.asarray(seed_lens, jnp.int32)
    return q, kp, vp, table, lens


@pytest.mark.parametrize("page_size,maxp", [(16, 4), (32, 2), (8, 8)])
@pytest.mark.parametrize("h,hk", [(4, 4), (8, 2)])
def test_paged_matches_ref(page_size, maxp, h, hk):
    q, kp, vp, table, lens = setup(jax.random.PRNGKey(0), 3, h, hk, 32,
                                   64, page_size, maxp)
    got = paged_decode_attention(q, kp, vp, table, lens)
    want = paged_attention_ref(q, kp, vp, table, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ragged_lengths():
    b, maxp, ps = 4, 4, 16
    q, kp, vp, table, _ = setup(jax.random.PRNGKey(1), b, 4, 4, 32, 64,
                                ps, maxp)
    lens = jnp.asarray([1, 17, 40, 64], jnp.int32)
    got = paged_decode_attention(q, kp, vp, table, lens)
    want = paged_attention_ref(q, kp, vp, table, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_shared_prefix_pages():
    """Two requests sharing prefix pages (the cache-placement win case):
    identical prefixes must produce identical attention for equal queries."""
    b, h, d, ps, maxp = 2, 4, 32, 16, 3
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    qrow = jax.random.normal(ks[0], (1, h, d), jnp.float32)
    q = jnp.concatenate([qrow, qrow], axis=0)
    kp = jax.random.normal(ks[1], (32, ps, h, d), jnp.float32)
    vp = jax.random.normal(ks[2], (32, ps, h, d), jnp.float32)
    shared = jnp.asarray([[5, 9, 11], [5, 9, 11]], jnp.int32)
    lens = jnp.full((2,), maxp * ps, jnp.int32)
    out = paged_decode_attention(q, kp, vp, shared, lens)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out[1]),
                               rtol=1e-6, atol=1e-6)


def test_bfloat16():
    q, kp, vp, table, lens = setup(jax.random.PRNGKey(3), 2, 4, 4, 64,
                                   32, 16, 2)
    q = q.astype(jnp.bfloat16)
    kp = kp.astype(jnp.bfloat16)
    vp = vp.astype(jnp.bfloat16)
    got = paged_decode_attention(q, kp, vp, table, lens)
    want = paged_attention_ref(q, kp, vp, table, lens)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)
