"""simjoin Pallas kernel vs pure-jnp oracle: shape/dim/eps sweeps +
cross-check against the cluster's numpy executor (property tests live in
test_hypothesis_properties.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cluster import count_similar_pairs_np as np_counter
from repro.kernels.simjoin import ops
from repro.kernels.simjoin.ref import count_pairs_ref


def rand_coords(rng, n, d, hi=200):
    return rng.integers(0, hi, size=(n, d)).astype(np.int32)


@pytest.mark.parametrize("n,m", [(1, 1), (7, 13), (128, 128), (130, 255),
                                 (300, 41), (1024, 77)])
@pytest.mark.parametrize("d", [2, 3])
def test_cross_join_matches_ref(n, m, d):
    rng = np.random.default_rng(n * 1000 + m + d)
    a = rand_coords(rng, n, d, hi=60)
    b = rand_coords(rng, m, d, hi=60)
    for eps in (0, 1, 3):
        got = int(ops.count_similar_pairs(jnp.asarray(a), jnp.asarray(b),
                                          eps, False))
        want = int(count_pairs_ref(jnp.asarray(a), jnp.asarray(b), eps,
                                   False))
        assert got == want, (n, m, d, eps)


@pytest.mark.parametrize("n", [1, 5, 129, 384, 1000])
def test_self_join_matches_ref(n):
    rng = np.random.default_rng(n)
    a = rand_coords(rng, n, 3, hi=40)
    for eps in (1, 2):
        got = int(ops.count_similar_pairs(jnp.asarray(a), jnp.asarray(a),
                                          eps, True))
        want = int(count_pairs_ref(jnp.asarray(a), jnp.asarray(a), eps,
                                   True))
        assert got == want


def test_matches_numpy_cluster_executor():
    rng = np.random.default_rng(0)
    a = rand_coords(rng, 257, 3, hi=30)
    b = rand_coords(rng, 100, 3, hi=30)
    assert ops.count_similar_pairs_np(a, b, 2, False) == \
        np_counter(a, b, 2, False)
    assert ops.count_similar_pairs_np(a, a, 1, True) == \
        np_counter(a, a, 1, True)


def test_empty_inputs():
    a = np.zeros((0, 2), np.int32)
    b = rand_coords(np.random.default_rng(1), 10, 2)
    assert ops.count_similar_pairs_np(a, b, 5, False) == 0


def test_dtype_and_large_coords():
    # Domain coordinates up to 10^5 (PTF ra/dec ranges) stay exact.
    rng = np.random.default_rng(3)
    a = rng.integers(0, 100_000, size=(200, 3)).astype(np.int32)
    got = int(ops.count_similar_pairs(jnp.asarray(a), jnp.asarray(a),
                                      1000, True))
    want = int(count_pairs_ref(jnp.asarray(a), jnp.asarray(a), 1000, True))
    assert got == want
