"""Cross-batch MQO + the versioned result-cache tier: match counts must
be bit-identical with the tiers on vs off on both backends (including
across evict -> re-admit -> split churn), each distinct join task must
execute exactly once per batch, exact repeat queries must bypass the
planner entirely, and the seed-parity defaults (``mqo="off"``,
``result_cache="off"``) must leave every observable untouched."""
import tempfile
from types import SimpleNamespace

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.backend.base import ExecutedQuery, workload_summary  # noqa: E402
from repro.backend.jax_mesh import JaxMeshBackend  # noqa: E402
from repro.backend.simulated import MQO_MODES, SimulatedBackend  # noqa: E402
from repro.core.cache_state import CacheState  # noqa: E402
from repro.core.coordinator import SimilarityJoinQuery  # noqa: E402
from repro.core.geometry import Box  # noqa: E402
from repro.core.result_cache import (RESULT_CACHE_MODES,  # noqa: E402
                                     ResultCache)
from repro.core.workload import zipf_workload  # noqa: E402


# ----------------------------------------------- ResultCache unit tests

def test_key_canonicalizes_box_and_eps():
    k1 = ResultCache.key_of(Box((1, 2), (3, 4)), 5)
    k2 = ResultCache.key_of(Box((np.int64(1), 2), (3, np.int32(4))),
                            np.int64(5))
    assert k1 == k2 == ((1, 2), (3, 4), 5)


def test_lookup_store_lru_and_capacity():
    rc = ResultCache(capacity=2)
    ka = ResultCache.key_of(Box((0,), (1,)), 1)
    kb = ResultCache.key_of(Box((2,), (3,)), 1)
    kc = ResultCache.key_of(Box((4,), (5,)), 1)
    assert rc.lookup(ka) is None and rc.misses == 1
    rc.store(ka, 10)
    rc.store(kb, 20)
    assert rc.lookup(ka).matches == 10      # refreshes ka's LRU position
    rc.store(kc, 30)                        # capacity 2: evicts kb (LRU)
    assert rc.capacity_evictions == 1
    assert rc.lookup(kb) is None
    assert rc.lookup(ka).matches == 10
    assert rc.lookup(kc).matches == 30
    assert len(rc) == 2


def test_version_bump_invalidates_everything_at_once():
    rc = ResultCache()
    k = ResultCache.key_of(Box((0,), (9,)), 2)
    rc.store(k, 7)
    assert rc.lookup(k).matches == 7
    rc.bump()
    assert rc.lookup(k) is None and rc.stale_drops == 1
    rc.store(k, 8)                          # restored at the new version
    assert rc.lookup(k).matches == 8


def test_listener_hooks_bump_and_reconcile_diffs_snapshot():
    rc = ResultCache()
    k = ResultCache.key_of(Box((0,), (9,)), 1)
    rc.store(k, 1)
    rc.on_drop(3)
    assert rc.lookup(k) is None             # drop bumped
    rc.store(k, 1)
    rc.on_split(3, [])
    assert rc.lookup(k) is None             # split bumped
    rc.store(k, 1)
    state = CacheState(n_nodes=2, node_budget_bytes=1 << 20)
    state.cached = {1, 2}
    state.set_replicas(1, 0)
    state.set_replicas(2, 1)
    rc.reconcile(state)                     # residency changed -> bump
    assert rc.lookup(k) is None
    rc.store(k, 1)
    rc.reconcile(state)                     # unchanged -> version kept
    assert rc.lookup(k).matches == 1
    state.set_replicas(2, 0)                # relocation alone also bumps
    rc.reconcile(state)
    assert rc.lookup(k) is None
    rc.store(k, 1)
    state.set_replicas(2, (0, 1))           # replica-set growth with an
    rc.reconcile(state)                     # unchanged primary also bumps
    assert rc.lookup(k) is None


def test_ttl_expiry_with_injected_clock():
    now = [0.0]
    rc = ResultCache(ttl_s=10.0, clock=lambda: now[0])
    k = ResultCache.key_of(Box((0,), (1,)), 1)
    rc.store(k, 5)
    now[0] = 9.0
    assert rc.lookup(k).matches == 5
    now[0] = 20.1
    assert rc.lookup(k) is None and rc.expired_drops == 1


def test_knob_validation():
    with pytest.raises(ValueError):
        ResultCache(capacity=0)
    with pytest.raises(ValueError):
        SimulatedBackend(2, mqo="maybe")
    assert MQO_MODES == ("off", "on")
    assert RESULT_CACHE_MODES == ("off", "on")


def test_unbound_backend_raises_runtime_error_not_assert():
    """python -O must not erase the unbound-backend guard (ISSUE-6
    satellite: assert -> RuntimeError)."""
    q = SimilarityJoinQuery(box=Box((0, 0), (1, 1)))
    with pytest.raises(RuntimeError, match="not bound"):
        SimulatedBackend(2).gather_join_tasks(q, SimpleNamespace(
            result_cache_hit=False, join_plan=None, queried_chunks=[]))
    mesh = JaxMeshBackend(2)
    with pytest.raises(RuntimeError, match="not bound"):
        mesh.reconcile(SimpleNamespace(cached=set(), locations={}))
    with pytest.raises(RuntimeError, match="not bound"):
        mesh.execute(q, SimpleNamespace(result_cache_hit=False))


# -------------------------------------------- workload_summary edge cases

def _stub(report=None, **kw):
    base = dict(time_scan_s=1.0, time_net_s=0.5, time_compute_s=0.25,
                time_opt_s=0.0, matches=3)
    base.update(kw)
    return ExecutedQuery(report=report or SimpleNamespace(
        scan_bytes_by_node={0: 8}, files_scanned=[1], reuse_hits=0,
        reuse_bytes_served=0, residual_bytes_scanned=0, reuse_scan_skips=0,
        result_cache_hit=False), **base)


def test_summary_empty_workload():
    s = workload_summary([])
    assert s["queries"] == 0.0 and s["total_time_s"] == 0.0
    for k in ("mqo_tasks_total", "prep_s", "block_pairs_total",
              "measured_net_s", "result_cache_hits"):
        assert k not in s


def test_summary_optional_keys_appear_iff_any_query_has_them():
    plain = [_stub(), _stub()]
    s = workload_summary(plain)
    for k in ("mqo_tasks_total", "mqo_tasks_executed", "mqo_shared_hits",
              "prep_s", "block_pairs_total", "result_cache_hits"):
        assert k not in s
    mixed = [_stub(), _stub(prep_s=0.5, dispatch_s=0.1, artifact_hits=2,
                            artifact_misses=1),
             _stub(mqo_tasks_total=4, mqo_tasks_executed=3,
                   mqo_shared_hits=1)]
    s = workload_summary(mixed)
    # One carrier is enough to pin the key; Nones sum as zero.
    assert s["prep_s"] == 0.5 and s["artifact_hits"] == 2.0
    assert s["mqo_tasks_total"] == 4.0
    assert s["mqo_tasks_executed"] == 3.0 and s["mqo_shared_hits"] == 1.0
    assert s["queries"] == 3.0


def test_summary_counts_result_cache_hits_from_reports():
    hit_report = SimpleNamespace(
        scan_bytes_by_node={}, files_scanned=[], reuse_hits=0,
        reuse_bytes_served=0, residual_bytes_scanned=0, reuse_scan_skips=0,
        result_cache_hit=True)
    s = workload_summary([_stub(), _stub(report=hit_report)])
    assert s["result_cache_hits"] == 1.0
    # Reports lacking the attribute entirely (foreign stubs) stay safe.
    bare = SimpleNamespace(
        scan_bytes_by_node={}, files_scanned=[], reuse_hits=0,
        reuse_bytes_served=0, residual_bytes_scanned=0, reuse_scan_skips=0)
    assert "result_cache_hits" not in workload_summary([_stub(report=bare)])


# --------------------------------------------------- cluster-level tests

@pytest.fixture(scope="module")
def dataset():
    from repro.arrayio.catalog import build_catalog
    from repro.arrayio.generator import make_geo_files
    files = make_geo_files(n_files=3, n_seeds=150, clones_per_seed=25,
                           seed=13)
    catalog, data = build_catalog(files, tempfile.mkdtemp(prefix="mqo_"),
                                  "csv", n_nodes=4)
    return catalog, data


def make_cluster(dataset, backend="simulated", budget_frac=8,
                 min_cells=512, **kw):
    from repro.arrayio.catalog import FileReader
    from repro.core.cluster import RawArrayCluster
    catalog, data = dataset
    total = sum(f.n_cells * f.cell_bytes for f in catalog.files)
    return RawArrayCluster(catalog, FileReader(catalog, data), 4,
                           max(total // budget_frac, 4_000) // 4,
                           policy="cost", min_cells=min_cells,
                           backend=backend, join_backend="pallas", **kw)


def zipf(catalog, n_queries=24, n_templates=6, seed=7):
    return zipf_workload(catalog.domain, n_queries=n_queries,
                         n_templates=n_templates, s=1.1, eps=400,
                         field_frac=0.4, seed=seed)


def test_zipf_workload_is_seeded_and_skewed(dataset):
    catalog, _ = dataset
    qs = zipf(catalog, n_queries=200, n_templates=30, seed=11)
    assert qs == zipf(catalog, n_queries=200, n_templates=30, seed=11)
    assert qs != zipf(catalog, n_queries=200, n_templates=30, seed=12)
    keys = [(q.box.lo, q.box.hi, q.eps) for q in qs]
    assert len(set(keys)) <= 30
    counts = sorted((keys.count(k) for k in set(keys)), reverse=True)
    # Zipf(s=1.1): the hottest template dominates the tail.
    assert counts[0] >= 5 * counts[-1]


@pytest.mark.parametrize("backend", ["simulated", "jax_mesh"])
def test_mqo_and_result_cache_parity(dataset, backend):
    """The acceptance gate: bit-identical per-query matches with the
    tiers on vs off, on both backends, under batched admission with
    residency churn (tight budget forces evicts and re-admits)."""
    catalog, _ = dataset
    queries = zipf(catalog)
    ref = make_cluster(dataset, backend, budget_frac=16, min_cells=256)
    got = make_cluster(dataset, backend, budget_frac=16, min_cells=256,
                       mqo="on", result_cache="on")
    ref_m = [e.matches for e in ref.run_workload(queries, batch_size=8)]
    opt = got.run_workload(queries, batch_size=8)
    assert [e.matches for e in opt] == ref_m
    assert sum(m or 0 for m in ref_m) > 0
    summ = workload_summary(opt)
    assert summ["mqo_shared_hits"] > 0
    assert (summ["mqo_tasks_executed"] + summ["mqo_shared_hits"]
            == summ["mqo_tasks_total"])
    assert got.coordinator.stats["result_cache_hits"] > 0


def test_parity_across_evict_readmit_split(dataset):
    """Churn sequence: repeats, then a sub-box query forcing R-tree
    splits, then repeats again — stored results must never be served
    stale across the residency events."""
    catalog, _ = dataset
    base = zipf(catalog)[:4]
    d = catalog.domain
    mid = tuple((l + h) // 2 for l, h in zip(d.lo, d.hi))
    q_sub = SimilarityJoinQuery(box=Box(d.lo, mid), eps=400)
    seq = base + base + [q_sub] + base
    ref = make_cluster(dataset, budget_frac=16, min_cells=256)
    opt = make_cluster(dataset, budget_frac=16, min_cells=256,
                       mqo="on", result_cache="on")
    ref_m = [e.matches for e in ref.run_workload(seq, batch_size=4)]
    opt_m = [e.matches for e in opt.run_workload(seq, batch_size=4)]
    assert opt_m == ref_m
    assert sum(m or 0 for m in ref_m) > 0
    rc = opt.coordinator.result_cache
    assert rc.invalidations > 0              # churn bumped the version


def test_repeat_queries_bypass_the_planner(dataset):
    """An all-resident cluster answering an exact repeat batch must not
    invoke the planner at all (pure-hit batches skip the policy round)."""
    catalog, _ = dataset
    queries = zipf(catalog)[:8]
    cluster = make_cluster(dataset, budget_frac=1, result_cache="on")
    cluster.run_workload(queries, batch_size=8)
    cluster.run_workload(queries, batch_size=8)   # warm residency stamp
    before = cluster.coordinator.planner_invocations
    repeat = cluster.run_workload(queries, batch_size=8)
    assert cluster.coordinator.planner_invocations == before
    assert all(e.report.result_cache_hit for e in repeat)
    assert all(e.time_total_s == 0.0 for e in repeat)


def test_mqo_executes_each_distinct_task_once_per_batch(dataset):
    """Per batch, executed tasks == distinct sharing signatures: an
    8-query batch of ONE repeated template pays for exactly one query's
    tasks (the <= 1.1x unique-task acceptance bound, exactly)."""
    catalog, _ = dataset
    q = zipf(catalog)[0]
    cluster = make_cluster(dataset, budget_frac=1, mqo="on")
    executed = cluster.run_workload([q] * 8, batch_size=8)
    summ = workload_summary(executed)
    assert summ["mqo_tasks_executed"] == summ["mqo_tasks_total"] / 8
    per_query = {e.mqo_tasks_executed for e in executed[1:]}
    assert per_query == {0}                  # only the first owns tasks
    assert len({e.matches for e in executed}) == 1


def test_off_defaults_preserve_seed_observables(dataset):
    """mqo/result_cache default off: no MQO counters on ExecutedQuery,
    no result-cache keys in the summary, zero stats, and execute_batch
    degenerates to the per-query loop."""
    catalog, _ = dataset
    queries = zipf(catalog)[:6]
    cluster = make_cluster(dataset)
    batched = cluster.run_workload(queries, batch_size=3)
    looped = [e.matches
              for e in make_cluster(dataset).run_workload(queries)]
    assert [e.matches for e in batched] == looped
    summ = workload_summary(batched)
    for k in ("mqo_tasks_total", "result_cache_hits"):
        assert k not in summ
    assert all(e.mqo_tasks_total is None for e in batched)
    assert cluster.coordinator.result_cache is None
    assert cluster.coordinator.stats["result_cache_hits"] == 0
    assert cluster.coordinator.stats["result_cache_misses"] == 0


def test_result_cache_listener_registered_and_versioned(dataset):
    """The tier rides CacheState.listeners: policy rounds that change
    residency bump the version; stored entries are stamped with it."""
    catalog, _ = dataset
    cluster = make_cluster(dataset, budget_frac=16, min_cells=256,
                           result_cache="on")
    rc = cluster.coordinator.result_cache
    assert rc in cluster.coordinator.cache.listeners
    v0 = rc.version
    cluster.run_workload(zipf(catalog)[:4], batch_size=4)
    assert rc.version > v0                   # admissions bumped
    assert len(rc) > 0
    assert all(e.version == rc.version for e in rc._entries.values())
