import pytest

from repro.core.placement import (JoinRecord, cost_based_placement,
                                  static_placement)


def test_singletons_pinned():
    replicas = {1: {0}, 2: {1}}
    res = cost_based_placement([], replicas, {1: 10, 2: 10},
                               {0: 100, 1: 100})
    assert res.locations == {1: 0, 2: 1}
    assert res.fallback_moves == [] and res.dropped == []


def test_colocates_join_partners():
    w = [JoinRecord(1, ((1, 2),))]
    replicas = {1: {0}, 2: {0, 1}}        # 2 was shipped to node 0 to join
    res = cost_based_placement(w, replicas, {1: 10, 2: 10}, {0: 100, 1: 100})
    assert res.locations[2] == 0          # stays with its partner
    assert res.colocated_pair_weight > 0


def test_recent_queries_outweigh_old():
    # Old query joined (1,2); new query joined (1,3). Chunk 1 can keep only
    # one partner: node 1 holds 2, node 2 holds 3.
    w = [JoinRecord(1, ((1, 2),)), JoinRecord(8, ((1, 3),))]
    replicas = {1: {1, 2}, 2: {1}, 3: {2}}
    res = cost_based_placement(w, replicas, {1: 10, 2: 10, 3: 10},
                               {0: 100, 1: 100, 2: 100})
    assert res.locations[1] == 2          # with the recent partner


def test_budget_drops_without_fallback_ship():
    # Piggyback-only (default): chunks that fit no replica node are dropped.
    w = []
    replicas = {1: {0}, 2: {0}, 3: {0}}
    bytes_ = {1: 60, 2: 60, 3: 60}
    res = cost_based_placement(w, replicas, bytes_, {0: 100, 1: 70})
    assert len(res.locations) == 1 and len(res.dropped) == 2
    assert res.fallback_moves == []
    assert set(res.locations.values()) == {0}


def test_budget_fallback_ship_variant():
    w = []
    replicas = {1: {0}, 2: {0}, 3: {0}}
    bytes_ = {1: 60, 2: 60, 3: 60}
    res = cost_based_placement(w, replicas, bytes_, {0: 100, 1: 70},
                               allow_fallback_ship=True)
    placed_nodes = set(res.locations.values())
    assert 1 in placed_nodes              # someone spilled to node 1
    assert len(res.locations) + len(res.dropped) == 3
    used0 = sum(bytes_[c] for c, n in res.locations.items() if n == 0)
    used1 = sum(bytes_[c] for c, n in res.locations.items() if n == 1)
    assert used0 <= 100 and used1 <= 70


def test_replica_count_ordering():
    # The 3-replica chunk is placed after the 2-replica chunk.
    w = [JoinRecord(3, ((10, 11), (10, 12)))]
    replicas = {10: {0, 1, 2}, 11: {0, 1}, 12: {2}}
    res = cost_based_placement(w, replicas, {10: 10, 11: 10, 12: 10},
                               {0: 100, 1: 100, 2: 100})
    # 12 pinned at 2; 11 placed first among multis; 10 then joins whichever
    # grouping wins — both partners have weight 1, tie broken by free budget.
    assert res.locations[12] == 2
    assert res.locations[10] in (res.locations[11], 2)


def test_static_placement_keeps_home():
    replicas = {1: {0, 1}, 2: {1}}
    res = static_placement(replicas, {1: 0, 2: 1}, {1: 10, 2: 10},
                           {0: 100, 1: 100})
    assert res.locations == {1: 0, 2: 1}
