"""Regression: the layered planning engine reproduces the seed (monolithic)
coordinator's observable cache behavior exactly.

The EXPECTED table was captured by running the pre-refactor coordinator on
this fixed-seed workload (dataset seed 21, ptf1 seed 7 + ptf2 seed 5,
4 nodes, 6 kB/node budget). Per query and policy it freezes:

    [bytes scanned, files scanned, queried cells,
     cached chunks after, cached bytes after, evicted items, join matches]

Any drift in chunking, scan accounting, eviction, placement, or join
execution shows up as a diff against these rows.
"""
import tempfile

import pytest

from repro.arrayio.catalog import FileReader, build_catalog
from repro.arrayio.generator import make_ptf_files
from repro.core.cluster import RawArrayCluster
from repro.core.workload import ptf1_workload, ptf2_workload

N_NODES = 4
NODE_BUDGET = 6_000

EXPECTED = {
    "cost": [
        [86400, [1, 6, 7], 2, 2, 1856, 0, 0],
        [86400, [1, 6, 7], 31, 5, 4480, 0, 0],
        [149760, [0, 1, 5, 7], 0, 5, 4480, 0, 0],
        [0, [], 0, 5, 4480, 0, 0],
        [0, [], 0, 5, 4480, 0, 0],
        [149760, [0, 1, 5, 7], 31, 9, 5472, 0, 1],
        [149760, [0, 1, 5, 7], 1351, 20, 43232, 5, 101],
        [48960, [7], 714, 21, 23328, 15, 48],
    ],
    "chunk_lru": [
        [86400, [1, 6, 7], 2, 2, 1856, 0, 0],
        [86400, [1, 6, 7], 31, 5, 4480, 0, 0],
        [149760, [0, 1, 5, 7], 0, 5, 4480, 0, 0],
        [0, [], 0, 5, 4480, 0, 0],
        [0, [], 0, 5, 4480, 0, 0],
        [149760, [0, 1, 5, 7], 31, 9, 5472, 0, 1],
        [149760, [0, 1, 5, 7], 1351, 10, 23296, 17, 101],
        [149760, [0, 1, 5, 7], 714, 21, 23328, 14, 48],
    ],
    "file_lru": [
        [86400, [1, 6, 7], 2, 1, 18720, 1, 0],
        [86400, [1, 6, 7], 31, 1, 18720, 2, 0],
        [172800, [0, 1, 5, 6, 7], 0, 1, 18720, 2, 0],
        [172800, [0, 1, 5, 6, 7], 0, 1, 18720, 2, 0],
        [172800, [0, 1, 5, 6, 7], 0, 1, 18720, 2, 0],
        [172800, [0, 1, 5, 6, 7], 31, 1, 18720, 2, 1],
        [172800, [0, 1, 5, 6, 7], 1351, 1, 18720, 2, 101],
        [172800, [0, 1, 5, 6, 7], 714, 1, 18720, 2, 48],
    ],
}


@pytest.fixture(scope="module")
def dataset():
    files = make_ptf_files(n_files=10, cells_per_file_mean=900, seed=21)
    catalog, data = build_catalog(files, tempfile.mkdtemp(prefix="parity_"),
                                  "fits", n_nodes=N_NODES)
    return catalog, data


def fixed_workload(catalog):
    return (ptf1_workload(catalog.domain, n_queries=4, eps=300, seed=7)
            + ptf2_workload(catalog.domain, n_queries=4, eps=300))


def observe(cluster, queries):
    rows = []
    for e in cluster.run_workload(queries):
        r = e.report
        rows.append([sum(r.scan_bytes_by_node.values()),
                     sorted(r.files_scanned), r.queried_cells,
                     r.cached_chunks_after, r.cached_bytes_after,
                     r.evicted_items, e.matches])
    return rows


@pytest.mark.parametrize("policy", sorted(EXPECTED))
def test_layered_pipeline_matches_seed_observables(dataset, policy):
    catalog, data = dataset
    cluster = RawArrayCluster(catalog, FileReader(catalog, data), N_NODES,
                              NODE_BUDGET, policy=policy, min_cells=64)
    assert observe(cluster, fixed_workload(catalog)) == EXPECTED[policy]


def test_pallas_batched_executor_matches_numpy(dataset):
    """The Pallas-batched join executor returns match counts identical to
    the numpy reference executor on the same admitted plans."""
    catalog, data = dataset
    queries = fixed_workload(catalog)
    matches = {}
    for backend in ("numpy", "pallas"):
        cluster = RawArrayCluster(catalog, FileReader(catalog, data),
                                  N_NODES, NODE_BUDGET, policy="cost",
                                  min_cells=64, join_backend=backend)
        matches[backend] = [e.matches
                            for e in cluster.run_workload(queries)]
    assert matches["pallas"] == matches["numpy"]
    assert sum(matches["numpy"]) > 0       # the fixture exercises the join


def test_pallas_backend_on_quickstart_workload():
    """Quickstart-scale cross-check (the acceptance workload): batched
    Pallas execution and the numpy executor agree query by query."""
    files = make_ptf_files(n_files=12, cells_per_file_mean=2000, seed=5)
    catalog, data = build_catalog(files, tempfile.mkdtemp(prefix="qs_"),
                                  "fits", n_nodes=N_NODES)
    total = sum(f.n_cells * f.cell_bytes for f in catalog.files)
    queries = ptf2_workload(catalog.domain, n_queries=10)
    matches = {}
    for backend in ("numpy", "pallas"):
        cluster = RawArrayCluster(catalog, FileReader(catalog, data),
                                  N_NODES, total // (4 * N_NODES),
                                  policy="cost", min_cells=128,
                                  join_backend=backend)
        matches[backend] = [e.matches
                            for e in cluster.run_workload(queries)]
    assert matches["pallas"] == matches["numpy"]
