"""Hot-chunk replication + simulated node failure handling.

The contract under test: ``replication="off"`` (the default) is
bit-for-bit the single-copy pipeline on both backends; with
``replication="hot"`` match counts never change, secondaries are shed
strictly before sole copies when budget tightens, the join planner
routes deterministically to the least-loaded replica, and a
``fail_node`` crash-restart re-admits lost chunks (cheap from surviving
replicas, raw-file fallback otherwise) while every listener-driven tier
— device buffers, join artifacts, result-cache version stamps — forgets
the dead copies. Also holds the ISSUE-7 accessor discipline: nothing
outside ``cache_state.py`` touches the raw ``locations`` dict.
"""
import re
from pathlib import Path

import pytest

from repro.arrayio.catalog import FileReader, build_catalog
from repro.arrayio.generator import make_ptf_files
from repro.backend.base import workload_summary
from repro.core.cache_state import CacheState
from repro.core.chunk import ChunkMeta
from repro.core.cluster import RawArrayCluster
from repro.core.geometry import Box
from repro.core.join_planner import plan_join
from repro.core.policies import (REPLICATION_MODES, HotChunkReplication,
                                 ReplicationContext, build_replication)
from repro.core.workload import zipf_workload

N_NODES = 4


@pytest.fixture(scope="module")
def ptf(tmp_path_factory):
    root = tmp_path_factory.mktemp("ptf_repl")
    files = make_ptf_files(n_files=8, cells_per_file_mean=700, seed=11)
    catalog, data = build_catalog(files, str(root), "fits", n_nodes=N_NODES)
    return catalog, data


def make_cluster(ptf, budget=400_000, **kw):
    catalog, data = ptf
    return RawArrayCluster(catalog, FileReader(catalog, data), N_NODES,
                           budget, policy="cost", min_cells=64, **kw)


def skewed(catalog, n_queries=18, seed=3):
    return zipf_workload(catalog.domain, n_queries=n_queries, n_templates=3,
                         s=1.5, eps=1, field_frac=0.25, seed=seed)


def hottest_node(cluster):
    """The node holding the most cached bytes (the failover victim)."""
    chunk_bytes, _ = cluster.coordinator.chunks.size_tables()
    by_node = cluster.coordinator.cache.bytes_by_node(chunk_bytes)
    return max(by_node, key=lambda n: (by_node[n], -n))


# ------------------------------------------------------- knob validation

def test_knob_validation(ptf):
    assert REPLICATION_MODES == ("off", "hot")
    with pytest.raises(ValueError):
        build_replication("mirror")
    with pytest.raises(ValueError):
        HotChunkReplication(k=0)
    with pytest.raises(ValueError):
        make_cluster(ptf, replication="all")
    cl = make_cluster(ptf)
    assert cl.coordinator.replication == "off"   # off by default
    with pytest.raises(ValueError):
        cl.coordinator.fail_node(N_NODES)


# ------------------------------------------------- off = seed parity

@pytest.mark.parametrize("backend", ["simulated", "jax_mesh"])
def test_replication_off_is_single_copy_seed_path(ptf, backend):
    """Default and explicit ``replication="off"`` produce identical
    workloads, keep every replica tuple at length one, and leave every
    replication/failover observable absent (None fields, no summary
    keys) — the single-copy path of the seed."""
    if backend == "jax_mesh":
        pytest.importorskip("jax")
    queries = skewed(ptf[0], n_queries=12)
    default = make_cluster(ptf, backend=backend)
    explicit = make_cluster(ptf, backend=backend, replication="off")
    ed = default.run_workload(queries, batch_size=3)
    ee = explicit.run_workload(queries, batch_size=3)
    assert [e.matches for e in ed] == [e.matches for e in ee]

    def modeled(executed):
        # opt_time_s is real measured policy-round wall-clock (and
        # total_time_s includes it): strip the nondeterministic timings,
        # compare every planned/counted observable exactly.
        s = workload_summary(executed)
        return {k: v for k, v in s.items()
                if k not in ("total_time_s", "opt_time_s", "prep_s",
                             "dispatch_s", "bitmap_build_s",
                             "measured_net_s", "measured_compute_s",
                             "recovery_s")}
    assert modeled(ed) == modeled(ee)
    summary = workload_summary(ee)
    assert "replica_hits" not in summary
    assert "failover_readmits" not in summary
    assert all(e.replica_hits is None and e.failover_readmits is None
               for e in ee)
    cache = explicit.coordinator.cache
    assert cache.location_items()
    assert all(len(reps) == 1 for _, reps in cache.location_items())


@pytest.mark.parametrize("backend", ["simulated", "jax_mesh"])
def test_hot_replication_same_matches_and_forms_replicas(ptf, backend):
    """Replication never changes a match count; under a skewed repeat
    workload with slack budget, hot chunks actually gain secondaries and
    the summary grows the replica counter group."""
    if backend == "jax_mesh":
        pytest.importorskip("jax")
    queries = skewed(ptf[0])
    off = make_cluster(ptf, backend=backend)
    hot = make_cluster(ptf, backend=backend, replication="hot",
                       replica_k=2, replication_threshold=2.0)
    eo = off.run_workload(queries, batch_size=3)
    eh = hot.run_workload(queries, batch_size=3)
    assert [e.matches for e in eo] == [e.matches for e in eh]
    cache = hot.coordinator.cache
    assert any(len(reps) > 1 for _, reps in cache.location_items())
    assert all(len(reps) <= 2 for _, reps in cache.location_items())
    summary = workload_summary(eh)
    assert "replica_hits" in summary and "replicas_dropped" in summary
    assert hot.coordinator.stats["replica_hits"] >= 0


# ------------------------------------------------ planner replica routing

def _cm(cid, lo, hi, n_cells=100, nbytes=1000):
    return ChunkMeta(cid, 0, Box(lo, hi), n_cells, nbytes)


def test_plan_join_replica_routing_is_deterministic_and_served_in_place():
    chunks = [_cm(1, (0, 0), (4, 4)), _cm(2, (3, 3), (9, 9))]
    locs = {1: (0, 1), 2: (1,)}
    p1 = plan_join(chunks, locs, eps=1, n_nodes=N_NODES)
    p2 = plan_join(chunks, locs, eps=1, n_nodes=N_NODES)
    assert p1.pair_node == p2.pair_node
    assert p1.transfer_routes == p2.transfer_routes
    # Chunk 1's secondary at node 1 serves the cross pair in place: the
    # whole plan runs without shipping a byte.
    assert p1.transfers == []
    assert p1.replica_hits > 0


def test_plan_join_single_copy_forms_are_bit_identical():
    """A bare node id and its one-tuple plan identically (the compat
    guarantee the off-parity rows rely on), with zero replica hits."""
    chunks = [_cm(1, (0, 0), (4, 4)), _cm(2, (3, 3), (9, 9))]
    a = plan_join(chunks, {1: 0, 2: 1}, eps=1, n_nodes=N_NODES)
    b = plan_join(chunks, {1: (0,), 2: (1,)}, eps=1, n_nodes=N_NODES)
    assert a.pair_node == b.pair_node
    assert a.transfer_routes == b.transfer_routes
    assert a.bytes_in == b.bytes_in and a.bytes_out == b.bytes_out
    assert a.replica_hits == b.replica_hits == 0


# -------------------------------------------- replica-aware eviction

def test_budget_squeeze_sheds_secondaries_before_sole_copies():
    """The structural ordering: when leftover budget disappears, the
    policy sheds secondaries (counted) while residency — every sole
    copy — is untouched."""
    state = CacheState(n_nodes=2, node_budget_bytes=1000,
                       budget_scope="node")
    chunk_bytes = {1: 300, 2: 300, 3: 600}
    state.cached = {1, 2}
    state.set_replicas(1, 0)
    state.set_replicas(2, 1)
    pol = HotChunkReplication(k=2, threshold=1.0)
    shed = pol.replicate(ReplicationContext(
        state=state, chunk_bytes=chunk_bytes, freq={1: 5.0},
        home_of=lambda c: 0))
    assert shed == 0
    assert state.replicas_of(1) == (0, 1)      # hot chunk gained a copy
    # Next round: placement admitted sole-copy chunk 3 at node 1 and (as
    # every round does) wiped locations back to single-valued. The
    # leftover budget no longer fits chunk 1's secondary -> it is shed;
    # no resident chunk is dropped.
    state.cached = {1, 2, 3}
    state.assign_locations({1: 0, 2: 1, 3: 1})
    shed = pol.replicate(ReplicationContext(
        state=state, chunk_bytes=chunk_bytes, freq={1: 0.5},
        home_of=lambda c: 0))
    assert shed == 1
    assert state.replicas_of(1) == (0,)
    assert state.cached == {1, 2, 3}


def test_replicas_never_push_a_node_over_budget(ptf):
    """Per-node budgets hold with every replica charged at its holder."""
    budget = 60_000
    cl = make_cluster(ptf, budget=budget, budget_scope="node",
                      replication="hot", replication_threshold=1.5)
    cl.run_workload(skewed(ptf[0]), batch_size=3)
    chunk_bytes, _ = cl.coordinator.chunks.size_tables()
    for node, used in cl.coordinator.cache.bytes_by_node(
            chunk_bytes).items():
        assert used <= budget, f"node {node} over budget"


# ------------------------------------------------ kill -> re-admit

@pytest.mark.parametrize("backend", ["simulated", "jax_mesh"])
def test_kill_node_readmits_and_preserves_matches(ptf, backend):
    """Crash-restart of the hottest node mid-workload: lost chunks are
    re-admitted (from replicas or raw files), the recovery counters land
    on the next executed query, and every match count is identical to an
    unfailed reference run."""
    if backend == "jax_mesh":
        pytest.importorskip("jax")
    queries = skewed(ptf[0])
    kw = dict(backend=backend, replication="hot", replica_k=2,
              replication_threshold=2.0)
    reference = [e.matches
                 for e in make_cluster(ptf, **kw).run_workload(
                     queries, batch_size=3)]
    cl = make_cluster(ptf, **kw)
    half = len(queries) // 2
    before = cl.run_workload(queries[:half], batch_size=3)
    victim = hottest_node(cl)
    event = cl.fail_node(victim)
    assert cl.coordinator.stats["node_failures"] == 1
    assert event["failover_readmits"] > 0
    assert (event["recovery_bytes_from_replica"]
            + event["recovery_bytes_from_raw"]) > 0
    after = cl.run_workload(queries[half:], batch_size=3)
    assert [e.matches for e in before + after] == reference
    # The event's counters ride exactly once into the executed stream.
    summary = workload_summary(before + after)
    assert summary["failover_readmits"] == event["failover_readmits"]
    assert summary["recovery_bytes_from_replica"] == \
        event["recovery_bytes_from_replica"]
    assert summary["recovery_bytes_from_raw"] == \
        event["recovery_bytes_from_raw"]


def test_kill_without_replication_recovers_from_raw(ptf):
    """``fail_node`` works under ``replication="off"`` too: every lost
    chunk is a sole copy, so recovery is raw-file re-scan only."""
    cl = make_cluster(ptf)
    cl.run_workload(skewed(ptf[0], n_queries=6), batch_size=3)
    event = cl.fail_node(hottest_node(cl))
    assert event["recovery_bytes_from_replica"] == 0
    assert event["failover_readmits"] > 0
    assert event["recovery_bytes_from_raw"] > 0
    # Post-recovery residency is still single-copy and consistent.
    cache = cl.coordinator.cache
    assert all(len(reps) == 1 for _, reps in cache.location_items())


def test_kill_during_warm_artifact_and_result_cache_workload(ptf):
    """A failure under warm host tiers: the result tier's version stamp
    bumps (no pre-failure hit survives), the artifact cache keeps no
    entry for a non-resident chunk, and the re-planned repeat query
    still produces the identical match count."""
    cl = make_cluster(ptf, join_backend="pallas", result_cache="on",
                      replication="hot", replication_threshold=1.5)
    q = skewed(ptf[0], n_queries=1)[0]
    first = cl.run_query(q)
    warm = cl.run_query(q)
    assert warm.report.result_cache_hit
    assert warm.matches == first.matches
    rc = cl.coordinator.result_cache
    v_before = rc.version
    event = cl.fail_node(hottest_node(cl))
    assert event["failover_readmits"] > 0
    assert rc.version > v_before           # stamp bumped: hits are dead
    assert cl.backend.artifacts is not None
    assert cl.backend.artifacts.chunk_ids() <= cl.coordinator.cache.cached
    again = cl.run_query(q)
    assert not again.report.result_cache_hit
    assert again.matches == first.matches


def test_mesh_replica_buffers_track_replica_sets(ptf):
    """On the mesh backend every cached chunk holds one committed buffer
    per replica, each on its holder's device — before and after a node
    failure."""
    pytest.importorskip("jax")
    cl = make_cluster(ptf, backend="jax_mesh", replication="hot",
                      replication_threshold=1.5)

    def check():
        backend, cache = cl.backend, cl.coordinator.cache
        chunks = cl.coordinator.chunks
        seen_multi = 0
        for cid, reps in cache.location_items():
            if cid not in cache.cached or chunks.meta_of(cid) is None:
                continue
            devs = backend.replica_devices(cid)
            assert set(devs) == set(reps)
            seen_multi += len(reps) > 1
            for node, dev in devs.items():
                assert dev == backend.device_for_node(node)
        return seen_multi

    cl.run_workload(skewed(ptf[0]), batch_size=3)
    assert check() > 0                     # replication actually engaged
    cl.fail_node(hottest_node(cl))
    check()


# --------------------------------------- accessor-discipline regression

FORBIDDEN = re.compile(r"(?:state|cache)\.locations")


def test_no_raw_location_access_outside_cache_state():
    """ISSUE-7 satellite: every location read/write in ``src/repro``
    goes through the ``CacheState`` accessor surface. Any ``*state.
    locations`` / ``*cache.locations`` expression outside
    ``cache_state.py`` — code or docstring — fails this test, so a
    future caller cannot silently hold a single-valued view of a
    multi-valued entry."""
    src = Path(__file__).resolve().parent.parent / "src" / "repro"
    offenders = []
    for path in sorted(src.rglob("*.py")):
        if path.name == "cache_state.py":
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if FORBIDDEN.search(line):
                offenders.append(
                    f"{path.relative_to(src)}:{lineno}: {line.strip()}")
    assert not offenders, (
        "raw CacheState.locations access outside the accessor surface "
        "(use node_of/replicas_of/set_replicas/...):\n"
        + "\n".join(offenders))
