import numpy as np
import pytest

from repro.core.geometry import Box, bounding_box, points_in_box
from repro.core.rtree import EvolvingRTree, RefineStats


def make_tree(coords, min_cells=5):
    counter = iter(range(1, 1_000_000))
    return EvolvingRTree(0, np.asarray(coords, dtype=np.int64), 12,
                         min_cells, lambda: next(counter))


def test_single_chunk_initially():
    t = make_tree([[1, 1], [5, 5], [3, 9]])
    assert t.n_leaves() == 1
    assert t.root_box == Box((1, 1), (5, 9))


def test_figure3_walkthrough():
    """Figure 3: three queries over a 2-D array, MinC=5, ends at 4 chunks.

    We reproduce the *behavioral* claims: Q1 splits the root in two; Q2
    leaves the small relevant chunk alone and splits the other; a query
    overlapping a chunk with no contained cells forces a split.
    """
    # 12 cells, loosely two clusters (top band and bottom band).
    cells = [[1, 1], [2, 2], [1, 4], [3, 2], [2, 5], [3, 5],
             [8, 1], [9, 3], [8, 4], [9, 5], [10, 2], [10, 5]]
    t = make_tree(cells, min_cells=5)
    # Q1 cuts between the bands along dim 0.
    q1 = Box((1, 1), (5, 9))
    got = t.refine(q1)
    assert t.n_leaves() == 2
    assert {c.n_cells for c in t.leaves()} == {6}
    assert len(got) == 1 and got[0].n_cells == 6
    t.validate()
    # Q2 overlaps the left chunk only; 6 cells >= MinC -> splits again.
    q2 = Box((1, 1), (2, 9))
    t.refine(q2)
    assert t.n_leaves() >= 3
    t.validate()
    # Query overlapping a chunk's box but containing none of its cells
    # forces a split even below MinC (the "condensing" rule).
    before = t.n_leaves()
    empty_q = Box((4, 6), (7, 9))   # in the gap between the bands
    got = t.refine(empty_q)
    assert got == []                # no relevant cells
    t.validate()
    assert t.n_leaves() >= before   # any overlapping chunk was condensed


def test_small_relevant_chunk_not_split():
    cells = [[1, 1], [2, 2], [3, 3], [4, 4]]
    t = make_tree(cells, min_cells=5)
    got = t.refine(Box((1, 1), (2, 2)))
    # 4 cells < MinC and a queried cell exists -> unchanged per Alg. 1 line 1.
    assert t.n_leaves() == 1 and len(got) == 1


def test_chunk_inside_query_not_split():
    cells = [[5, 5], [6, 6], [5, 7], [7, 5], [6, 5], [7, 7]]
    t = make_tree(cells, min_cells=2)
    got = t.refine(Box((1, 1), (20, 20)))
    assert t.n_leaves() == 1          # no query face bisects the box
    assert len(got) == 1


def test_refine_returns_only_chunks_with_queried_cells():
    rng = np.random.default_rng(0)
    coords = rng.integers(0, 100, size=(500, 2))
    t = make_tree(coords, min_cells=20)
    q = Box((10, 10), (30, 30))
    got = t.refine(q)
    for c in got:
        pts = t.coords[c.cell_idx]
        assert points_in_box(pts, q).any()
    t.validate()


def test_descendants_after_splits():
    rng = np.random.default_rng(1)
    coords = rng.integers(0, 60, size=(300, 3))
    t = make_tree(coords, min_cells=10)
    root_id = t.leaves()[0].chunk_id
    for lo in range(0, 50, 7):
        t.refine(Box((lo, lo, lo), (lo + 10, lo + 10, lo + 10)))
    desc = t.descendants(root_id)
    assert set(desc) == {c.chunk_id for c in t.leaves()}
    total = sum(t.get_chunk(d).n_cells for d in desc)
    assert total == 300


def test_pruning_via_overlapping():
    cells = [[1, 1], [2, 2], [50, 50], [51, 51]]
    t = make_tree(cells, min_cells=1)
    t.refine(Box((1, 1), (3, 3)))
    # After refinement the middle void is carved out: a query in the void
    # overlaps no leaf -> the file can be pruned without scanning.
    assert t.overlapping(Box((20, 20), (30, 30))) == []


def _best_split_reference(chunk, pts, query):
    """The pre-vectorization _best_split loop, kept as the oracle for
    the one-pass masked min/max implementation (identical choice,
    including first-strict-minimum tie-breaking in candidate order)."""
    from repro.core.geometry import split_boundaries
    candidates = split_boundaries(query, chunk.box)
    if not candidates:
        return None
    best = None
    best_vol = None
    for dim, cut in candidates:
        lo_mask = pts[:, dim] <= cut
        lo_box = bounding_box(pts[lo_mask])
        hi_box = bounding_box(pts[~lo_mask])
        vol = ((lo_box.volume() if lo_box is not None else 0) +
               (hi_box.volume() if hi_box is not None else 0))
        if best_vol is None or vol < best_vol:
            best_vol = vol
            best = (lo_mask, ~lo_mask, lo_box, hi_box)
    lo_mask, hi_mask, lo_box, hi_box = best
    return (np.nonzero(lo_mask)[0], np.nonzero(hi_mask)[0], lo_box, hi_box)


def test_vectorized_best_split_matches_reference():
    rng = np.random.default_rng(0)
    for trial in range(40):
        n = int(rng.integers(2, 300))
        coords = rng.integers(0, 90, size=(n, 2))
        t = make_tree(coords, min_cells=5)
        chunk = t.leaves()[0]
        lo = rng.integers(0, 80, size=2)
        hi = lo + rng.integers(1, 30, size=2)
        q = Box(tuple(int(x) for x in lo), tuple(int(x) for x in hi))
        pts = t.coords[chunk.cell_idx]
        want = _best_split_reference(chunk, pts, q)
        st = RefineStats()
        got = t._best_split(chunk, pts, q, st)
        if want is None:
            assert got is None
            continue
        assert (got[0] == want[0]).all() and (got[1] == want[1]).all()
        assert got[2] == want[2] and got[3] == want[3]
        assert st.split_candidates > 0
        assert st.split_eval_s >= 0.0


def test_refine_stats_split_timings_accumulate():
    rng = np.random.default_rng(5)
    coords = rng.integers(0, 100, size=(400, 2))
    t = make_tree(coords, min_cells=10)
    st = RefineStats()
    t.refine(Box((10, 10), (60, 60)), st)
    assert st.splits > 0
    assert st.split_candidates >= st.splits
    assert st.split_eval_s > 0.0
